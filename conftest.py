"""Make `pytest python/tests/` work from the repo root: the build-time
package lives under python/ (it is never installed — python only runs at
artifact-build time)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
