#!/usr/bin/env python3
"""Promote CI-measured bench artifacts to committed baselines.

Usage: promote_baselines.py <artifact.json> <committed_baseline.json> [...pairs]
       promote_baselines.py --check <baseline.json> [...]

The committed BENCH_*.json baselines at the repo root gate CI through
tools/bench_delta.py — but the gate only ARMS when a baseline carries a
host fingerprint (host_* keys stamped by bench_harness::HostFingerprint)
matching the runner. The seed baselines are hand-estimated and
fingerprint-less, marked PROVISIONAL, so the gate idles.

This script is the promotion step documented in EXPERIMENTS.md: download
the `bench-gemm` / `bench-serving` artifacts from a green CI run on the
target runner class, then

    tools/promote_baselines.py BENCH_gemm.json.artifact BENCH_gemm.json \\
                               BENCH_serving.json.artifact BENCH_serving.json

For each (artifact, baseline) pair it:
  1. refuses artifacts missing the host fingerprint (they could never
     arm the gate — promoting one would silently keep CI advisory);
  2. refuses artifacts whose numeric key set lost keys vs the current
     baseline (a shrunk artifact usually means a bench step silently
     skipped — pass --allow-key-loss to override);
  3. drops any `*_note` keys marking the old baseline PROVISIONAL and
     writes the artifact over the baseline, stamping `promoted_from` so
     the provenance is in the diff.

--check mode verifies committed baselines are armed (fingerprinted and
not PROVISIONAL) and exits 2 otherwise — CI can call it once baselines
have been promoted, making a silent de-arm loud.

Exit codes: 0 ok, 1 usage/IO, 2 validation refused.
"""

import json
import sys

FINGERPRINT_KEYS = ("host_cores", "host_arch", "host_dispatch_path", "host_gemm_threads")


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"promote_baselines: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(1)


def is_provisional(doc):
    return any(
        isinstance(v, str) and "PROVISIONAL" in v
        for k, v in doc.items()
        if k.endswith("_note")
    )


def fingerprinted(doc):
    return all(k in doc for k in FINGERPRINT_KEYS)


def numeric_keys(doc):
    return {k for k, v in doc.items() if isinstance(v, (int, float)) and not k.startswith("host_")}


def check(paths):
    bad = False
    for path in paths:
        doc = load(path)
        problems = []
        if not fingerprinted(doc):
            problems.append("no host fingerprint (gate cannot arm)")
        if is_provisional(doc):
            problems.append("still marked PROVISIONAL")
        if problems:
            print(f"{path}: {'; '.join(problems)}")
            bad = True
        else:
            print(f"{path}: armed ({len(numeric_keys(doc))} gated keys)")
    return 2 if bad else 0


def promote(pairs, allow_key_loss):
    for artifact_path, baseline_path in pairs:
        artifact = load(artifact_path)
        baseline = load(baseline_path)
        if not fingerprinted(artifact):
            print(
                f"promote_baselines: REFUSED {artifact_path}: artifact has no "
                f"host fingerprint ({', '.join(FINGERPRINT_KEYS)}); promoting "
                "it would leave the regression gate disarmed",
                file=sys.stderr,
            )
            return 2
        lost = numeric_keys(baseline) - numeric_keys(artifact)
        if lost and not allow_key_loss:
            print(
                f"promote_baselines: REFUSED {artifact_path}: artifact lost "
                f"{len(lost)} keys the baseline tracks ({', '.join(sorted(lost)[:6])}"
                f"{', ...' if len(lost) > 6 else ''}); a shrunk artifact usually "
                "means a bench step silently skipped. Re-run with "
                "--allow-key-loss to promote anyway.",
                file=sys.stderr,
            )
            return 2
        promoted = {k: v for k, v in artifact.items() if not k.endswith("_note")}
        promoted["promoted_from"] = artifact_path
        with open(baseline_path, "w", encoding="utf-8") as f:
            json.dump(promoted, f, indent=2, sort_keys=True)
            f.write("\n")
        gained = numeric_keys(artifact) - numeric_keys(baseline)
        print(
            f"{baseline_path}: promoted from {artifact_path} "
            f"({len(numeric_keys(artifact))} keys, +{len(gained)} new, gate ARMED)"
        )
    return 0


def main():
    args = sys.argv[1:]
    allow_key_loss = "--allow-key-loss" in args
    args = [a for a in args if a != "--allow-key-loss"]
    if args and args[0] == "--check":
        if len(args) < 2:
            print(__doc__, file=sys.stderr)
            return 1
        return check(args[1:])
    if not args or len(args) % 2 != 0:
        print(__doc__, file=sys.stderr)
        return 1
    pairs = list(zip(args[0::2], args[1::2]))
    return promote(pairs, allow_key_loss)


if __name__ == "__main__":
    sys.exit(main())
