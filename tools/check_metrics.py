#!/usr/bin/env python3
"""Validate edgemlp's Prometheus text exposition (format 0.0.4).

Usage: check_metrics.py <file | http://host:port/metrics> [--require-pool POOL]

Reads the exposition from a file (or `-` for stdin), or scrapes it over
HTTP when the argument starts with http://. Checks, in order:

  1. Line syntax: every line is a comment (# HELP / # TYPE), blank, or
     a sample `name{labels} value` with a parseable float value.
  2. Family structure: each # HELP is immediately followed by the
     matching # TYPE; TYPE is one of counter/gauge/histogram; every
     sample belongs to the most recently declared family (families are
     contiguous, as the exposition format requires).
  3. Required families: the serving engine's always-present inventory
     (see docs/observability.md) must all be declared.
  4. Histogram invariants, per labelset: cumulative buckets are
     non-decreasing in declaration order, a +Inf bucket exists, and it
     equals the matching _count sample.
  5. Counters are non-negative.

With --require-pool, at least one edgemlp_pool_requests_total sample
must carry that pool label (CI uses this to prove the scrape observed
the pool the smoke test exercised).

Exit codes: 0 valid, 1 usage/IO error, 2 validation failure.
"""

import re
import sys
import urllib.request

REQUIRED_FAMILIES = [
    "edgemlp_uptime_seconds",
    "edgemlp_degraded",
    "edgemlp_degraded_transitions_total",
    "edgemlp_read_timeouts_total",
    "edgemlp_busy_rejected_total",
    "edgemlp_shed_total",
    "edgemlp_expired_total",
    "edgemlp_bad_requests_total",
    "edgemlp_trace_buffer_events",
    "edgemlp_trace_dropped_total",
    "edgemlp_static_power_watts",
    "edgemlp_loop_registered_connections",
    "edgemlp_loop_ready_events_total",
    "edgemlp_loop_poll_ticks_total",
    "edgemlp_loop_pending_writeback_bytes",
    "edgemlp_loop_timer_wheel_depth",
    "edgemlp_pool_requests_total",
    "edgemlp_pool_samples_total",
    "edgemlp_pool_batches_total",
    "edgemlp_pool_errors_total",
    "edgemlp_pool_shed_total",
    "edgemlp_pool_expired_total",
    "edgemlp_pool_bytes_per_sample",
    "edgemlp_pool_queue_depth",
    "edgemlp_pool_queue_capacity",
    "edgemlp_pool_replicas",
    "edgemlp_pool_replicas_current",
    "edgemlp_pool_replicas_min",
    "edgemlp_pool_replicas_max",
    "edgemlp_autoscale_scale_ups_total",
    "edgemlp_autoscale_scale_downs_total",
    "edgemlp_autoscale_power_watts",
    "edgemlp_autoscale_power_budget_watts",
    "edgemlp_autoscale_power_degraded",
    "edgemlp_request_latency_seconds",
    "edgemlp_pool_energy_joules_total",
    "edgemlp_pool_energy_joules_per_request",
    "edgemlp_pool_energy_mj_per_sample",
    "edgemlp_pool_power_watts",
]

SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>[^ ]+)$"
)
LABEL_RE = re.compile(r'^(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|\\.)*)"$')


def fail(msg):
    print(f"check_metrics: FAIL: {msg}", file=sys.stderr)
    sys.exit(2)


def parse_labels(raw):
    if not raw:
        return {}
    labels = {}
    # Label values are escaped (\\, \", \n) — split on commas outside
    # quotes.
    parts, depth, cur = [], False, ""
    i = 0
    while i < len(raw):
        c = raw[i]
        if c == "\\" and i + 1 < len(raw):
            cur += raw[i : i + 2]
            i += 2
            continue
        if c == '"':
            depth = not depth
        if c == "," and not depth:
            parts.append(cur)
            cur = ""
        else:
            cur += c
        i += 1
    if cur:
        parts.append(cur)
    for part in parts:
        m = LABEL_RE.match(part)
        if not m:
            fail(f"malformed label pair {part!r}")
        labels[m.group("k")] = m.group("v")
    return labels


def base_family(name):
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def main():
    args = [a for a in sys.argv[1:]]
    require_pool = None
    if "--require-pool" in args:
        i = args.index("--require-pool")
        try:
            require_pool = args[i + 1]
        except IndexError:
            print(__doc__, file=sys.stderr)
            return 1
        del args[i : i + 2]
    if len(args) != 1:
        print(__doc__, file=sys.stderr)
        return 1
    src = args[0]
    try:
        if src.startswith("http://") or src.startswith("https://"):
            with urllib.request.urlopen(src, timeout=10) as resp:
                text = resp.read().decode("utf-8")
        elif src == "-":
            text = sys.stdin.read()
        else:
            with open(src, encoding="utf-8") as f:
                text = f.read()
    except OSError as e:
        print(f"check_metrics: cannot read {src}: {e}", file=sys.stderr)
        return 1

    if not text.endswith("\n"):
        fail("exposition does not end with a newline")

    lines = text.splitlines()
    declared = {}  # family -> type
    helped = set()
    current_family = None
    closed_families = set()
    # (family, labels-minus-le tuple) -> list of bucket values in order
    buckets = {}
    counts = {}
    pool_requests_pools = set()

    for lineno, line in enumerate(lines, 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            rest = line[len("# HELP ") :]
            fam = rest.split(" ", 1)[0]
            helped.add(fam)
            nxt = lines[lineno] if lineno < len(lines) else ""
            if not nxt.startswith(f"# TYPE {fam} "):
                fail(f"line {lineno}: HELP {fam} not followed by its TYPE")
            continue
        if line.startswith("# TYPE "):
            rest = line[len("# TYPE ") :]
            try:
                fam, ty = rest.split(" ", 1)
            except ValueError:
                fail(f"line {lineno}: malformed TYPE line {line!r}")
            if ty not in ("counter", "gauge", "histogram"):
                fail(f"line {lineno}: unknown type {ty!r} for {fam}")
            if fam in declared:
                fail(f"line {lineno}: family {fam} declared twice")
            if current_family is not None:
                closed_families.add(current_family)
            if fam in closed_families:
                fail(f"line {lineno}: family {fam} not contiguous")
            declared[fam] = ty
            current_family = fam
            continue
        if line.startswith("#"):
            continue  # other comments are legal
        m = SAMPLE_RE.match(line)
        if not m:
            fail(f"line {lineno}: unparseable sample {line!r}")
        name, value_s = m.group("name"), m.group("value")
        labels = parse_labels(m.group("labels"))
        try:
            value = float(value_s)
        except ValueError:
            fail(f"line {lineno}: non-float value {value_s!r}")
        fam = base_family(name)
        if fam != current_family:
            fail(f"line {lineno}: sample {name} outside its family block "
                 f"(current: {current_family})")
        ty = declared[fam]
        if ty == "counter" and value < 0:
            fail(f"line {lineno}: counter {name} is negative ({value})")
        if ty == "histogram":
            key = (fam, tuple(sorted((k, v) for k, v in labels.items() if k != "le")))
            if name.endswith("_bucket"):
                buckets.setdefault(key, []).append((labels.get("le", ""), value))
            elif name.endswith("_count"):
                counts[key] = value
        if name == "edgemlp_pool_requests_total" and "pool" in labels:
            pool_requests_pools.add(labels["pool"])

    missing = [f for f in REQUIRED_FAMILIES if f not in declared]
    if missing:
        fail(f"missing required families: {', '.join(missing)}")
    unhelped = [f for f in declared if f not in helped]
    if unhelped:
        fail(f"families without HELP: {', '.join(unhelped)}")

    if not buckets:
        fail("no histogram buckets found")
    for key, bs in buckets.items():
        values = [v for _, v in bs]
        for a, b in zip(values, values[1:]):
            if b < a:
                fail(f"{key}: buckets not cumulative: {values}")
        les = [le for le, _ in bs]
        if "+Inf" not in les:
            fail(f"{key}: no +Inf bucket")
        if key not in counts:
            fail(f"{key}: histogram without _count")
        if values[-1] != counts[key]:
            fail(f"{key}: +Inf bucket {values[-1]} != count {counts[key]}")

    if require_pool is not None and require_pool not in pool_requests_pools:
        fail(f"no edgemlp_pool_requests_total sample for pool "
             f"{require_pool!r} (saw: {sorted(pool_requests_pools)})")

    nsamples = sum(1 for l in lines if l and not l.startswith("#"))
    print(f"check_metrics: OK — {len(declared)} families, {nsamples} samples"
          + (f", pool {require_pool!r} present" if require_pool else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
