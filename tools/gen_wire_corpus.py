#!/usr/bin/env python3
"""Regenerate the committed wire-protocol fuzz corpus.

Usage: gen_wire_corpus.py [out_dir]   (default: rust/tests/data/wire_corpus)

Each .bin file is a byte stream a hostile (or merely old) client might
write to one TCP connection. `rust/tests/wire_fuzz.rs` replays every
file against a live server and checks the expectation encoded in the
filename prefix:

  frame_*    framing error: at most one error frame (BadRequest, v1,
             id 0) then a clean close; never a panic.
  payload_*  well-framed but hostile payload: >= 1 response, every one
             with a non-Ok status; the connection is not poisoned.
  mixed_*    interleaved valid v1..v4 frames (possibly ending in
             garbage): the server must answer what is answerable and
             survive.

The layout mirrors docs/wire-protocol.md: 20-byte header
`"EMWP" | u16 version | u8 opcode | u8 status | u64 id | u32 len`,
little-endian throughout.
"""

import os
import struct
import sys

MAGIC = b"EMWP"


def frame(version, opcode, status, req_id, payload):
    return MAGIC + struct.pack("<HBBQI", version, opcode, status, req_id, len(payload)) + payload


def name(s):
    b = s.encode()
    return struct.pack("<H", len(b)) + b


def f32s(xs):
    return b"".join(struct.pack("<f", x) for x in xs)


def infer_v2(backend, model, xs):
    return struct.pack("<I", backend) + name(model) + struct.pack("<I", len(xs)) + f32s(xs)


def infer_v1(backend, xs):
    return struct.pack("<I", backend) + struct.pack("<I", len(xs)) + f32s(xs)


def qos(deadline_us, priority):
    return struct.pack("<QB", deadline_us, priority)


def infer_v3(backend, model, xs, deadline_us=0, priority=0):
    return (
        struct.pack("<I", backend)
        + name(model)
        + qos(deadline_us, priority)
        + struct.pack("<I", len(xs))
        + f32s(xs)
    )


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "rust/tests/data/wire_corpus"
    os.makedirs(out, exist_ok=True)
    dim8 = [0.25] * 8  # the fuzz server's model is 8-dimensional

    corpus = {}

    # --- framing errors: error frame (or nothing) + close ---
    corpus["frame_truncated_header.bin"] = frame(2, 0, 0, 1, b"")[:10]
    corpus["frame_bad_magic.bin"] = b"XXWP" + frame(2, 0, 0, 1, b"")[4:]
    corpus["frame_bad_version_0.bin"] = frame(0, 0, 0, 1, b"")
    corpus["frame_bad_version_99.bin"] = frame(99, 0, 0, 1, b"")
    # One past the newest supported version (v4) — the near-miss case.
    corpus["frame_bad_version_5.bin"] = frame(5, 0, 0, 1, b"")
    corpus["frame_bad_opcode.bin"] = frame(2, 200, 0, 1, b"")
    corpus["frame_bad_status.bin"] = frame(2, 0, 200, 1, b"")
    # Declares a payload over the 16 MiB cap; no payload bytes follow.
    corpus["frame_oversized_len.bin"] = MAGIC + struct.pack("<HBBQI", 2, 1, 0, 1, 0xFFFFFFFF)
    # Declares 100 payload bytes, delivers 10, then the stream ends.
    corpus["frame_truncated_payload.bin"] = (
        MAGIC + struct.pack("<HBBQI", 2, 0, 0, 1, 100) + b"\x00" * 10
    )

    # --- hostile payloads inside valid frames: BadRequest, no close ---
    # batch = u32::MAX with dim = 0 in a 12-byte payload (alloc bomb).
    corpus["payload_batch_geometry_bomb.bin"] = frame(
        1, 2, 0, 2, struct.pack("<III", 0, 0xFFFFFFFF, 0)
    )
    # Declared geometry disagrees with the bytes present.
    corpus["payload_batch_count_lie.bin"] = frame(
        1,
        2,
        0,
        3,
        struct.pack("<III", 0, 100, 8) + f32s(dim8) * 2,
    )
    corpus["payload_infer_trailing_garbage.bin"] = frame(
        2, 1, 0, 4, infer_v2(0, "", dim8) + b"\x00"
    )
    # v2 model-name length pointing far past the payload.
    corpus["payload_model_name_overflow.bin"] = frame(
        2, 1, 0, 5, struct.pack("<IH", 0, 0xFFFF) + f32s(dim8)
    )
    # ListModels framed at v1 (the opcode is v2-only).
    corpus["payload_listmodels_v1.bin"] = frame(1, 5, 0, 6, b"")
    # SwapModel naming a slot/model the server does not hold.
    corpus["payload_swap_unknown.bin"] = frame(2, 4, 0, 7, name("ghost") + name("nope"))
    # --- hostile precision fields (v4 SwapModel suffix extension) ---
    # Unknown precision byte (9 is outside {0..3}): BadRequest, never a
    # panic — and never a swap.
    corpus["payload_swap_unknown_precision.bin"] = frame(
        4, 4, 0, 30, name("") + name("default") + bytes([9])
    )
    # The precision suffix on a version that forbids it (< v4) is
    # trailing garbage: BadRequest, connection survives.
    corpus["payload_swap_precision_v2.bin"] = frame(
        2, 4, 0, 31, name("") + name("default") + bytes([2])
    )
    corpus["payload_swap_precision_v3.bin"] = frame(
        3, 4, 0, 32, name("") + name("default") + bytes([2])
    )
    # Well-formed Infer whose dimension mismatches the served model.
    corpus["payload_infer_wrong_dim.bin"] = frame(2, 1, 0, 8, infer_v2(0, "", [1.0, 2.0, 3.0]))
    # v1 Infer with a dim lying about the f32s present.
    corpus["payload_infer_v1_dim_lie.bin"] = frame(
        1, 1, 0, 9, struct.pack("<II", 0, 1000) + f32s(dim8)
    )
    # --- hostile v3 QoS fields ---
    # Payload ends four bytes into the u64 deadline field.
    corpus["payload_infer_v3_truncated_deadline.bin"] = frame(
        3, 1, 0, 20, struct.pack("<I", 0) + name("") + struct.pack("<I", 0xDEAD)
    )
    # Deadline beyond the 1-hour protocol cap (u64::MAX µs).
    corpus["payload_infer_v3_absurd_deadline.bin"] = frame(
        3, 1, 0, 21, infer_v3(0, "", dim8, deadline_us=0xFFFFFFFFFFFFFFFF)
    )
    # Priority byte outside the defined set {0, 1, 2}.
    corpus["payload_infer_v3_unknown_priority.bin"] = frame(
        3, 1, 0, 22, infer_v3(0, "", dim8, priority=7)
    )
    # v3 QoS fields inside a v2 frame read as trailing garbage.
    corpus["payload_infer_v2_with_qos_tail.bin"] = frame(
        2, 1, 0, 23, infer_v3(0, "", dim8, deadline_us=50_000)
    )
    # v3 batch whose QoS fields swallow the batch/dim geometry.
    corpus["payload_batch_v3_truncated_qos.bin"] = frame(
        3, 2, 0, 24, struct.pack("<I", 0) + name("") + qos(1_000, 0)
    )
    # Health framed at v2 (the opcode is v3-only).
    corpus["payload_health_v2.bin"] = frame(2, 6, 0, 25, b"")
    # --- v4 observability opcodes framed below their gate ---
    # DumpTrace (7) and StatsV2 (8) are v4-only: pre-v4 framings are
    # BadRequest without poisoning the connection.
    corpus["payload_dumptrace_v1.bin"] = frame(1, 7, 0, 26, b"")
    corpus["payload_dumptrace_v3.bin"] = frame(3, 7, 0, 27, b"")
    corpus["payload_statsv2_v1.bin"] = frame(1, 8, 0, 28, b"")
    corpus["payload_statsv2_v3.bin"] = frame(3, 8, 0, 29, b"")

    # --- mixed v1/v2 traffic on one connection ---
    corpus["mixed_v1_v2_round_trip.bin"] = (
        frame(1, 0, 0, 10, b"ping-v1")
        + frame(2, 0, 0, 11, b"ping-v2")
        + frame(1, 1, 0, 12, infer_v1(0, dim8))
        + frame(2, 1, 0, 13, infer_v2(0, "", dim8))
    )
    corpus["mixed_valid_then_garbage.bin"] = (
        frame(2, 0, 0, 14, b"ok") + frame(1, 1, 0, 15, infer_v1(0, dim8)) + b"\xde" * 24
    )
    # v3 traffic with QoS set, a Health poll, then a legacy v1 ping —
    # one connection speaking three versions.
    corpus["mixed_v3_qos_health_then_v1.bin"] = (
        frame(3, 1, 0, 16, infer_v3(0, "", dim8, deadline_us=3_000_000, priority=1))
        + frame(3, 6, 0, 17, b"")
        + frame(1, 0, 0, 18, b"old-ping")
    )
    # v4 observability opcodes bracketed by legacy traffic — StatsV2
    # and DumpTrace answered inline, then a v1 ping still works.
    corpus["mixed_v4_obs_then_v1.bin"] = (
        frame(4, 1, 0, 19, infer_v3(0, "", dim8))
        + frame(4, 8, 0, 20, b"")
        + frame(4, 7, 0, 21, b"")
        + frame(1, 0, 0, 22, b"old-ping")
    )
    # Valid v4 no-op swap carrying the precision suffix (0 = f32), then
    # legacy traffic — the extension must not poison the connection.
    corpus["mixed_v4_swap_precision_then_v1.bin"] = (
        frame(4, 4, 0, 33, name("") + name("default") + bytes([0]))
        + frame(1, 0, 0, 34, b"old-ping")
    )

    for fname, data in sorted(corpus.items()):
        with open(os.path.join(out, fname), "wb") as f:
            f.write(data)
        print(f"{fname}: {len(data)} bytes")
    print(f"wrote {len(corpus)} corpus files to {out}")


if __name__ == "__main__":
    main()
