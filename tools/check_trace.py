#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON dump from `edgemlp ctl --op trace`.

Usage: check_trace.py <trace.json | -> [--require-cat CAT ...]

Checks that the dump is what Perfetto / chrome://tracing will load:

  1. Parses as JSON with a `traceEvents` list and an
     `otherData.dropped_events` count (the ring-overflow report).
  2. Every event carries the trace-event schema fields: name, ph, pid,
     tid, and (for non-metadata events) a numeric ts; "X" spans carry a
     numeric dur.
  3. Thread rows are named: each (pid, tid) used by an event has a
     thread_name metadata record.
  4. Duration spans exist (ph == "X") — a dump of instants only means
     span recording broke.
  5. Each --require-cat category appears on at least one event (CI
     passes stage/queue/worker/conn to prove the whole request
     lifecycle was captured, per-pipeline-stage spans included).

Exit codes: 0 valid, 1 usage/IO error, 2 validation failure.
"""

import json
import sys


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(2)


def main():
    args = sys.argv[1:]
    required_cats = []
    while "--require-cat" in args:
        i = args.index("--require-cat")
        try:
            required_cats.append(args[i + 1])
        except IndexError:
            print(__doc__, file=sys.stderr)
            return 1
        del args[i : i + 2]
    if len(args) != 1:
        print(__doc__, file=sys.stderr)
        return 1
    try:
        text = sys.stdin.read() if args[0] == "-" else open(args[0], encoding="utf-8").read()
    except OSError as e:
        print(f"check_trace: cannot read {args[0]}: {e}", file=sys.stderr)
        return 1
    try:
        doc = json.loads(text)
    except json.JSONDecodeError as e:
        fail(f"not valid JSON: {e}")

    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("no traceEvents list")
    if "dropped_events" not in doc.get("otherData", {}):
        fail("no otherData.dropped_events count")

    named_threads = set()
    used_threads = set()
    spans = 0
    cats = set()
    for ev in events:
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                fail(f"event missing {field!r}: {ev}")
        if ev["ph"] == "M":
            if ev["name"] == "thread_name":
                named_threads.add((ev["pid"], ev["tid"]))
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            fail(f"event without numeric ts: {ev}")
        used_threads.add((ev["pid"], ev["tid"]))
        cats.add(ev.get("cat", ""))
        if ev["ph"] == "X":
            spans += 1
            if not isinstance(ev.get("dur"), (int, float)):
                fail(f"X span without numeric dur: {ev}")

    unnamed = used_threads - named_threads
    if unnamed:
        fail(f"events on unnamed thread rows: {sorted(unnamed)}")
    if used_threads and spans == 0:
        fail("no duration spans (ph == 'X') in a non-empty trace")
    missing = [c for c in required_cats if c not in cats]
    if missing:
        fail(f"required categories absent: {', '.join(missing)} (saw: {sorted(cats)})")

    dropped = doc["otherData"]["dropped_events"]
    print(
        f"check_trace: OK — {len(events)} events ({spans} spans, "
        f"{len(named_threads)} rows, categories: {', '.join(sorted(c for c in cats if c))}; "
        f"dropped: {dropped})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
