#!/usr/bin/env python3
"""Print baseline-vs-current deltas for the flat BENCH_*.json files.

Usage: bench_delta.py <baseline.json> <current.json>

Both files are flat JSON objects written by bench_harness::BenchJson
(numbers or strings; `null` for non-finite samples). Matching numeric
keys are compared and printed as an aligned table with the relative
delta; keys present on only one side are listed afterwards so renamed
or newly added bench keys are visible in the CI log. Informational
only: always exits 0 when both files parse (perf gating stays a human
decision — CI hosts are too noisy for hard thresholds).
"""

import json
import sys


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_delta: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(1)


def main():
    if len(sys.argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        sys.exit(1)
    base_path, cur_path = sys.argv[1], sys.argv[2]
    base, cur = load(base_path), load(cur_path)

    numeric = lambda v: isinstance(v, (int, float)) and not isinstance(v, bool)
    shared = [k for k in cur if k in base and numeric(base[k]) and numeric(cur[k])]

    print(f"\n== bench delta: {base_path} (baseline) vs {cur_path} (current) ==")
    if isinstance(base.get("baseline_note"), str):
        print(f"baseline note: {base['baseline_note']}")
    if shared:
        width = max(len(k) for k in shared)
        print(f"{'key':<{width}}  {'baseline':>12}  {'current':>12}  {'delta':>8}")
        for k in shared:
            b, c = float(base[k]), float(cur[k])
            delta = f"{(c - b) / b * 100.0:+7.1f}%" if b != 0 else "     n/a"
            print(f"{k:<{width}}  {b:>12.4g}  {c:>12.4g}  {delta}")
    else:
        print("no matching numeric keys")

    # Differing string keys (e.g. gemm_dispatch_path baseline=avx2+fma
    # vs current=scalar) invalidate every numeric delta above — surface
    # them loudly instead of dropping them as non-numeric.
    for k in cur:
        if k in base and isinstance(base[k], str) and base[k] != cur[k]:
            print(f"MISMATCHED CONTEXT {k}: baseline={base[k]!r} current={cur[k]!r}")

    only_base = [k for k in base if k not in cur]
    only_cur = [k for k in cur if k not in base]
    if only_base:
        print(f"baseline-only keys: {', '.join(sorted(only_base))}")
    if only_cur:
        print(f"current-only keys:  {', '.join(sorted(only_cur))}")


if __name__ == "__main__":
    try:
        main()
    except BrokenPipeError:  # e.g. piped into `head`
        sys.exit(0)
