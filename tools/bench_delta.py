#!/usr/bin/env python3
"""Compare flat BENCH_*.json files and gate on perf regressions.

Usage: bench_delta.py [--max-regress PCT] <baseline.json> <current.json>

Both files are flat JSON objects written by bench_harness::BenchJson
(numbers or strings; `null` for non-finite samples). Matching numeric
keys are compared and printed as an aligned table with the relative
delta; keys present on only one side are listed afterwards so renamed
or newly added bench keys are visible in the CI log.

Regression gate
---------------
A key regresses when it moves in its bad direction by more than the
threshold (--max-regress, or env BENCH_DELTA_MAX_REGRESS; default
10%). Direction is inferred from the key name: throughput-like keys
(rps, gflops, speedup, attainment, ...) are higher-better; time-like
keys (*_ms, *_s, *_ns, *p50*, *p99*, ...) are lower-better. Keys whose
direction cannot be inferred never gate.

The gate is ARMED only when the baseline carries a host fingerprint
(the host_* keys stamped by bench_harness::HostFingerprint) and it
matches the current run's fingerprint. A fingerprint-less baseline is
PROVISIONAL — deltas print but never fail. A mismatched fingerprint
(different core count, ISA, or SIMD dispatch path) disarms the gate
and prints MISMATCHED CONTEXT loudly: numbers from different hosts are
not comparable.

Exit codes: 0 ok / informational, 1 usage or unreadable input,
2 regression past threshold on an armed gate.
"""

import json
import os
import sys

FINGERPRINT_KEYS = ("host_cores", "host_arch", "host_dispatch_path", "host_gemm_threads")

# Lower-better substrings that would otherwise be swallowed by the
# higher-better "per_s" match ("bytes_per_sample", "mj_per_sample") —
# checked before everything else.
LOWER_BETTER_FIRST = ("bytes_per_sample", "mj_per_sample")
# Substrings (checked against the lowercased key) that mark a metric
# where larger is better.
HIGHER_BETTER = ("rps", "gflops", "speedup", "throughput", "attainment", "per_s", "ops")
# Suffixes / substrings marking a metric where smaller is better.
LOWER_BETTER_SUFFIX = ("_ms", "_s", "_us", "_ns")
LOWER_BETTER_SUBSTR = (
    "p50",
    "p99",
    "latency",
    "shed_rate",
    "expired",
    "errors",
    "energy",
    "rss",
    "watts",
    "settle",
)


def direction(key):
    """+1 higher-better, -1 lower-better, 0 unknown (never gates)."""
    k = key.lower()
    if k.startswith("host_"):
        return 0
    if any(s in k for s in LOWER_BETTER_FIRST):
        return -1
    if any(s in k for s in HIGHER_BETTER):
        return +1
    if k.endswith(LOWER_BETTER_SUFFIX) or any(s in k for s in LOWER_BETTER_SUBSTR):
        return -1
    return 0


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_delta: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(1)


def parse_args(argv):
    threshold = float(os.environ.get("BENCH_DELTA_MAX_REGRESS", "10"))
    paths = []
    it = iter(argv)
    for a in it:
        if a == "--max-regress":
            nxt = next(it, None)
            if nxt is None:
                print("bench_delta: --max-regress needs a value", file=sys.stderr)
                sys.exit(1)
            threshold = float(nxt)
        elif a.startswith("--max-regress="):
            threshold = float(a.split("=", 1)[1])
        else:
            paths.append(a)
    if len(paths) != 2 or threshold < 0:
        print(__doc__.strip(), file=sys.stderr)
        sys.exit(1)
    return threshold, paths[0], paths[1]


def main():
    threshold, base_path, cur_path = parse_args(sys.argv[1:])
    base, cur = load(base_path), load(cur_path)

    numeric = lambda v: isinstance(v, (int, float)) and not isinstance(v, bool)
    shared = [k for k in cur if k in base and numeric(base[k]) and numeric(cur[k])]

    # Gate arming: baseline must carry a full fingerprint, and it must
    # match the current run's.
    base_fp = {k: base.get(k) for k in FINGERPRINT_KEYS}
    cur_fp = {k: cur.get(k) for k in FINGERPRINT_KEYS}
    provisional = any(v is None for v in base_fp.values())
    fp_mismatch = not provisional and base_fp != cur_fp
    armed = not provisional and not fp_mismatch

    print(f"\n== bench delta: {base_path} (baseline) vs {cur_path} (current) ==")
    if isinstance(base.get("baseline_note"), str):
        print(f"baseline note: {base['baseline_note']}")
    if provisional:
        print("baseline is PROVISIONAL (no host fingerprint) — gate disarmed, deltas informational")
    elif fp_mismatch:
        print("host fingerprint differs — gate disarmed, deltas informational")
    else:
        print(f"gate armed: fail on >{threshold:g}% regression (direction-aware)")

    regressions = []
    if shared:
        width = max(len(k) for k in shared)
        print(f"{'key':<{width}}  {'baseline':>12}  {'current':>12}  {'delta':>8}")
        for k in shared:
            b, c = float(base[k]), float(cur[k])
            if b != 0:
                pct = (c - b) / b * 100.0
                delta = f"{pct:+7.1f}%"
            else:
                pct = None
                delta = "     n/a"
            mark = ""
            if pct is not None:
                d = direction(k)
                regressed = (d > 0 and pct < -threshold) or (d < 0 and pct > threshold)
                if regressed:
                    mark = "  << REGRESSION" if armed else "  (regression; gate disarmed)"
                    if armed:
                        regressions.append((k, pct))
            print(f"{k:<{width}}  {b:>12.4g}  {c:>12.4g}  {delta}{mark}")
    else:
        print("no matching numeric keys")

    # Differing string keys (e.g. host_dispatch_path baseline=avx2+fma
    # vs current=scalar) invalidate every numeric delta above — surface
    # them loudly instead of dropping them as non-numeric.
    for k in cur:
        if k in base and isinstance(base[k], str) and base[k] != cur[k]:
            print(f"MISMATCHED CONTEXT {k}: baseline={base[k]!r} current={cur[k]!r}")

    only_base = [k for k in base if k not in cur]
    only_cur = [k for k in cur if k not in base]
    if only_base:
        print(f"baseline-only keys: {', '.join(sorted(only_base))}")
    if only_cur:
        print(f"current-only keys:  {', '.join(sorted(only_cur))}")

    if regressions:
        keys = ", ".join(f"{k} ({pct:+.1f}%)" for k, pct in regressions)
        print(f"bench_delta: FAIL — {len(regressions)} regression(s) past {threshold:g}%: {keys}")
        sys.exit(2)


if __name__ == "__main__":
    try:
        main()
    except BrokenPipeError:  # e.g. piped into `head`
        sys.exit(0)
