"""L2: the paper's compute graphs in JAX, built on the L1 kernels.

Two MLP variants (Eq 4.2, 784-128-10 sigmoid) — fp32 and SPx — plus the
Acrobot Q-network. These are the functions ``aot.py`` lowers to HLO
text; weights are runtime *inputs* (not baked constants) so one artifact
serves any training checkpoint the rust side produces.

The output layer (m = 10) is not 128-divisible, so its kernel runs with
tile_m = 10 (a single grid step); the hidden layer uses the full
tile_m = 128. Sigmoids stay in the XLA graph where they fuse with the
kernel's output write.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import spx_matmul as k


def sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def mlp_fp32(x, w2, b2, w3, b3):
    """Eq 4.2 with f32 weights, dense layers as Pallas kernels.

    x (B, 784); w2 (128, 784); b2 (128,); w3 (10, 128); b3 (10,).
    Returns (B, 10) class scores in (0, 1).
    """
    h = sigmoid(k.dense(x, w2, b2, tile_m=w2.shape[0]))
    return sigmoid(k.dense(h, w3, b3, tile_m=w3.shape[0]))


def mlp_spx(x, signs2, planes2, scale2, b2, signs3, planes3, scale3, b3):
    """Eq 4.2 with SPx-quantized weights decoded in the L1 kernel.

    signs* (m, n) int32; planes* (x, m, n) int32; scale* (1,) f32.
    """
    h = sigmoid(k.spx_matvec(x, signs2, planes2, scale2, b2, tile_m=signs2.shape[0]))
    return sigmoid(k.spx_matvec(h, signs3, planes3, scale3, b3, tile_m=signs3.shape[0]))


def qnet_fp32(x, w1, b1, w2, b2, w3, b3):
    """Acrobot Q-network (6-64-64-3, relu/relu/identity)."""
    h1 = jnp.maximum(k.dense(x, w1, b1, tile_m=w1.shape[0]), 0.0)
    h2 = jnp.maximum(k.dense(h1, w2, b2, tile_m=w2.shape[0]), 0.0)
    return k.dense(h2, w3, b3, tile_m=w3.shape[0])
