"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Everything here is straight-line jax.numpy with no pallas, no tiling —
the semantics the kernels must reproduce. pytest compares kernel output
against these under hypothesis-driven shape/seed sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp


def spx_decode_ref(signs, planes, scale):
    """Decode SPx operands to an f32 weight matrix.

    signs:  (m, n) int32 in {+1, -1}
    planes: (x, m, n) int32 exponent codes (0 = absent, k -> 2^-k)
    scale:  (1,) f32 — alpha / max_sum
    """
    mags = jnp.where(planes == 0, 0.0, jnp.exp2(-planes.astype(jnp.float32)))
    w = signs.astype(jnp.float32) * mags.sum(axis=0)
    return w * scale[0]


def spx_matvec_ref(x, signs, planes, scale, bias):
    """y = x @ decode(W)^T + b for batched x: (B, n) -> (B, m)."""
    w = spx_decode_ref(signs, planes, scale)  # (m, n)
    return x @ w.T + bias


def dense_ref(x, w, b):
    """Plain f32 dense layer: (B, n) @ (m, n)^T + (m,)."""
    return x @ w.T + b


def sigmoid_ref(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def mlp_fp32_ref(x, w2, b2, w3, b3):
    """The paper's Eq 4.2: sigma(W3 sigma(W2 x + b2) + b3), batched."""
    h = sigmoid_ref(dense_ref(x, w2, b2))
    return sigmoid_ref(dense_ref(h, w3, b3))


def mlp_spx_ref(x, signs2, planes2, scale2, b2, signs3, planes3, scale3, b3):
    """Eq 4.2 with SPx-decoded weights."""
    h = sigmoid_ref(spx_matvec_ref(x, signs2, planes2, scale2, b2))
    return sigmoid_ref(spx_matvec_ref(h, signs3, planes3, scale3, b3))


def qnet_ref(x, w1, b1, w2, b2, w3, b3):
    """Acrobot Q-network: relu-relu-identity."""
    h1 = jnp.maximum(dense_ref(x, w1, b1), 0.0)
    h2 = jnp.maximum(dense_ref(h1, w2, b2), 0.0)
    return dense_ref(h2, w3, b3)
