"""L1 Pallas kernel: the SPx shift-add matmul (§3.1 + §3.2 on TPU).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the FPGA design's
shift-add MAC becomes *exponent-field decode* on the VPU — an SPx code k
IS the (negated, biased) f32 exponent, so decoding is integer work:

    bits = (127 - k) << 23          # f32 for 2^-k, k in 1..127
    w    = sign * bitcast_f32(bits) # zero when the term is absent

— no transcendental, no table, no multiply. The decoded tile then feeds
``jnp.dot`` which lowers to the MXU systolic array. The paper's input
buffer / dual-clock decoupling maps onto the Pallas grid's implicit
HBM->VMEM double buffering: while the MXU contracts k-tile t, the next
tile's operands stream in.

Grid/tiling: the output (B, m) is produced in one shot per m-tile
(grid = m / TILE_M), with the full reduction dimension n resident — for
the paper's sizes (n = 784, B <= 64) one m-tile's working set is
  x: B*n*4 = 200 KiB, codes: x_terms*TILE_M*n, dec: TILE_M*n*4
which for TILE_M = 128, x = 2 is ~1.1 MiB — comfortably inside a 16 MiB
VMEM budget (exact numbers in DESIGN.md §8).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact
runs on the rust runtime. Real-TPU perf is *estimated* structurally, not
measured here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spx_matvec_kernel(x_ref, signs_ref, planes_ref, scale_ref, bias_ref, o_ref):
    """One m-tile: decode the SPx codes bitwise, then MXU matmul.

    x_ref:      (B, n)            f32
    signs_ref:  (TILE_M, n)       int32 (+1/-1)
    planes_ref: (x, TILE_M, n)    int32 exponent codes
    scale_ref:  (1,)              f32
    bias_ref:   (TILE_M,)         f32
    o_ref:      (B, TILE_M)       f32
    """
    planes = planes_ref[...]
    # Exponent-field decode: 2^-k as bit pattern (127 - k) << 23.
    bits = ((127 - planes) << 23).astype(jnp.int32)
    mags = jnp.where(
        planes == 0,
        jnp.float32(0.0),
        jax.lax.bitcast_convert_type(bits, jnp.float32),
    )
    # Sum the x term planes, apply the sign plane -> decoded tile.
    w = signs_ref[...].astype(jnp.float32) * mags.sum(axis=0)  # (TILE_M, n)
    # MXU contraction: (B, n) x (TILE_M, n)^T.
    acc = jnp.dot(x_ref[...], w.T, preferred_element_type=jnp.float32)
    o_ref[...] = acc * scale_ref[0] + bias_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("tile_m",))
def spx_matvec(x, signs, planes, scale, bias, *, tile_m: int = 128):
    """y = x @ decode(signs, planes, scale)^T + bias via the Pallas kernel.

    Shapes: x (B, n); signs (m, n); planes (x, m, n); scale (1,);
    bias (m,). m must be divisible by tile_m (pad upstream; the paper's
    m = 128 hidden layer fits exactly, m = 10 output uses tile_m = 10).
    """
    batch, n = x.shape
    m = signs.shape[0]
    if m % tile_m != 0:
        raise ValueError(f"m={m} not divisible by tile_m={tile_m}")
    x_terms = planes.shape[0]
    grid = (m // tile_m,)
    return pl.pallas_call(
        _spx_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, n), lambda i: (0, 0)),
            pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
            pl.BlockSpec((x_terms, tile_m, n), lambda i: (0, i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile_m,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((batch, tile_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, m), jnp.float32),
        interpret=True,
    )(x, signs, planes, scale, bias)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref):
    """f32 dense tile: (B, n) x (TILE_M, n)^T + b."""
    acc = jnp.dot(x_ref[...], w_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = acc + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("tile_m",))
def dense(x, w, b, *, tile_m: int = 128):
    """Plain f32 dense layer as a Pallas kernel (fp32 baseline path)."""
    batch, n = x.shape
    m = w.shape[0]
    if m % tile_m != 0:
        raise ValueError(f"m={m} not divisible by tile_m={tile_m}")
    return pl.pallas_call(
        _dense_kernel,
        grid=(m // tile_m,),
        in_specs=[
            pl.BlockSpec((batch, n), lambda i: (0, 0)),
            pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
            pl.BlockSpec((tile_m,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((batch, tile_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, m), jnp.float32),
        interpret=True,
    )(x, w, b)


def vmem_bytes_estimate(batch: int, n: int, tile_m: int, x_terms: int) -> int:
    """Static VMEM working-set estimate for one grid step of
    ``spx_matvec`` (DESIGN.md §8 uses this for the L1 perf targets)."""
    x_bytes = batch * n * 4
    signs_bytes = tile_m * n * 4
    planes_bytes = x_terms * tile_m * n * 4
    decode_bytes = tile_m * n * 4  # the decoded tile
    out_bytes = batch * tile_m * 4
    return x_bytes + signs_bytes + planes_bytes + decode_bytes + out_bytes
