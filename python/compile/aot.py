"""AOT pipeline: lower the L2 graphs to HLO *text* artifacts + manifest.

HLO text (not ``.serialize()``) is the interchange format — the image's
xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit
instruction ids); the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage (from ``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per model variant and ``manifest.json``
describing every artifact's inputs/outputs so the rust runtime can
validate shapes before feeding buffers.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .quant import SpxConfig

# The paper's architecture (§4.1) and the Q-network (§4.2).
MNIST_SIZES = (784, 128, 10)
QNET_SIZES = (6, 64, 64, 3)
# SPx configuration baked into the quantized artifacts: SP2 at b=5
# (1 sign + 2+2 term bits), the paper's headline scheme.
SPX_TERMS = 2
SPX_TOTAL_BITS = 5
# Batch variants: single-sample (edge latency) and the paper's B=64.
BATCHES = (1, 64)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def mlp_fp32_specs(batch: int):
    d, h, o = MNIST_SIZES
    return [
        ("x", _spec((batch, d))),
        ("w2", _spec((h, d))),
        ("b2", _spec((h,))),
        ("w3", _spec((o, h))),
        ("b3", _spec((o,))),
    ]


def mlp_spx_specs(batch: int):
    d, h, o = MNIST_SIZES
    x = SPX_TERMS
    return [
        ("x", _spec((batch, d))),
        ("signs2", _spec((h, d), jnp.int32)),
        ("planes2", _spec((x, h, d), jnp.int32)),
        ("scale2", _spec((1,))),
        ("b2", _spec((h,))),
        ("signs3", _spec((o, h), jnp.int32)),
        ("planes3", _spec((x, o, h), jnp.int32)),
        ("scale3", _spec((1,))),
        ("b3", _spec((o,))),
    ]


def qnet_specs(batch: int):
    d, h1, h2, o = QNET_SIZES
    return [
        ("x", _spec((batch, d))),
        ("w1", _spec((h1, d))),
        ("b1", _spec((h1,))),
        ("w2", _spec((h2, h1))),
        ("b2", _spec((h2,))),
        ("w3", _spec((o, h2))),
        ("b3", _spec((o,))),
    ]


def artifact_defs():
    """(name, fn, specs, meta) for every artifact we ship."""
    defs = []
    for batch in BATCHES:
        defs.append(
            (
                f"mlp_fp32_b{batch}",
                model.mlp_fp32,
                mlp_fp32_specs(batch),
                {"model": "mlp_fp32", "batch": batch, "sizes": list(MNIST_SIZES)},
            )
        )
        defs.append(
            (
                f"mlp_spx_b{batch}",
                model.mlp_spx,
                mlp_spx_specs(batch),
                {
                    "model": "mlp_spx",
                    "batch": batch,
                    "sizes": list(MNIST_SIZES),
                    "spx_terms": SPX_TERMS,
                    "spx_total_bits": SPX_TOTAL_BITS,
                    "spx_term_bits": list(
                        SpxConfig.spx(SPX_TOTAL_BITS, SPX_TERMS).term_bits
                    ),
                },
            )
        )
    defs.append(
        (
            "qnet_fp32_b1",
            model.qnet_fp32,
            qnet_specs(1),
            {"model": "qnet_fp32", "batch": 1, "sizes": list(QNET_SIZES)},
        )
    )
    return defs


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"format": "hlo-text", "artifacts": {}}
    for name, fn, specs, meta in artifact_defs():
        lowered = jax.jit(fn).lower(*[s for _, s in specs])
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": path,
            "inputs": [
                {
                    "name": arg_name,
                    "shape": list(s.shape),
                    "dtype": s.dtype.name,
                }
                for arg_name, s in specs
            ],
            "outputs": [
                {
                    "shape": [meta["batch"], meta["sizes"][-1]],
                    "dtype": "float32",
                }
            ],
            "meta": meta,
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
