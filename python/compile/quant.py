"""SPx quantization — python mirror of ``rust/src/quant/spx.rs``.

The rust side owns the canonical implementation (it quantizes trained
weights before they are fed to any backend); this mirror exists so the
build-time pytest suite can generate hardware-layout operands (sign
plane + exponent-code planes + scale) for the Pallas kernel without a
round-trip through rust. The two implementations are pinned together by
``python/tests/test_quant.py`` which re-derives the level sets from the
same Eq 3.3/3.4 definitions.

Representation (identical to rust):
  * per weight: a sign in {+1, -1} and ``x`` exponent codes, where code
    0 means "term absent" and code k in 1..2^{b_i}-1 contributes 2^-k;
  * the level set is normalized by its maximum sum so levels span
    [-1, 1]; the residual per-tensor scale is ``alpha / max_sum``.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class SpxConfig:
    """Bit widths of the x terms; total bits b = 1 + sum(term_bits)."""

    term_bits: tuple[int, ...]

    def __post_init__(self) -> None:
        if not self.term_bits:
            raise ValueError("need at least one term")
        if any(not (1 <= b <= 7) for b in self.term_bits):
            raise ValueError(f"term bits must be in 1..=7: {self.term_bits}")

    @staticmethod
    def sp2(total_bits: int) -> "SpxConfig":
        if total_bits < 3:
            raise ValueError("sp2 needs b >= 3")
        payload = total_bits - 1
        return SpxConfig((-(-payload // 2), payload // 2))

    @staticmethod
    def spx(total_bits: int, x: int) -> "SpxConfig":
        if not (x >= 1 and total_bits > x):
            raise ValueError("need b > x >= 1")
        payload = total_bits - 1
        base, extra = divmod(payload, x)
        return SpxConfig(tuple(base + (1 if i < extra else 0) for i in range(x)))

    @property
    def num_terms(self) -> int:
        return len(self.term_bits)

    @property
    def total_bits(self) -> int:
        return 1 + sum(self.term_bits)


def code_magnitude(code: tuple[int, ...]) -> float:
    """Raw (un-normalized) magnitude of a code vector."""
    return float(sum(0.0 if k == 0 else 2.0 ** (-k) for k in code))


@dataclass
class SpxCodebook:
    """Normalized level table plus canonical code per level."""

    config: SpxConfig
    levels: np.ndarray = field(init=False)  # sorted, includes negatives
    codes_by_level: list[tuple[int, ...]] = field(init=False)
    max_sum: float = field(init=False)

    def __post_init__(self) -> None:
        spaces = [range(1 << b) for b in self.config.term_bits]
        by_mag: dict[float, tuple[int, ...]] = {}
        for combo in itertools.product(*spaces):
            mag = code_magnitude(combo)
            active = sum(1 for k in combo if k != 0)
            old = by_mag.get(mag)
            if old is None or (active, combo) < (
                sum(1 for k in old if k != 0),
                old,
            ):
                by_mag[mag] = combo
        self.max_sum = max(by_mag)
        if self.max_sum <= 0.0:
            raise ValueError("degenerate SPx codebook")
        levels: list[float] = []
        mag_to_code: dict[float, tuple[int, ...]] = {}
        for mag, code in sorted(by_mag.items()):
            # Normalize in f32 so keys match the stored level values
            # exactly (the rust side also stores f32 levels).
            norm = float(np.float32(mag) / np.float32(self.max_sum))
            mag_to_code[norm] = code
            levels.append(norm)
            if norm > 0.0:
                levels.append(-norm)
        self.levels = np.array(sorted(levels), dtype=np.float32)
        self.codes_by_level = []
        for lvl in self.levels:
            self.codes_by_level.append(mag_to_code[abs(float(lvl))])

    def nearest(self, x: np.ndarray) -> np.ndarray:
        """Index of the nearest level, ties to the lower level (matches
        rust ``Codebook::nearest``)."""
        ls = self.levels
        idx = np.searchsorted(ls, x)
        idx = np.clip(idx, 1, len(ls) - 1)
        below = ls[idx - 1]
        above = ls[idx]
        pick_below = (x - below) <= (above - x)
        out = np.where(pick_below, idx - 1, idx)
        # Clamp handled by searchsorted bounds above.
        out = np.where(x <= ls[0], 0, out)
        out = np.where(x >= ls[-1], len(ls) - 1, out)
        return out.astype(np.int64)


@dataclass
class SpxTensor:
    """Hardware-layout quantized tensor."""

    config: SpxConfig
    shape: tuple[int, ...]
    signs: np.ndarray  # int32, +1/-1, flat
    planes: np.ndarray  # int32, (x, numel) exponent codes
    scale: float  # alpha / max_sum
    indices: np.ndarray  # level index per element
    table: SpxCodebook

    def decode(self) -> np.ndarray:
        alpha = self.scale * self.table.max_sum
        return (self.table.levels[self.indices] * alpha).reshape(self.shape)

    def decode_shift_add(self) -> np.ndarray:
        """Sign · Σ 2^-k · scale — the hardware path (and what the Pallas
        kernel computes)."""
        mags = np.where(self.planes == 0, 0.0, np.ldexp(1.0, -self.planes)).sum(axis=0)
        return (self.signs * mags * self.scale).astype(np.float32).reshape(self.shape)


def encode(config: SpxConfig, data: np.ndarray) -> SpxTensor:
    """Quantize ``data`` with max-abs calibration (the paper's implicit
    choice and the rust default)."""
    flat = np.asarray(data, dtype=np.float32).ravel()
    table = SpxCodebook(config)
    alpha = float(np.max(np.abs(flat))) if flat.size else 0.0
    inv = 1.0 / alpha if alpha > 0.0 else 0.0
    normalized = np.clip(flat * inv, -1.0, 1.0)
    indices = table.nearest(normalized)
    levels = table.levels[indices]
    signs = np.where(levels < 0.0, -1, 1).astype(np.int32)
    planes = np.zeros((config.num_terms, flat.size), dtype=np.int32)
    for e, idx in enumerate(indices):
        for t, k in enumerate(table.codes_by_level[idx]):
            planes[t, e] = k
    return SpxTensor(
        config=config,
        shape=tuple(np.asarray(data).shape),
        signs=signs,
        planes=planes,
        scale=alpha / table.max_sum,
        indices=indices,
        table=table,
    )
