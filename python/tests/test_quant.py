"""SPx quantization properties (python mirror) — pinned to the same
Eq 3.3/3.4 semantics the rust implementation tests."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.quant import SpxCodebook, SpxConfig, code_magnitude, encode


def test_sp2_split():
    assert SpxConfig.sp2(5).term_bits == (2, 2)
    assert SpxConfig.sp2(6).term_bits == (3, 2)


def test_spx_split_total_bits():
    for b in range(3, 9):
        for x in range(1, 4):
            if b > x:
                assert SpxConfig.spx(b, x).total_bits == b


def test_invalid_configs_rejected():
    with pytest.raises(ValueError):
        SpxConfig(())
    with pytest.raises(ValueError):
        SpxConfig((8,))
    with pytest.raises(ValueError):
        SpxConfig.spx(2, 2)


def test_sp2_b3_codebook_manual():
    # b1=b2=1 -> q_i in {0, 1/2} -> levels {0, +-1/2, +-1} (max_sum 1).
    t = SpxCodebook(SpxConfig((1, 1)))
    assert t.max_sum == 1.0
    np.testing.assert_allclose(t.levels, [-1.0, -0.5, 0.0, 0.5, 1.0])


def test_code_magnitude():
    assert code_magnitude((0, 0)) == 0.0
    assert code_magnitude((1, 0)) == 0.5
    assert code_magnitude((1, 1)) == 1.0
    assert code_magnitude((2, 3)) == 0.375


def test_canonical_code_prefers_fewer_terms():
    t = SpxCodebook(SpxConfig((2, 2)))
    idx = int(np.where(t.levels == 0.5)[0][0])
    code = t.codes_by_level[idx]
    assert sum(1 for k in code if k != 0) == 1


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(min_value=3, max_value=8),
    x=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_decode_paths_agree(b, x, seed):
    """Table decode == shift-add decode (the kernel's semantics)."""
    if b <= x:
        return
    rng = np.random.default_rng(seed)
    data = rng.normal(size=64).astype(np.float32)
    t = encode(SpxConfig.spx(b, x), data)
    np.testing.assert_allclose(t.decode(), t.decode_shift_add(), rtol=1e-5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_quantization_idempotent(seed):
    rng = np.random.default_rng(seed)
    data = rng.uniform(-3.0, 3.0, size=32).astype(np.float32)
    cfg = SpxConfig.sp2(5)
    once = encode(cfg, data).decode()
    twice = encode(cfg, once).decode()
    np.testing.assert_allclose(twice, once, rtol=1e-6, atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_quantization_error_bounded_by_max_gap(seed):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=128).astype(np.float32)
    cfg = SpxConfig.sp2(6)
    t = encode(cfg, data)
    alpha = t.scale * t.table.max_sum
    gaps = np.diff(t.table.levels)
    max_gap = float(gaps.max())
    err = np.abs(t.decode() - data)
    assert err.max() <= (max_gap / 2) * alpha * (1 + 1e-5)


def test_levels_symmetric_and_contain_zero():
    for b in range(3, 8):
        for x in (1, 2, 3):
            if b <= x:
                continue
            t = SpxCodebook(SpxConfig.spx(b, x))
            assert 0.0 in t.levels
            np.testing.assert_allclose(np.sort(-t.levels), t.levels, atol=0)


def test_planes_shape_and_sign_values():
    data = np.linspace(-1, 1, 24).astype(np.float32).reshape(4, 6)
    t = encode(SpxConfig.spx(7, 3), data)
    assert t.planes.shape == (3, 24)
    assert set(np.unique(t.signs)) <= {-1, 1}
    assert t.shape == (4, 6)
