"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

hypothesis sweeps shapes and seeds; every case must match ``ref.py`` to
f32 tolerance. interpret=True keeps the kernels executable on CPU."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels import spx_matmul as k
from compile.quant import SpxConfig, encode


def _quantized_operands(m, n, x_terms, seed, scale=0.4):
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(m, n)) * scale).astype(np.float32)
    t = encode(SpxConfig.spx(2 + x_terms, x_terms), w)
    signs = jnp.array(t.signs.reshape(m, n))
    planes = jnp.array(t.planes.reshape(x_terms, m, n))
    sc = jnp.array([t.scale], dtype=jnp.float32)
    return signs, planes, sc


@settings(max_examples=25, deadline=None)
@given(
    batch=st.sampled_from([1, 2, 8]),
    m=st.sampled_from([8, 16, 128]),
    n=st.sampled_from([16, 64, 784]),
    x_terms=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_spx_matvec_matches_ref(batch, m, n, x_terms, seed):
    rng = np.random.default_rng(seed + 1)
    signs, planes, scale = _quantized_operands(m, n, x_terms, seed)
    x = jnp.array(rng.random(size=(batch, n)).astype(np.float32))
    bias = jnp.array(rng.normal(size=(m,)).astype(np.float32))
    got = k.spx_matvec(x, signs, planes, scale, bias, tile_m=m)
    want = ref.spx_matvec_ref(x, signs, planes, scale, bias)
    # f32 reduction order differs between the tiled kernel and the
    # one-shot reference; n = 784 accumulations need ~5e-5 of slack.
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=5e-5)


def test_spx_matvec_tiled_grid_matches_single_tile():
    # m = 128 with tile_m = 32 exercises a 4-step grid.
    signs, planes, scale = _quantized_operands(128, 64, 2, 7)
    rng = np.random.default_rng(3)
    x = jnp.array(rng.random(size=(4, 64)).astype(np.float32))
    bias = jnp.array(rng.normal(size=(128,)).astype(np.float32))
    tiled = k.spx_matvec(x, signs, planes, scale, bias, tile_m=32)
    whole = k.spx_matvec(x, signs, planes, scale, bias, tile_m=128)
    np.testing.assert_allclose(tiled, whole, rtol=1e-6, atol=1e-6)


def test_spx_matvec_rejects_bad_tiling():
    signs, planes, scale = _quantized_operands(10, 16, 2, 0)
    x = jnp.zeros((1, 16))
    bias = jnp.zeros((10,))
    try:
        k.spx_matvec(x, signs, planes, scale, bias, tile_m=4)
        raise AssertionError("expected ValueError")
    except ValueError:
        pass


def test_exponent_decode_is_exact():
    """The bitwise (127-k)<<23 decode must equal 2^-k exactly."""
    for kk in range(1, 127):
        planes = jnp.full((1, 1, 1), kk, dtype=jnp.int32)
        signs = jnp.ones((1, 1), dtype=jnp.int32)
        scale = jnp.array([1.0], dtype=jnp.float32)
        x = jnp.ones((1, 1), dtype=jnp.float32)
        bias = jnp.zeros((1,), dtype=jnp.float32)
        got = float(k.spx_matvec(x, signs, planes, scale, bias, tile_m=1)[0, 0])
        assert got == 2.0 ** (-kk), f"k={kk}: {got}"


def test_absent_term_contributes_zero():
    planes = jnp.zeros((2, 1, 4), dtype=jnp.int32)
    signs = jnp.ones((1, 4), dtype=jnp.int32)
    scale = jnp.array([1.0], dtype=jnp.float32)
    x = jnp.ones((1, 4), dtype=jnp.float32)
    bias = jnp.zeros((1,), dtype=jnp.float32)
    got = k.spx_matvec(x, signs, planes, scale, bias, tile_m=1)
    np.testing.assert_allclose(got, 0.0)


@settings(max_examples=20, deadline=None)
@given(
    batch=st.sampled_from([1, 4, 64]),
    m=st.sampled_from([8, 128]),
    n=st.sampled_from([32, 784]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dense_matches_ref(batch, m, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.random(size=(batch, n)).astype(np.float32))
    w = jnp.array(rng.normal(size=(m, n)).astype(np.float32))
    b = jnp.array(rng.normal(size=(m,)).astype(np.float32))
    got = k.dense(x, w, b, tile_m=m)
    want = ref.dense_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_vmem_estimate_within_budget():
    """The DESIGN.md §8 target: one grid step fits in 4 MiB VMEM for the
    paper's layer sizes."""
    assert k.vmem_bytes_estimate(batch=64, n=784, tile_m=128, x_terms=2) < 4 << 20
    assert k.vmem_bytes_estimate(batch=1, n=784, tile_m=128, x_terms=2) < 4 << 20
