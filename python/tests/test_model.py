"""L2 model graphs: shapes, reference agreement, and AOT lowering."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import aot, model
from compile.kernels import ref
from compile.quant import SpxConfig, encode


def _mlp_params(seed, scale=0.1):
    rng = np.random.default_rng(seed)
    w2 = jnp.array((rng.normal(size=(128, 784)) * scale).astype(np.float32))
    b2 = jnp.array(rng.normal(size=(128,)).astype(np.float32) * scale)
    w3 = jnp.array((rng.normal(size=(10, 128)) * scale).astype(np.float32))
    b3 = jnp.array(rng.normal(size=(10,)).astype(np.float32) * scale)
    return w2, b2, w3, b3


@settings(max_examples=8, deadline=None)
@given(
    batch=st.sampled_from([1, 3, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_mlp_fp32_matches_reference(batch, seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.random(size=(batch, 784)).astype(np.float32))
    params = _mlp_params(seed)
    got = model.mlp_fp32(x, *params)
    want = ref.mlp_fp32_ref(x, *params)
    assert got.shape == (batch, 10)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # Sigmoid outputs live in (0, 1).
    assert float(got.min()) > 0.0 and float(got.max()) < 1.0


def test_mlp_spx_matches_reference():
    rng = np.random.default_rng(11)
    w2, b2, w3, b3 = _mlp_params(11)
    cfg = SpxConfig.sp2(5)
    t2 = encode(cfg, np.asarray(w2))
    t3 = encode(cfg, np.asarray(w3))
    args = (
        jnp.array(rng.random(size=(4, 784)).astype(np.float32)),
        jnp.array(t2.signs.reshape(128, 784)),
        jnp.array(t2.planes.reshape(2, 128, 784)),
        jnp.array([t2.scale], dtype=jnp.float32),
        b2,
        jnp.array(t3.signs.reshape(10, 128)),
        jnp.array(t3.planes.reshape(2, 10, 128)),
        jnp.array([t3.scale], dtype=jnp.float32),
        b3,
    )
    got = model.mlp_spx(*args)
    want = ref.mlp_spx_ref(*args)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_mlp_spx_tracks_fp32_at_moderate_bits():
    """Quantized model should be close to fp32 in output space (sigmoid
    squashes weight error); this is the accuracy-preservation premise."""
    rng = np.random.default_rng(5)
    w2, b2, w3, b3 = _mlp_params(5)
    x = jnp.array(rng.random(size=(8, 784)).astype(np.float32))
    fp = model.mlp_fp32(x, w2, b2, w3, b3)
    cfg = SpxConfig.spx(8, 2)
    t2 = encode(cfg, np.asarray(w2))
    t3 = encode(cfg, np.asarray(w3))
    q = model.mlp_spx(
        x,
        jnp.array(t2.signs.reshape(128, 784)),
        jnp.array(t2.planes.reshape(2, 128, 784)),
        jnp.array([t2.scale], dtype=jnp.float32),
        b2,
        jnp.array(t3.signs.reshape(10, 128)),
        jnp.array(t3.planes.reshape(2, 10, 128)),
        jnp.array([t3.scale], dtype=jnp.float32),
        b3,
    )
    assert float(jnp.abs(q - fp).max()) < 0.08


def test_qnet_matches_reference():
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=(1, 6)).astype(np.float32))
    params = []
    for shape in [(64, 6), (64,), (64, 64), (64,), (3, 64), (3,)]:
        params.append(jnp.array(rng.normal(size=shape).astype(np.float32) * 0.3))
    got = model.qnet_fp32(x, *params)
    want = ref.qnet_ref(x, *params)
    assert got.shape == (1, 3)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_artifact_defs_cover_all_variants():
    names = [name for name, *_ in aot.artifact_defs()]
    assert names == [
        "mlp_fp32_b1",
        "mlp_spx_b1",
        "mlp_fp32_b64",
        "mlp_spx_b64",
        "qnet_fp32_b1",
    ]


def test_lowering_produces_hlo_text():
    """Smoke the full AOT path for the smallest artifact: HLO text with
    an ENTRY computation and the right parameter count."""
    name, fn, specs, _meta = aot.artifact_defs()[0]  # mlp_fp32_b1
    import jax

    lowered = jax.jit(fn).lower(*[s for _, s in specs])
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    for i in range(len(specs)):
        assert f"parameter({i})" in text, f"missing parameter({i})"
