//! End-to-end driver (deliverable (e) of DESIGN.md): the full edge
//! serving scenario on a real small workload.
//!
//! * trains the paper's MLP on the digit dataset, logging the loss
//!   curve (recorded in EXPERIMENTS.md);
//! * starts the coordinator with all three backends — rust CPU, the
//!   cycle-accurate FPGA simulator, and the XLA/PJRT artifact;
//! * serves a Poisson request stream against each backend through the
//!   dynamic batcher;
//! * reports latency percentiles, throughput, accuracy, and (for the
//!   FPGA backend) simulated time-per-sample and power — the live
//!   version of Table I.
//!
//! ```bash
//! make artifacts && cargo run --release --example digit_serving
//! ```

use edgemlp::coordinator::backend::{Backend, CpuBackend, FnBackend, FpgaBackend};
use edgemlp::coordinator::batcher::BatchPolicy;
use edgemlp::coordinator::server::{BackendFactory, Coordinator, CoordinatorConfig};
use edgemlp::data::batch::SampleStream;
use edgemlp::data::load_digits;
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::fpga::power::PlatformPower;
use edgemlp::nn::metrics::accuracy;
use edgemlp::nn::mlp::{argmax, Mlp, MlpConfig};
use edgemlp::nn::train::{train, TrainConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::Calibration;
use edgemlp::runtime::executable::mlp_fp32_inputs;
use edgemlp::runtime::{Registry, Runtime};
use edgemlp::util::rng::Pcg32;
use std::path::Path;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    // ---- 1. Train (loss curve logged). ----
    let (train_set, test_set) = load_digits(4000, 800, 2021);
    println!("## training ({} samples, {})", train_set.len(), train_set.source);
    let mut rng = Pcg32::new(42);
    let mut mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let log = train(
        &mut mlp,
        &train_set.inputs,
        &train_set.labels,
        &TrainConfig { epochs: 6, ..Default::default() },
    );
    for s in &log {
        println!("epoch {:>2}  loss {:.4}  train-acc {:.3}", s.epoch, s.loss, s.train_accuracy);
    }
    let fp32_acc = accuracy(&mlp, &test_set.inputs, &test_set.labels);
    println!("fp32 test accuracy: {fp32_acc:.3}\n");

    // ---- 2. Coordinator with three backends. ----
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let cpu_mlp = mlp.clone();
    let cpu_factory: BackendFactory =
        Box::new(move || Ok(Box::new(CpuBackend::new(cpu_mlp)) as Box<dyn Backend>));

    let q = QuantizedMlp::from_mlp(
        &mlp,
        &SpxConfig::sp2(5),
        Calibration::MaxAbs,
        Some(&train_set.inputs),
    );
    let q_for_fpga = q.clone();
    let fpga_factory: BackendFactory = Box::new(move || {
        Ok(Box::new(FpgaBackend::new(Accelerator::new(
            q_for_fpga,
            AccelConfig::default_fpga(),
        ))) as Box<dyn Backend>)
    });

    let xla_mlp = mlp.clone();
    let xla_factory: BackendFactory = Box::new(move || {
        let rt = Runtime::new(Registry::open(&artifacts)?)?;
        let model = rt.load("mlp_fp32_b1")?;
        Ok(Box::new(FnBackend::new("xla", 1, move |inputs: &[Vec<f32>]| {
            let _keep_alive = &rt;
            inputs.iter().map(|x| model.run(&mlp_fp32_inputs(&xla_mlp, x))).collect()
        })) as Box<dyn Backend>)
    });

    let coord = Coordinator::start(
        vec![
            ("cpu".into(), cpu_factory),
            ("fpga".into(), fpga_factory),
            ("xla".into(), xla_factory),
        ],
        CoordinatorConfig {
            queue_capacity: 512,
            policy: BatchPolicy::windowed(64, Duration::from_millis(2)),
        },
    )?;

    // ---- 3. Poisson load against each backend. ----
    let n_requests = 400usize;
    let rate_rps = 600.0f64;
    println!("## serving {n_requests} requests at {rate_rps} rps per backend\n");
    let platform = PlatformPower::paper_measured();
    for backend in ["cpu", "fpga", "xla"] {
        let idx = coord.backend_index(backend).unwrap();
        let mut stream = SampleStream::new(&test_set, 5);
        let mut load_rng = Pcg32::new(99);
        let mut expected = Vec::with_capacity(n_requests);
        let mut receivers = Vec::with_capacity(n_requests);
        let t0 = Instant::now();
        let mut next_arrival = 0.0f64;
        let mut shed = 0u64;
        for _ in 0..n_requests {
            let u: f64 = load_rng.uniform().max(1e-12);
            next_arrival += -u.ln() / rate_rps;
            let wait = next_arrival - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait));
            }
            let (payload, label) = stream.next_sample();
            match coord.try_submit_to(idx, payload) {
                Ok(rx) => {
                    receivers.push(rx);
                    expected.push(label);
                }
                Err(_) => shed += 1,
            }
        }
        let mut latencies = Vec::new();
        let mut correct = 0usize;
        for (rx, label) in receivers.into_iter().zip(&expected) {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
            latencies.push(resp.latency_s);
            if argmax(&resp.output) == *label {
                correct += 1;
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let snap = coord.metrics().snapshot();
        let m = &snap.backends[backend];
        println!("backend {backend}:");
        println!("  served {} ({} shed), {:.0} req/s", latencies.len(), shed, latencies.len() as f64 / elapsed);
        println!(
            "  latency p50 {:.2} ms  p99 {:.2} ms  mean batch {:.1}",
            edgemlp::util::percentile(&latencies, 50.0) * 1e3,
            edgemlp::util::percentile(&latencies, 99.0) * 1e3,
            m.mean_batch()
        );
        println!("  accuracy {:.3}", correct as f64 / latencies.len() as f64);
        match backend {
            "fpga" => {
                let accel = Accelerator::new(q.clone(), AccelConfig::default_fpga());
                let cs = &m.cycle_stats;
                let sim_time = accel.config.pipeline.clocks.cycles_to_seconds(cs.compute_cycles);
                println!(
                    "  simulated device: {:.2} µs/sample at {} MHz, {:.1} W (activity model)",
                    sim_time / latencies.len() as f64 * 1e6,
                    accel.config.pipeline.clocks.clk_compute_mhz,
                    accel.config.energy.average_power_w(cs, sim_time)
                );
            }
            "cpu" => println!("  platform power (paper-measured constant): {:.1} W", platform.cpu_w),
            _ => println!("  platform power (paper-measured constant): {:.1} W", platform.gpu_w),
        }
        println!();
    }
    coord.shutdown();
    println!("digit_serving OK");
    Ok(())
}
