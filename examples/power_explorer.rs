//! Design-space exploration: sweep SPx configurations (bit budget ×
//! term count) and microarchitectures (PU count, clocks) on the
//! cycle-accurate simulator, reporting the accuracy / latency / power
//! frontier — the codesign loop an FPGA team would actually run before
//! committing RTL.
//!
//! ```bash
//! cargo run --release --example power_explorer
//! ```

use edgemlp::bench_harness::Table;
use edgemlp::data::load_digits;
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::fpga::clock::ClockConfig;
use edgemlp::fpga::pipeline::PipelineConfig;
use edgemlp::fpga::stats::CycleStats;
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::nn::train::{train, TrainConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::Calibration;
use edgemlp::util::rng::Pcg32;

fn main() {
    // Shared trained model.
    let (train_set, test_set) = load_digits(3000, 400, 2021);
    let mut rng = Pcg32::new(42);
    let mut mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let _ = train(
        &mut mlp,
        &train_set.inputs,
        &train_set.labels,
        &TrainConfig { epochs: 5, ..Default::default() },
    );

    let n_eval = 120usize;

    // ---- Sweep 1: quantization configs at the default microarch. ----
    println!("## SPx design space (accuracy vs energy, default microarchitecture)\n");
    let mut t = Table::new(&[
        "scheme",
        "bits",
        "x",
        "accuracy",
        "µs/sample",
        "power (W)",
        "µJ/inference",
        "weight KiB",
    ]);
    let mut configs: Vec<(String, SpxConfig)> = Vec::new();
    for bits in [3u32, 4, 5, 6, 8] {
        configs.push((format!("sp2(b={bits})"), SpxConfig::sp2(bits.max(3))));
    }
    configs.push(("spx(b=6,x=3)".into(), SpxConfig::spx(6, 3)));
    configs.push(("spx(b=8,x=3)".into(), SpxConfig::spx(8, 3)));
    for (name, spx) in configs {
        let q = QuantizedMlp::from_mlp(&mlp, &spx, Calibration::MaxAbs, None);
        let weight_kib = q.weight_bits() as f64 / 8.0 / 1024.0;
        let accel = Accelerator::new(q, AccelConfig::default_fpga());
        let (acc, stats) = evaluate(&accel, &test_set, n_eval);
        let time = accel.seconds_per_inference(&stats) / n_eval as f64;
        let power = accel.power_w(&stats);
        let energy_uj =
            accel.config.energy.total_energy_j(&stats, time * n_eval as f64) / n_eval as f64 * 1e6;
        t.row(&[
            name,
            spx.total_bits().to_string(),
            spx.num_terms().to_string(),
            format!("{acc:.3}"),
            format!("{:.2}", time * 1e6),
            format!("{power:.1}"),
            format!("{energy_uj:.1}"),
            format!("{weight_kib:.0}"),
        ]);
    }
    t.print();

    // ---- Sweep 2: microarchitecture at fixed SP2(b=5). ----
    println!("\n## Microarchitecture sweep at SP2(b=5)\n");
    let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
    let mut t = Table::new(&["PUs", "clk MHz", "bw words", "µs/sample", "stall %", "power (W)"]);
    for (pus, clk, bw) in [
        (32usize, 100.0f64, 64u32),
        (64, 100.0, 128),
        (128, 150.0, 256),
        (128, 200.0, 256),
        (256, 150.0, 512),
    ] {
        let config = AccelConfig {
            pipeline: PipelineConfig {
                clocks: ClockConfig {
                    clk_inbuff_mhz: clk / 2.0,
                    clk_compute_mhz: clk,
                    bandwidth_words: bw,
                },
                num_pus: pus,
                buffer_capacity_rows: 32,
                pipeline_depth: 3,
                lanes: 8,
                weight_resident: true,
            },
            energy: edgemlp::fpga::power::EnergyModel::default_fpga(),
        };
        let accel = Accelerator::new(q.clone(), config);
        let (_, stats) = evaluate(&accel, &test_set, n_eval);
        let time = accel.seconds_per_inference(&stats) / n_eval as f64;
        t.row(&[
            pus.to_string(),
            format!("{clk:.0}"),
            bw.to_string(),
            format!("{:.2}", time * 1e6),
            format!("{:.1}", 100.0 * stats.stall_fraction()),
            format!("{:.1}", accel.power_w(&stats)),
        ]);
    }
    t.print();
    println!("\npower_explorer OK");
}

fn evaluate(
    accel: &Accelerator,
    test_set: &edgemlp::data::Dataset,
    n: usize,
) -> (f64, CycleStats) {
    let mut stats = CycleStats::default();
    let mut correct = 0usize;
    for i in 0..n.min(test_set.len()) {
        let (pred, s) = accel.classify_one(test_set.inputs.row(i));
        stats.merge(&s);
        if pred == test_set.labels[i] {
            correct += 1;
        }
    }
    (correct as f64 / n as f64, stats)
}
