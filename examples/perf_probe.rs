//! Host-side performance probe used by the §Perf pass: wallclock
//! throughput of the FPGA simulator and the rust CPU forward.
//! `cargo run --release --example perf_probe`

use edgemlp::data::load_digits;
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::Calibration;
use edgemlp::util::rng::Pcg32;
use std::time::Instant;
fn main() {
    let (_, test) = load_digits(64, 200, 2021);
    let mut rng = Pcg32::new(42);
    let mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    // 1. FPGA simulator host throughput
    let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
    let accel = Accelerator::new(q, AccelConfig::default_fpga());
    for i in 0..5 { let _ = accel.infer_one(test.inputs.row(i)); }
    let t0 = Instant::now();
    let n = 200;
    for i in 0..n { std::hint::black_box(accel.infer_one(test.inputs.row(i % test.len()))); }
    let dt = t0.elapsed().as_secs_f64();
    println!("fpga-sim: {:.1} samples/s host ({:.3} ms/sample)", n as f64 / dt, dt / n as f64 * 1e3);
    // 1b. batched SPx shift-add kernel (weight-stationary) at batch 64
    let xb = edgemlp::data::batch::gather(&test.inputs, &(0..64).collect::<Vec<_>>());
    for _ in 0..3 { let _ = accel.forward_batch(&xb); }
    let t0 = Instant::now();
    let bit = 50;
    for _ in 0..bit { std::hint::black_box(accel.forward_batch(&xb)); }
    let dt = t0.elapsed().as_secs_f64();
    println!("spx batch64: {:.1} samples/s host ({:.3} ms/batch)", bit as f64 * 64.0 / dt, dt / bit as f64 * 1e3);
    // 2. CPU batched forward (blocked GEMM through reusable scratch)
    let mut scratch = edgemlp::nn::mlp::ForwardScratch::new();
    let t0 = Instant::now();
    let iters = 200;
    for _ in 0..iters { std::hint::black_box(mlp.forward_with(&xb, &mut scratch).data[0]); }
    let dt = t0.elapsed().as_secs_f64();
    println!("cpu fwd b64: {:.3} ms/batch = {:.2} us/sample", dt / iters as f64 * 1e3, dt / iters as f64 / 64.0 * 1e6);
    // 3. single-sample cpu
    let t0 = Instant::now();
    for i in 0..1000 { std::hint::black_box(mlp.forward_one(test.inputs.row(i % test.len()))); }
    let dt = t0.elapsed().as_secs_f64();
    println!("cpu fwd b1: {:.2} us/sample", dt / 1000.0 * 1e6);
}
