//! Quickstart: the minimal end-to-end path through the three layers.
//!
//! 1. Train the paper's 784-128-10 MLP on the digit dataset (pure rust).
//! 2. Quantize it with SP2 (Eq 3.3) — the paper's non-uniform scheme.
//! 3. Run the same sample through all three inference backends:
//!    rust CPU, the cycle-accurate FPGA simulator, and the AOT-compiled
//!    XLA artifact loaded via PJRT (no python at runtime).
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use edgemlp::data::load_digits;
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::nn::mlp::{argmax, Mlp, MlpConfig};
use edgemlp::nn::train::{train, TrainConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::Calibration;
use edgemlp::runtime::executable::mlp_fp32_inputs;
use edgemlp::runtime::Runtime;
use edgemlp::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    // 1. Data + training (B=64, η=0.5, MSE — the paper's §4.1 recipe).
    let (train_set, test_set) = load_digits(2000, 200, 2021);
    println!("dataset: {} train / {} test ({})", train_set.len(), test_set.len(), train_set.source);
    let mut rng = Pcg32::new(42);
    let mut mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let log = train(
        &mut mlp,
        &train_set.inputs,
        &train_set.labels,
        &TrainConfig { epochs: 4, ..Default::default() },
    );
    println!("final train loss {:.4}", log.last().unwrap().loss);

    // 2. SP2 quantization at b=5 (1 sign + 2+2 exponent bits).
    let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
    println!(
        "quantized: {} bits/weight vs 32 ({}x compression)",
        5,
        32 / 5
    );

    // 3a. CPU backend.
    let x = test_set.inputs.row(0);
    let label = test_set.labels[0];
    let cpu_pred = mlp.classify_one(x);

    // 3b. FPGA simulator backend.
    let accel = Accelerator::new(q, AccelConfig::default_fpga());
    let (fpga_pred, stats) = accel.classify_one(x);
    println!(
        "fpga sim: {} cycles = {:.2} µs at {} MHz, {:.1} W average",
        stats.compute_cycles,
        accel.seconds_per_inference(&stats) * 1e6,
        accel.config.pipeline.clocks.clk_compute_mhz,
        accel.power_w(&stats),
    );

    // 3c. XLA/PJRT backend (AOT artifact; python was only used at build
    // time by `make artifacts`).
    let rt = Runtime::new_default()?;
    let model = rt.load("mlp_fp32_b1")?;
    let out = model.run(&mlp_fp32_inputs(&mlp, x))?;
    let xla_pred = argmax(&out);

    println!("\nsample label = {label}");
    println!("  cpu  backend → {cpu_pred}");
    println!("  fpga backend → {fpga_pred}");
    println!("  xla  backend → {xla_pred}");
    anyhow::ensure!(cpu_pred == xla_pred, "cpu and xla must agree exactly");
    println!("\nquickstart OK");
    Ok(())
}
