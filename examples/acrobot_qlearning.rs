//! E5 — the paper's §4.2 reinforcement-learning experiment: Q-learning
//! on Acrobot-v1 with an MLP Q-function, then a comparison of the
//! greedy policy under three inference paths:
//!
//! * the fp32 rust network,
//! * the SPx-quantized network on the FPGA simulator's decoded path,
//! * the fp32 network through the XLA/PJRT `qnet_fp32_b1` artifact.
//!
//! ```bash
//! make artifacts && cargo run --release --example acrobot_qlearning -- 60
//! ```
//! (optional first arg = training episodes, default 80)

use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::Calibration;
use edgemlp::rl::qlearn::{evaluate_policy, QLearnConfig, QLearner};
use edgemlp::rl::Acrobot;
use edgemlp::runtime::executable::qnet_inputs;
use edgemlp::runtime::{Registry, Runtime};
use edgemlp::util::mean;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(80);

    // ---- Train. ----
    let mut env = Acrobot::new();
    let mut learner = QLearner::new(&env, QLearnConfig { episodes, ..Default::default() });
    println!("training Q-learning on Acrobot-v1 ({episodes} episodes)...");
    let stats = learner.train(&mut env);
    for chunk in stats.chunks(10) {
        let mean_ret: f64 =
            chunk.iter().map(|s| s.return_sum as f64).sum::<f64>() / chunk.len() as f64;
        println!(
            "  episodes {:>3}-{:>3}: mean return {:>7.1}  ε={:.2}",
            chunk[0].episode,
            chunk.last().unwrap().episode,
            mean_ret,
            chunk.last().unwrap().epsilon
        );
    }
    let early: f64 = stats[..10.min(stats.len())]
        .iter()
        .map(|s| s.return_sum as f64)
        .sum::<f64>()
        / 10.0f64.min(stats.len() as f64);
    let late: f64 = stats[stats.len().saturating_sub(10)..]
        .iter()
        .map(|s| s.return_sum as f64)
        .sum::<f64>()
        / 10.0f64.min(stats.len() as f64);
    println!("learning progress: first-10 mean {early:.1} → last-10 mean {late:.1}");

    // ---- Evaluate the greedy policy through each inference path. ----
    let eval_eps = 10;
    let qnet = learner.qnet.clone();

    let mut fp32_q = |obs: &[f32]| qnet.forward_one(obs);
    let fp32 = evaluate_policy(&mut env, &mut fp32_q, eval_eps, 123);

    let quant =
        QuantizedMlp::from_mlp(&learner.qnet, &SpxConfig::spx(8, 2), Calibration::MaxAbs, None);
    let accel = Accelerator::new(quant, AccelConfig::default_fpga());
    let mut spx_q = |obs: &[f32]| accel.forward_decoded(obs);
    let spx = evaluate_policy(&mut env, &mut spx_q, eval_eps, 123);

    let to64 = |v: &[f32]| v.iter().map(|&x| x as f64).collect::<Vec<_>>();
    println!("\ngreedy-policy mean return over {eval_eps} episodes:");
    println!("  fp32 rust:        {:>8.1}", mean(&to64(&fp32)));
    println!("  SPx(b=8,x=2) sim: {:>8.1}", mean(&to64(&spx)));

    // XLA path (optional — needs artifacts).
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let rt = Runtime::new(Registry::open(&artifacts)?)?;
        let model = rt.load("qnet_fp32_b1")?;
        let qnet2 = learner.qnet.clone();
        let mut xla_q =
            |obs: &[f32]| model.run(&qnet_inputs(&qnet2, obs)).expect("xla qnet run");
        let xla = evaluate_policy(&mut env, &mut xla_q, eval_eps, 123);
        println!("  fp32 via XLA:     {:>8.1}", mean(&to64(&xla)));
        // fp32 rust and fp32-via-XLA compute the same function, so the
        // greedy trajectories — and returns — must match exactly.
        assert_eq!(fp32, xla, "fp32 rust and XLA policies diverged");
    } else {
        println!("  (XLA path skipped — run `make artifacts`)");
    }

    println!("\nacrobot_qlearning OK");
    Ok(())
}
