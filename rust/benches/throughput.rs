//! Bench: coordinator serving throughput/latency under Poisson load —
//! the edge-deployment scenario. `cargo bench --bench throughput`.

use edgemlp::experiments::common::ExperimentScale;
use edgemlp::experiments::throughput;

fn main() {
    let scale = ExperimentScale::from_env();
    match throughput::run(scale) {
        Ok(rows) => {
            println!("\n=== Serving throughput/latency (coordinator, Poisson load) ===\n");
            println!("{}", throughput::render(&rows));
        }
        Err(e) => {
            eprintln!("throughput bench failed: {e:#}");
            std::process::exit(1);
        }
    }
}
