//! Bench: the blocked GEMM vs the seed single-pass baseline at the
//! Table-I layer shapes, the batched SPx serving kernel vs the
//! per-sample stream path, and the E9 SIMD-dispatch/worker-pool matrix
//! (forced-scalar vs native, one thread vs the persistent pool). Emits
//! `BENCH_gemm.json` (override the path with `EDGEMLP_BENCH_JSON`) so
//! future PRs have a perf trajectory — compare against the committed
//! repo-root baseline with `tools/bench_delta.py`. `cargo bench
//! --bench gemm` — see EXPERIMENTS.md §Perf and §Perf gains.

use edgemlp::bench_harness::{bench, fmt_time, BenchConfig, BenchJson, HostFingerprint, Table};
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::nn::kernels::{
    gemm::configured_threads, gemm_into_with, simd, vsq_matmul_batch, DispatchPath,
};
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::nn::tensor::Matrix;
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::vsq::{quantize_data_i8_into, VsqTensor};
use edgemlp::quant::Calibration;
use edgemlp::serve::{ModelRegistry, Precision};
use edgemlp::util::rng::Pcg32;
use std::hint::black_box;
use std::path::Path;

fn gflops(m: usize, k: usize, n: usize, secs: f64) -> f64 {
    if secs > 0.0 {
        2.0 * m as f64 * k as f64 * n as f64 / secs / 1e9
    } else {
        f64::INFINITY
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rng = Pcg32::new(7);
    let mut json = BenchJson::new();
    let mut table = Table::new(&["kernel", "shape", "mean", "GFLOP/s", "vs seed"]);

    // The forward pass computes A·Bᵀ with A = batch×in activations and
    // B = out×in weights; these are the shapes Table I exercises.
    // (m, k, n) = (batch, fan_in, fan_out).
    for &(m, k, n) in &[(256usize, 784usize, 128usize), (64, 784, 128), (64, 128, 10)] {
        let a = Matrix::random_uniform(m, k, 1.0, &mut rng);
        let b = Matrix::random_uniform(n, k, 1.0, &mut rng);
        let shape = format!("{m}x{k}.{k}x{n}");
        let blocked = bench(&format!("gemm {shape}"), cfg, || a.matmul_bt(&b));
        let seed = bench(&format!("seed {shape}"), cfg, || a.matmul_bt_unblocked(&b));
        let (gb, gs) = (gflops(m, k, n, blocked.mean_s()), gflops(m, k, n, seed.mean_s()));
        let speedup = seed.mean_s() / blocked.mean_s();
        table.row(&[
            "blocked gemm".into(),
            shape.clone(),
            fmt_time(blocked.mean_s()),
            format!("{gb:.2}"),
            format!("{speedup:.2}x"),
        ]);
        table.row(&[
            "seed matmul_bt".into(),
            shape.clone(),
            fmt_time(seed.mean_s()),
            format!("{gs:.2}"),
            "1.00x".into(),
        ]);
        json.num(&format!("gemm_bt_{shape}_gflops"), gb);
        json.num(&format!("seed_bt_{shape}_gflops"), gs);
        json.num(&format!("gemm_bt_{shape}_speedup"), speedup);
    }

    // Batched SPx serving kernel at the paper's network, batch 64.
    let mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
    let accel = Accelerator::new(q, AccelConfig::default_fpga());
    let x = Matrix::random_uniform(64, 784, 0.5, &mut rng);
    let batched = bench("spx forward_batch b64", cfg, || accel.forward_batch(&x));
    let streamed = bench("spx infer_one x64", cfg, || {
        for r in 0..x.rows {
            black_box(accel.infer_one(x.row(r)));
        }
    });
    let batch_sps = 64.0 / batched.mean_s();
    let stream_sps = 64.0 / streamed.mean_s();
    table.row(&[
        "spx batch64".into(),
        "784-128-10".into(),
        fmt_time(batched.mean_s()),
        format!("{batch_sps:.0}/s"),
        format!("{:.2}x", batch_sps / stream_sps),
    ]);
    table.row(&[
        "spx per-sample".into(),
        "784-128-10".into(),
        fmt_time(streamed.mean_s()),
        format!("{stream_sps:.0}/s"),
        "1.00x".into(),
    ]);
    json.num("spx_batch64_samples_per_s", batch_sps);
    json.num("spx_per_sample_samples_per_s", stream_sps);
    json.num("spx_batch_speedup", batch_sps / stream_sps);

    // ---- E9: SIMD dispatch + persistent worker pool (§Perf gains). ----
    // Forced-scalar vs the native path at one thread isolates the SIMD
    // micro-kernel win (acceptance: ≥ 2× at 256³ on AVX2/NEON hosts);
    // the pooled row adds the persistent worker pool at the default
    // thread cap — the serving path's configuration.
    let native = simd::native_path();
    // The same cap gemm_into runs under (EDGEMLP_GEMM_THREADS-aware),
    // so the recorded pool numbers describe the real serving config.
    let pool_threads = configured_threads();
    json.text("gemm_dispatch_path", native.name());
    json.num("gemm_pool_threads", pool_threads as f64);
    let mut e9 = Table::new(&["kernel", "shape", "mean", "GFLOP/s", "vs scalar 1t"]);
    for &(m, k, n) in &[(256usize, 256usize, 256usize), (256, 784, 128), (64, 784, 128)] {
        let a = Matrix::random_uniform(m, k, 1.0, &mut rng);
        let b = Matrix::random_uniform(n, k, 1.0, &mut rng);
        let mut out = Matrix::zeros(m, n);
        let label = format!("{m}x{k}x{n}");
        let scalar_1t = bench(&format!("scalar 1t {label}"), cfg, || {
            gemm_into_with(DispatchPath::Scalar, 1, &mut out, &a, false, &b, true)
        });
        let simd_1t = bench(&format!("simd 1t {label}"), cfg, || {
            gemm_into_with(native, 1, &mut out, &a, false, &b, true)
        });
        let simd_pool = bench(&format!("simd pool {label}"), cfg, || {
            gemm_into_with(native, pool_threads, &mut out, &a, false, &b, true)
        });
        let rows: [(&str, &edgemlp::bench_harness::Timing); 3] = [
            ("gemm scalar 1t", &scalar_1t),
            ("gemm simd 1t", &simd_1t),
            ("gemm simd pool", &simd_pool),
        ];
        for (name, t) in rows {
            e9.row(&[
                name.into(),
                label.clone(),
                fmt_time(t.mean_s()),
                format!("{:.2}", gflops(m, k, n, t.mean_s())),
                format!("{:.2}x", scalar_1t.mean_s() / t.mean_s()),
            ]);
        }
        json.num(&format!("gemm_scalar_{label}_gflops"), gflops(m, k, n, scalar_1t.mean_s()));
        json.num(&format!("gemm_simd_{label}_gflops"), gflops(m, k, n, simd_1t.mean_s()));
        json.num(&format!("gemm_simd_{label}_speedup"), scalar_1t.mean_s() / simd_1t.mean_s());
        json.num(
            &format!("gemm_simd_pool_{label}_gflops"),
            gflops(m, k, n, simd_pool.mean_s()),
        );
        json.num(
            &format!("gemm_simd_pool_{label}_speedup"),
            simd_1t.mean_s() / simd_pool.mean_s(),
        );
    }

    println!("\n=== GEMM + batched-SPx kernel bench (EXPERIMENTS.md §Perf) ===\n");
    table.print();
    println!(
        "\n=== E9: SIMD dispatch ({} on this host) + worker pool ({} threads) ===\n",
        native.name(),
        pool_threads
    );
    e9.print();

    // ---- VSQ int8/int4 integer kernels vs the f32 SIMD GEMM. ----
    // Same serving shapes as E9 ((m,k,n) = (batch, fan_in, fan_out)),
    // single thread, both sides on the native dispatch path: the f32
    // row is `gemm_into_with(native, 1, ..)` and the integer rows are
    // the weight-stationary `vsq_matmul_batch` (docs/quantization-modes.md).
    // GFLOP/s counts the same 2·m·k·n useful MACs for every row, so the
    // column is directly comparable across precisions.
    let mut vsq_table = Table::new(&["kernel", "shape", "mean", "GFLOP/s", "vs f32 simd"]);
    for &(m, k, n) in &[(256usize, 784usize, 128usize), (64, 784, 128)] {
        let a = Matrix::random_uniform(m, k, 1.0, &mut rng);
        let b = Matrix::random_uniform(n, k, 0.1, &mut rng);
        let mut out = Matrix::zeros(m, n);
        let label = format!("{m}x{k}x{n}");
        let f32_1t = bench(&format!("f32 simd 1t {label}"), cfg, || {
            gemm_into_with(native, 1, &mut out, &a, false, &b, true)
        });
        vsq_table.row(&[
            "gemm f32 simd 1t".into(),
            label.clone(),
            fmt_time(f32_1t.mean_s()),
            format!("{:.2}", gflops(m, k, n, f32_1t.mean_s())),
            "1.00x".into(),
        ]);
        let mut x_q = Vec::new();
        quantize_data_i8_into(&a.data, 1.0, &mut x_q);
        let mut iout = vec![0.0f32; m * n];
        for bits in [8u8, 4] {
            let w = VsqTensor::encode(bits, 16, &b.data, n, k, Calibration::MaxAbs);
            let timing = bench(&format!("vsq i{bits} {label}"), cfg, || {
                vsq_matmul_batch(&w, &x_q, m, 1.0, &mut iout)
            });
            let speedup = f32_1t.mean_s() / timing.mean_s();
            vsq_table.row(&[
                format!("vsq int{bits}"),
                label.clone(),
                fmt_time(timing.mean_s()),
                format!("{:.2}", gflops(m, k, n, timing.mean_s())),
                format!("{speedup:.2}x"),
            ]);
            json.num(&format!("gemm_i{bits}_{label}_gflops"), gflops(m, k, n, timing.mean_s()));
            json.num(&format!("gemm_i{bits}_{label}_speedup"), speedup);
        }
    }

    // Weight footprint per served sample at each precision for the
    // paper's MNIST network — lower-better keys (`bytes_per_sample`)
    // so the delta gate flags any regression in model-streaming bytes.
    let registry = ModelRegistry::new("default", mlp.clone(), SpxConfig::sp2(5));
    let active = registry.slots()[0].active();
    for (precision, key) in [
        (Precision::F32, "f32_bytes_per_sample"),
        (Precision::Spx, "spx_bytes_per_sample"),
        (Precision::Int8, "int8_bytes_per_sample"),
        (Precision::Int4, "int4_bytes_per_sample"),
    ] {
        json.num(key, active.weight_bytes(precision) as f64);
    }

    println!("\n=== VSQ int8/int4 kernels vs f32 SIMD (docs/quantization-modes.md) ===\n");
    vsq_table.print();

    HostFingerprint::detect().stamp(&mut json);
    let path = std::env::var("EDGEMLP_BENCH_JSON").unwrap_or_else(|_| "BENCH_gemm.json".into());
    json.write(Path::new(&path)).expect("write bench json");
    println!("\nwrote {path}");
}
