//! Bench: §3.1's dataflow claims — pipelined vs serialized, dual-clock
//! decoupling, buffer sizing, PU scaling.
//! `cargo bench --bench pipeline_ablation`.

use edgemlp::experiments::pipeline_ablation;

fn main() {
    let a = pipeline_ablation::run();
    println!("\n=== Pipeline ablation (§3.1, Fig 1/2) ===\n");
    println!("{}", pipeline_ablation::render(&a));
}
