//! Bench: §3.2's quantization claims — uniform vs PoT vs SP2 vs SPx
//! across bit budgets. `cargo bench --bench quant_ablation`.

use edgemlp::experiments::common::ExperimentScale;
use edgemlp::experiments::quant_ablation;

fn main() {
    let scale = ExperimentScale::from_env();
    let bits = if std::env::var("EDGEMLP_BENCH_QUICK").is_ok() {
        vec![4u32, 5]
    } else {
        vec![3u32, 4, 5, 6, 8]
    };
    let fp32 = quant_ablation::fp32_accuracy(scale);
    let rows = quant_ablation::run(scale, &bits);
    println!("\n=== Quantization ablation (§3.2) ===\n");
    println!("{}", quant_ablation::render(&rows, fp32));

    // Serving-precision modes: the actual f32/SPx/int8/int4 datapaths
    // end to end (EXPERIMENTS.md §Quantized serving).
    let (pfp32, prows) = quant_ablation::run_precision_modes(scale);
    println!("\n=== Accuracy vs serving precision ===\n");
    println!("{}", quant_ablation::render_precision_modes(pfp32, &prows));
}
