//! Bench: regenerate the paper's Table I (time per sample + power for
//! CPU / GPU-stand-in / FPGA-sim). `cargo bench --bench table1`.

use edgemlp::experiments::common::ExperimentScale;
use edgemlp::experiments::table1;

fn main() {
    let scale = ExperimentScale::from_env();
    let with_xla = edgemlp::runtime::Registry::open_default().is_ok();
    if !with_xla {
        eprintln!("note: artifacts not built — GPU/XLA row skipped (run `make artifacts`)");
    }
    match table1::run(scale, with_xla) {
        Ok(t) => {
            println!("\n=== Table I — CPU vs GPU vs FPGA, digit recognition ===\n");
            println!("{}", table1::render(&t));
            println!(
                "paper shape check: FPGA fastest ({}), FPGA lowest power ({})",
                t.rows.iter().all(|r| t.rows.last().unwrap().time_per_sample_s
                    <= r.time_per_sample_s),
                t.rows.iter().all(|r| t.rows.last().unwrap().power_w <= r.power_w),
            );
        }
        Err(e) => {
            eprintln!("table1 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
