//! Bench: regenerate the paper's Figure 5 (inference time per sample
//! across training epochs). `cargo bench --bench fig5`.

use edgemlp::experiments::common::ExperimentScale;
use edgemlp::experiments::fig5;

fn main() {
    let scale = ExperimentScale::from_env();
    let points = fig5::run(scale);
    println!("\n=== Figure 5 — per-epoch inference time per sample (CPU) ===\n");
    println!("{}", fig5::render(&points));
    println!(
        "flatness: CV of the time series = {:.3} (paper's figure is a flat line)",
        fig5::flatness(&points)
    );
}
