//! Bench: end-to-end TCP serving throughput/latency of the network
//! subsystem (wire protocol → connection pool → coordinator batching →
//! CPU/FPGA-sim backends). Emits `BENCH_serving.json` (override the
//! path with `EDGEMLP_BENCH_JSON`) alongside `BENCH_gemm.json` for the
//! perf trajectory. `cargo bench --bench serving` — see EXPERIMENTS.md
//! §Serving.

use edgemlp::bench_harness::{fmt_time, BenchJson, Table};
use edgemlp::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use edgemlp::fpga::accelerator::AccelConfig;
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::serve::{
    run_loadgen, swappable_cpu_factory, swappable_fpga_factory, LoadGenConfig, ModelRegistry,
    ServeConfig, Server,
};
use edgemlp::util::rng::Pcg32;
use std::path::Path;
use std::time::Duration;

struct Scenario {
    label: &'static str,
    backend: u32,
    connections: usize,
    batch: usize,
    pipeline: usize,
}

fn main() {
    let quick = std::env::var("EDGEMLP_BENCH_QUICK").is_ok();
    let requests = if quick { 2_000 } else { 20_000 };

    // The paper's MNIST network; weights random — serving cost is
    // weight-value independent.
    let mut rng = Pcg32::new(2021);
    let mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let registry = ModelRegistry::new("default", mlp, SpxConfig::sp2(5));
    let coord = Coordinator::start(
        vec![
            ("cpu".into(), swappable_cpu_factory(registry.clone())),
            (
                "fpga".into(),
                swappable_fpga_factory(registry.clone(), AccelConfig::default_fpga()),
            ),
        ],
        CoordinatorConfig {
            queue_capacity: 4096,
            policy: BatchPolicy::windowed(64, Duration::from_millis(1)),
        },
    )
    .expect("start coordinator");
    let server = Server::start(coord, registry, "127.0.0.1:0", ServeConfig::default())
        .expect("start server");
    let addr = server.local_addr();

    let scenarios = [
        Scenario { label: "cpu_single_c8_p8", backend: 0, connections: 8, batch: 1, pipeline: 8 },
        Scenario { label: "cpu_batch16_c4", backend: 0, connections: 4, batch: 16, pipeline: 1 },
        Scenario { label: "fpga_single_c4_p8", backend: 1, connections: 4, batch: 1, pipeline: 8 },
    ];

    let mut json = BenchJson::new();
    let mut table = Table::new(&["scenario", "requests", "req/s", "p50", "p99", "shed"]);
    for s in &scenarios {
        let report = run_loadgen(
            addr,
            LoadGenConfig {
                requests,
                connections: s.connections,
                backend: s.backend,
                dim: 784,
                batch: s.batch,
                pipeline: s.pipeline,
                ..LoadGenConfig::default()
            },
        )
        .expect("loadgen");
        assert_eq!(report.ok + report.shed + report.errors, report.sent, "lost responses");
        table.row(&[
            s.label.to_string(),
            report.sent.to_string(),
            format!("{:.0}", report.throughput_rps()),
            fmt_time(report.p50_s()),
            fmt_time(report.p99_s()),
            report.shed.to_string(),
        ]);
        json.num(&format!("serving_{}_rps", s.label), report.throughput_rps());
        json.num(&format!("serving_{}_p50_ms", s.label), report.p50_s() * 1e3);
        json.num(&format!("serving_{}_p99_ms", s.label), report.p99_s() * 1e3);
        json.num(&format!("serving_{}_shed", s.label), report.shed as f64);
    }
    server.shutdown();

    println!("\n=== TCP serving bench (EXPERIMENTS.md §Serving) ===\n");
    table.print();

    let path =
        std::env::var("EDGEMLP_BENCH_JSON").unwrap_or_else(|_| "BENCH_serving.json".into());
    json.write(Path::new(&path)).expect("write bench json");
    println!("\nwrote {path}");
}
