//! Bench: end-to-end TCP serving throughput/latency of the network
//! subsystem (wire protocol → connection pool → model routing →
//! coordinator worker pools → CPU/FPGA-sim backends), plus the E8
//! replica-scaling sweep, the E10 stage-pipelined depth sweep
//! (pipelined vs monolithic CPU at depths 1..4, single replica), and
//! the E11 SLO sweep (deadline-carrying load at 0.5×/1×/2× capacity:
//! attainment and shed-rate curves under admission control), and the
//! E13 c10k scenario (live traffic with ~10k idle connections
//! registered on the readiness event loop, plus a burst-reconnect
//! storm — docs/async-net.md), and the E14 power-budget autoscale
//! scenario (replica band under a step load, budget-gated
//! accuracy-for-power degradation — docs/autoscaling.md).
//! Emits `BENCH_serving.json` (override the
//! path with `EDGEMLP_BENCH_JSON`) alongside `BENCH_gemm.json` for the
//! perf trajectory. `cargo bench --bench serving` — see EXPERIMENTS.md
//! §Serving and §Scaling the engine.
//!
//! The whole process pins `EDGEMLP_GEMM_THREADS=1`: each replica worker
//! runs its GEMMs single-threaded, so worker-pool replication is the
//! only parallelism variable the sweep measures (intra-op threading
//! would otherwise oversubscribe the cores and mask the scaling).

use edgemlp::bench_harness::{fmt_time, BenchJson, HostFingerprint, Table};
use edgemlp::coordinator::{AutoscalePolicy, BatchPolicy, CoordinatorConfig};
use edgemlp::fpga::accelerator::AccelConfig;
use edgemlp::fpga::power::EnergyModel;
use edgemlp::nn::activations::Activation;
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::obs::pool_energy;
use edgemlp::quant::spx::SpxConfig;
use edgemlp::serve::{
    run_loadgen, run_slo_sweep, BackendKind, Client, EngineConfig, LoadGenConfig, ModelRegistry,
    ServeConfig, Server,
};
use edgemlp::util::rng::Pcg32;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

struct Scenario {
    label: &'static str,
    backend: u32,
    connections: usize,
    batch: usize,
    pipeline: usize,
}

/// The paper's MNIST network; weights random — serving cost is
/// weight-value independent.
fn registry() -> Arc<ModelRegistry> {
    let mut rng = Pcg32::new(2021);
    let mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    ModelRegistry::new("default", mlp, SpxConfig::sp2(5))
}

fn engine(replicas: usize, backends: Vec<BackendKind>) -> EngineConfig {
    EngineConfig {
        replicas,
        backends,
        coordinator: CoordinatorConfig {
            queue_capacity: 4096,
            policy: BatchPolicy::windowed(64, Duration::from_millis(1)),
        },
        serve: ServeConfig::default(),
        autoscale: None,
        power_budget_w: None,
    }
}

/// Resident set size in MiB from `/proc/self/status` (0.0 when the
/// proc filesystem is unavailable — the RSS key is simply omitted).
fn proc_rss_mb() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            if let Some(kb) = rest.split_whitespace().next().and_then(|v| v.parse::<f64>().ok()) {
                return kb / 1024.0;
            }
        }
    }
    0.0
}

fn main() {
    // Before any GEMM runs (the cap is latched on first use).
    std::env::set_var("EDGEMLP_GEMM_THREADS", "1");
    let quick = std::env::var("EDGEMLP_BENCH_QUICK").is_ok();
    let requests = if quick { 2_000 } else { 20_000 };
    let mut json = BenchJson::new();

    // ---- Fixed scenarios (labels pinned since PR 2). ----
    // The int8/int4 pools ride on the same engine: explicit backend
    // indices keep the pinned scenarios on the pools they always hit,
    // and the quantized pools add their own rows/keys.
    let server = Server::serve(
        registry(),
        "127.0.0.1:0",
        engine(
            1,
            vec![
                BackendKind::Cpu,
                BackendKind::FpgaSim(AccelConfig::default_fpga()),
                BackendKind::Int8,
                BackendKind::Int4,
            ],
        ),
    )
    .expect("start server");
    let addr = server.local_addr();

    let scenarios = [
        Scenario { label: "cpu_single_c8_p8", backend: 0, connections: 8, batch: 1, pipeline: 8 },
        Scenario { label: "cpu_batch16_c4", backend: 0, connections: 4, batch: 16, pipeline: 1 },
        Scenario { label: "fpga_single_c4_p8", backend: 1, connections: 4, batch: 1, pipeline: 8 },
        Scenario { label: "int8_single_c8_p8", backend: 2, connections: 8, batch: 1, pipeline: 8 },
        Scenario { label: "int4_single_c8_p8", backend: 3, connections: 8, batch: 1, pipeline: 8 },
    ];

    let mut table = Table::new(&["scenario", "requests", "req/s", "p50", "p99", "shed"]);
    for s in &scenarios {
        let report = run_loadgen(
            addr,
            LoadGenConfig {
                requests,
                connections: s.connections,
                backend: s.backend,
                dim: 784,
                batch: s.batch,
                pipeline: s.pipeline,
                ..LoadGenConfig::default()
            },
        )
        .expect("loadgen");
        assert_eq!(report.ok + report.shed + report.errors, report.sent, "lost responses");
        table.row(&[
            s.label.to_string(),
            report.sent.to_string(),
            format!("{:.0}", report.throughput_rps()),
            fmt_time(report.p50_s()),
            fmt_time(report.p99_s()),
            report.shed.to_string(),
        ]);
        json.num(&format!("serving_{}_rps", s.label), report.throughput_rps());
        json.num(&format!("serving_{}_p50_ms", s.label), report.p50_s() * 1e3);
        json.num(&format!("serving_{}_p99_ms", s.label), report.p99_s() * 1e3);
        json.num(&format!("serving_{}_shed", s.label), report.shed as f64);
    }

    // ---- E12: perf-per-watt — modeled energy for the SPx pool. ----
    // The same accounting the server exposes on /metrics and Stats
    // (obs::pool_energy over the pool's aggregate CycleStats); the
    // "energy" keys are lower-better for bench_delta.py.
    let snap = server.metrics().snapshot();
    if let Some(m) = snap.backends.get("fpga/default") {
        let e = pool_energy(&EnergyModel::default_fpga(), m, 1.0);
        json.num("serving_fpga_energy_mj_per_sample", e.mj_per_sample);
        json.num("serving_fpga_energy_j_per_request", e.j_per_request);
        println!(
            "\nfpga pool modeled energy: {:.4} mJ/sample, {:.6} J/request",
            e.mj_per_sample, e.j_per_request
        );
    }
    // Per-precision weight footprint the engine registered at assembly
    // (f32 on the CPU pool, SPx on the FPGA pool, VSQ on int8/int4) —
    // lower-better `bytes_per_sample` keys for the delta gate.
    for (pool, key) in [
        ("cpu/default", "serving_f32_bytes_per_sample"),
        ("fpga/default", "serving_spx_bytes_per_sample"),
        ("int8/default", "serving_int8_bytes_per_sample"),
        ("int4/default", "serving_int4_bytes_per_sample"),
    ] {
        if let Some(m) = snap.backends.get(pool) {
            json.num(key, m.bytes_per_sample as f64);
        }
    }
    server.shutdown();

    println!("\n=== TCP serving bench (EXPERIMENTS.md §Serving) ===\n");
    table.print();

    // ---- E8: replica sweep 1 → num_cpus on the CPU backend. ----
    // Powers of two up to the core count, with 4 always included so the
    // ≥4-replica acceptance point exists even on small CI machines.
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let top = cores.max(4);
    let mut sweep = vec![1usize];
    while *sweep.last().unwrap() * 2 <= top {
        sweep.push(sweep.last().unwrap() * 2);
    }
    if !sweep.contains(&top) {
        sweep.push(top);
    }
    let sweep_requests = if quick { 1_500 } else { 10_000 };
    // Warm-up keeps replica spawn + first-batch cache effects out of
    // the recorded percentiles.
    let warmup = sweep_requests / 10;

    let mut sweep_table = Table::new(&["replicas", "req/s", "p50", "p99", "vs 1 replica"]);
    let mut base_rps = 0.0f64;
    let (mut best_r, mut best_rps) = (1usize, 0.0f64);
    for &r in &sweep {
        let server = Server::serve(registry(), "127.0.0.1:0", engine(r, vec![BackendKind::Cpu]))
            .expect("start sweep server");
        let report = run_loadgen(
            server.local_addr(),
            LoadGenConfig {
                requests: sweep_requests,
                connections: 8,
                backend: 0,
                dim: 784,
                batch: 1,
                pipeline: 8,
                warmup,
                ..LoadGenConfig::default()
            },
        )
        .expect("sweep loadgen");
        server.shutdown();
        assert_eq!(report.ok + report.shed + report.errors, report.sent, "lost responses");
        let rps = report.throughput_rps();
        if r == 1 {
            base_rps = rps;
        }
        if rps > best_rps {
            best_rps = rps;
            best_r = r;
        }
        let speedup = if base_rps > 0.0 { rps / base_rps } else { 0.0 };
        sweep_table.row(&[
            r.to_string(),
            format!("{rps:.0}"),
            fmt_time(report.p50_s()),
            fmt_time(report.p99_s()),
            format!("{speedup:.2}x"),
        ]);
        json.num(&format!("serving_replicas_{r}_rps"), rps);
        json.num(&format!("serving_replicas_{r}_p50_ms"), report.p50_s() * 1e3);
        json.num(&format!("serving_replicas_{r}_p99_ms"), report.p99_s() * 1e3);
        json.num(&format!("serving_replicas_{r}_speedup"), speedup);
    }
    json.num("serving_replica_sweep_max", *sweep.last().unwrap() as f64);
    json.num("serving_replica_sweep_cores", cores as f64);
    // serving_pool_*: the engine's replicated worker pool at its best
    // operating point — the headline the perf trajectory tracks for the
    // serving path. (The GEMM worker pool is measured in
    // BENCH_gemm.json's gemm_simd_pool_* keys: this process pins
    // EDGEMLP_GEMM_THREADS=1 so replication stays the only variable.)
    json.num("serving_pool_best_replicas", best_r as f64);
    json.num("serving_pool_best_rps", best_rps);
    json.num(
        "serving_pool_speedup",
        if base_rps > 0.0 { best_rps / base_rps } else { 0.0 },
    );

    println!("\n=== E8: replica sweep, CPU backend (EXPERIMENTS.md §Scaling) ===\n");
    sweep_table.print();

    // ---- E10: stage-pipelined backend vs monolithic (depth sweep). ----
    // Single replica, EDGEMLP_GEMM_THREADS=1 process-wide: the layer
    // stages are the only parallelism, so the depth sweep isolates the
    // pipeline's contribution. Speedup is against the monolithic
    // 1-replica CPU point measured in E8 (`base_rps`) — same model,
    // same load shape, same thread budget per layer.
    let depths = [1usize, 2, 3, 4];
    let mut pipe_table = Table::new(&["depth", "req/s", "p50", "p99", "vs monolithic"]);
    for &depth in &depths {
        let server = Server::serve(
            registry(),
            "127.0.0.1:0",
            engine(1, vec![BackendKind::PipelineCpu { depth }]),
        )
        .expect("start pipeline server");
        let report = run_loadgen(
            server.local_addr(),
            LoadGenConfig {
                requests: sweep_requests,
                connections: 8,
                backend: 0,
                dim: 784,
                batch: 1,
                pipeline: 8,
                warmup,
                ..LoadGenConfig::default()
            },
        )
        .expect("pipeline loadgen");
        server.shutdown();
        assert_eq!(report.ok + report.shed + report.errors, report.sent, "lost responses");
        let rps = report.throughput_rps();
        let speedup = if base_rps > 0.0 { rps / base_rps } else { 0.0 };
        pipe_table.row(&[
            depth.to_string(),
            format!("{rps:.0}"),
            fmt_time(report.p50_s()),
            fmt_time(report.p99_s()),
            format!("{speedup:.2}x"),
        ]);
        json.num(&format!("serving_pipeline_{depth}_rps"), rps);
        json.num(&format!("serving_pipeline_{depth}_p99_ms"), report.p99_s() * 1e3);
        json.num(&format!("serving_pipeline_{depth}_speedup"), speedup);
    }
    json.num("serving_pipeline_monolithic_rps", base_rps);

    println!("\n=== E10: stage-pipelined backend, depth sweep (EXPERIMENTS.md §E10) ===\n");
    pipe_table.print();

    // ---- E11: SLO attainment & shed rate under rising offered load. ----
    // Deadline-carrying traffic against a single-replica CPU pool at
    // 0.5×/1×/2× the capacity measured in E8 (`base_rps`). Graceful
    // degradation means attainment among accepted requests holds near
    // 1.0 at every rung while admission control sheds the overload
    // (docs/serving-resilience.md) — the 2× rung is the acceptance
    // scenario, not a failure mode.
    let server = Server::serve(registry(), "127.0.0.1:0", engine(1, vec![BackendKind::Cpu]))
        .expect("start slo server");
    let slo_base_rps = base_rps.max(50.0);
    let slo_config = LoadGenConfig {
        requests: if quick { 500 } else { 4_000 },
        connections: 4,
        backend: 0,
        dim: 784,
        batch: 1,
        pipeline: 8,
        rate_rps: slo_base_rps,
        deadline_us: 50_000,
        ..LoadGenConfig::default()
    };
    let factors = [0.5, 1.0, 2.0];
    let points = run_slo_sweep(server.local_addr(), &slo_config, &factors).expect("slo sweep");
    server.shutdown();
    let mut slo_table =
        Table::new(&["rate (rps)", "sent", "ok", "shed+expired", "attainment", "p99"]);
    for (factor, p) in factors.iter().zip(&points) {
        assert_eq!(p.ok + p.shed + p.expired + p.errors, p.sent, "lost responses");
        slo_table.row(&[
            format!("{:.0}", p.rate_rps),
            p.sent.to_string(),
            p.ok.to_string(),
            (p.shed + p.expired).to_string(),
            format!("{:.1}%", p.attainment * 100.0),
            fmt_time(p.p99_s),
        ]);
        // Keys are by load factor, not absolute rate — absolute capacity
        // varies per host, the shape of the curve is what trends.
        let label = format!("{factor}x").replace('.', "_");
        json.num(&format!("serving_slo_{label}_attainment"), p.attainment);
        json.num(&format!("serving_slo_{label}_shed_rate"), p.shed_rate);
        json.num(&format!("serving_slo_{label}_p99_ms"), p.p99_s * 1e3);
    }
    json.num("serving_slo_base_rps", slo_base_rps);
    json.num("serving_slo_deadline_ms", slo_config.deadline_us as f64 / 1e3);

    println!("\n=== E11: SLO sweep, deadline 50 ms (EXPERIMENTS.md §E11) ===\n");
    slo_table.print();

    // ---- E13: c10k idle population + reconnect storm. ----
    // The readiness event loop keeps thousands of mostly-idle
    // connections registered on one thread while live traffic flows
    // through the same loop — throughput/p99 of the live lane and the
    // process RSS are the costs being tracked (docs/async-net.md).
    // The idle population is clamped to the fd limit the OS actually
    // grants: loadgen and server sockets both live in this process.
    let idle_target: usize = if quick { 1_000 } else { 10_000 };
    let fd_limit = edgemlp::serve::raise_nofile_limit(idle_target as u64 * 2 + 512);
    let idle_conns = idle_target.min((fd_limit.saturating_sub(512) / 2) as usize);
    let server = Server::serve(
        registry(),
        "127.0.0.1:0",
        EngineConfig {
            replicas: 1,
            backends: vec![BackendKind::Cpu],
            coordinator: CoordinatorConfig {
                queue_capacity: 4096,
                policy: BatchPolicy::windowed(64, Duration::from_millis(1)),
            },
            serve: ServeConfig {
                max_conns: idle_conns + 64,
                // Idle conns stall between pings for the whole run;
                // keep the slowloris reaper out of the measurement.
                read_timeout: Duration::from_secs(600),
                ..ServeConfig::default()
            },
            autoscale: None,
            power_budget_w: None,
        },
    )
    .expect("start idle server");
    let report = run_loadgen(
        server.local_addr(),
        LoadGenConfig {
            requests: sweep_requests,
            connections: 8,
            backend: 0,
            dim: 784,
            batch: 1,
            pipeline: 8,
            warmup,
            idle_conns,
            ..LoadGenConfig::default()
        },
    )
    .expect("idle loadgen");
    assert_eq!(report.ok + report.shed + report.errors, report.sent, "lost responses");
    let rss_mb = proc_rss_mb();
    println!(
        "\n=== E13: live traffic with {} idle conns registered (EXPERIMENTS.md §E13) ===\n",
        report.idle_held
    );
    println!(
        "{:.0} req/s | p99 {} | rss {:.0} MiB",
        report.throughput_rps(),
        fmt_time(report.p99_s()),
        rss_mb
    );
    json.num("serving_idle10k_conns", report.idle_held as f64);
    json.num("serving_idle10k_rps", report.throughput_rps());
    json.num("serving_idle10k_p99_ms", report.p99_s() * 1e3);
    if rss_mb > 0.0 {
        json.num("serving_idle10k_rss_mb", rss_mb);
    }

    // Burst-reconnect churn against the same engine: accept path, slab
    // slot recycling, and careful-close draining at full tilt.
    let storm_cycles = if quick { 400 } else { 4_000 };
    let storm = edgemlp::serve::run_reconnect_storm(server.local_addr(), 16, storm_cycles)
        .expect("reconnect storm");
    println!("{}", storm.render());
    json.num("serving_storm_reconnects_per_s", storm.reconnects_per_s());
    json.num("serving_storm_errors", storm.errors as f64);
    server.shutdown();

    // ---- E14: power-budget autoscale under a step load. ----
    // A slow-draining CPU pool (wide MLP, 256-deep queue) behind a
    // [1, 4] replica band: the closed-loop burst holds queue occupancy
    // above the scale-up threshold so replicas grow, and once the load
    // stops the controller walks the pool back to the floor (the settle
    // time is the recorded figure). The 1 W power budget sits below the
    // energy model's 2.5 W static floor, so the budget gate must also
    // latch accuracy-for-power degradation — int8/int4 pools are
    // present as the cheap routing target — without shedding anything.
    let wide = {
        let mut rng = Pcg32::new(2024);
        Mlp::new(
            MlpConfig {
                sizes: vec![784, 512, 256, 10],
                activations: vec![Activation::Sigmoid; 3],
            },
            &mut rng,
        )
    };
    let policy = AutoscalePolicy {
        scale_up_occupancy: 0.1,
        scale_down_occupancy: 0.02,
        dwell: Duration::from_millis(100),
        cooldown: Duration::from_millis(250),
        sample_every: Duration::from_millis(25),
        ..AutoscalePolicy::band(1, 4)
    };
    let server = Server::serve(
        ModelRegistry::new("default", wide, SpxConfig::sp2(5)),
        "127.0.0.1:0",
        EngineConfig {
            replicas: 1,
            backends: vec![BackendKind::Cpu, BackendKind::Int8, BackendKind::Int4],
            coordinator: CoordinatorConfig {
                queue_capacity: 256,
                policy: BatchPolicy::windowed(64, Duration::from_millis(1)),
            },
            serve: ServeConfig::default(),
            autoscale: Some(policy),
            power_budget_w: Some(1.0),
        },
    )
    .expect("start autoscale server");
    let burst = if quick { 4_000 } else { 20_000 };
    let report = run_loadgen(
        server.local_addr(),
        LoadGenConfig {
            requests: burst,
            connections: 16,
            backend: 0,
            dim: 784,
            batch: 1,
            pipeline: 8,
            warmup: burst / 10,
            ..LoadGenConfig::default()
        },
    )
    .expect("autoscale loadgen");
    assert_eq!(report.ok + report.shed + report.errors, report.sent, "lost responses");

    // The step back down: poll Health until the loaded pool returns to
    // the replica floor (60 s cap so a stuck controller still reports).
    let mut client = Client::connect(server.local_addr()).expect("autoscale ctl client");
    let settle_start = std::time::Instant::now();
    let (health, auto, settle_s) = loop {
        let (health, _, auto) = client.health_full().expect("health");
        let auto = auto.expect("autoscale health block");
        let at_floor = health.pools.iter().all(|p| (p.replicas as usize) <= policy.min);
        if at_floor || settle_start.elapsed() > Duration::from_secs(60) {
            break (health, auto, settle_start.elapsed().as_secs_f64());
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    let watts = auto.power_mw as f64 / 1e3;
    let shed: u64 = health.pools.iter().map(|p| p.shed).sum();
    assert!(auto.scale_ups >= 1, "burst never tripped a scale-up: {auto:?}");
    assert!(auto.power_degraded, "1 W budget under the 2.5 W static floor must degrade");
    assert_eq!(shed, 0, "degradation must precede shedding");
    println!("\n=== E14: power-budget autoscale, step load (EXPERIMENTS.md §E14) ===\n");
    println!(
        "{:.0} req/s | p99 {} | {} ups / {} downs | settle {settle_s:.1} s | \
         {watts:.2} W (budget 1.00 W) | power-degraded {}",
        report.throughput_rps(),
        fmt_time(report.p99_s()),
        auto.scale_ups,
        auto.scale_downs,
        auto.power_degraded,
    );
    json.num("serving_autoscale_rps", report.throughput_rps());
    json.num("serving_autoscale_p99_ms", report.p99_s() * 1e3);
    json.num("serving_autoscale_settle_s", settle_s);
    json.num("serving_autoscale_watts", watts);
    json.num("serving_autoscale_scale_ups", auto.scale_ups as f64);
    server.shutdown();

    HostFingerprint::detect().stamp(&mut json);
    let path =
        std::env::var("EDGEMLP_BENCH_JSON").unwrap_or_else(|_| "BENCH_serving.json".into());
    json.write(Path::new(&path)).expect("write bench json");
    println!("\nwrote {path}");
}
