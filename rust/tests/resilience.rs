//! Integration: serving resilience under hostile and overload
//! conditions — slowloris read deadlines freeing connection slots,
//! at-most-once retries over real TCP, admission-control `Expired`
//! frames, `Health` introspection, degraded-mode hysteresis, v2
//! framing against the v3 server, and an overload SLO smoke test
//! proving nothing is silently dropped.

use edgemlp::coordinator::backend::{Backend, FnBackend};
use edgemlp::coordinator::server::BackendFactory;
use edgemlp::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, DegradePolicy};
use edgemlp::nn::activations::Activation;
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::serve::wire::{self, Frame};
use edgemlp::serve::{
    run_loadgen, BackendKind, Client, EngineConfig, InferReply, LoadGenConfig, ModelRegistry,
    Opcode, Qos, RetryPolicy, RetryingClient, ServeConfig, Server, Status, BACKEND_ANY,
};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn mnist_shaped(seed: u64) -> Mlp {
    let mut rng = edgemlp::util::rng::Pcg32::new(seed);
    Mlp::new(
        MlpConfig {
            sizes: vec![784, 32, 10],
            activations: vec![Activation::Sigmoid, Activation::Sigmoid],
        },
        &mut rng,
    )
}

fn probe() -> Vec<f32> {
    vec![0.37f32; 784]
}

/// Echo server with one deliberately slow single-replica pool: every
/// request takes `service_ms`, so queue depth — and with it admission
/// control, expiry, shedding, and degraded mode — is test-controlled.
fn slow_echo_server(service_ms: u64, queue_capacity: usize, config: ServeConfig) -> Server {
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    let slow: BackendFactory = Box::new(move || {
        Ok(Box::new(FnBackend::new("slow", 1, move |inputs: &[Vec<f32>]| {
            std::thread::sleep(Duration::from_millis(service_ms));
            Ok(inputs.to_vec())
        })) as Box<dyn Backend>)
    });
    let coord = Coordinator::start(
        vec![("slow".into(), slow)],
        CoordinatorConfig { queue_capacity, policy: BatchPolicy::immediate(1) },
    )
    .unwrap();
    Server::start(coord, registry, "127.0.0.1:0", config).unwrap()
}

/// A slowloris peer — half a frame header, then silence — must be
/// answered `Timeout`, disconnected, and its connection slot reused.
#[test]
fn stalled_half_frame_times_out_and_frees_the_only_slot() {
    let server = slow_echo_server(
        1,
        64,
        ServeConfig {
            max_conns: 1,
            read_timeout: Duration::from_millis(300),
            ..ServeConfig::default()
        },
    );
    let addr = server.local_addr();

    let mut stalled = TcpStream::connect(addr).unwrap();
    stalled.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    stalled.write_all(b"EMWP\x03\x00").unwrap(); // magic + version, then stall
    let goodbye = wire::read_frame(&mut stalled, 1 << 20).unwrap();
    assert_eq!(goodbye.status, Status::Timeout, "{goodbye:?}");
    assert!(goodbye.message().contains("deadline"), "{}", goodbye.message());
    let mut rest = Vec::new();
    assert_eq!(stalled.read_to_end(&mut rest).unwrap(), 0, "server must hang up");

    // max_conns is 1: this connect can only be served because the
    // stalled connection was evicted. The slot release races the
    // eviction by a hair, so tolerate a few Busy bounces.
    let mut served = None;
    for _ in 0..100 {
        let mut client = Client::connect(addr).unwrap();
        match client.infer(0, &probe()) {
            Ok(InferReply::Output(out)) => {
                served = Some((client, out));
                break;
            }
            Ok(_) | Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let (mut client, out) = served.expect("freed slot never served a well-behaved client");
    assert_eq!(out, probe());
    let health = client.health().unwrap();
    assert!(health.read_timeouts >= 1, "{health:?}");
    server.shutdown();
}

/// The slowloris defense at c10k-class scale (ISSUE 9 satellite): a
/// thousand concurrently stalled half-frame connections each get
/// exactly one `Timeout` goodbye, every slot is freed, and the server's
/// thread count stays flat while they are registered — connections are
/// a memory problem for the event loop, not a thread problem.
#[test]
fn a_thousand_stalled_connections_all_time_out_and_free_their_slots() {
    // Client and server sockets both live in this process; scale the
    // population down if the fd limit cannot cover 2× connections.
    let fd_limit = edgemlp::serve::raise_nofile_limit(4096);
    let stalled_n: usize = if fd_limit >= 2500 { 1000 } else { 100 };
    let server = slow_echo_server(
        1,
        64,
        ServeConfig {
            max_conns: stalled_n + 8,
            read_timeout: Duration::from_millis(500),
            ..ServeConfig::default()
        },
    );
    let addr = server.local_addr();
    let threads_before = thread_count();

    let mut stalled = Vec::with_capacity(stalled_n);
    for _ in 0..stalled_n {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s.write_all(b"EMWP\x03\x00").unwrap(); // magic + version, then stall
        stalled.push(s);
    }
    // Every connection is registered with the loop, yet no thread was
    // spawned for any of them (tolerance for unrelated runtime threads).
    if let (Some(before), Some(during)) = (threads_before, thread_count()) {
        assert!(
            during <= before + 2,
            "thread count grew with connections: {before} -> {during}"
        );
    }

    for mut s in stalled {
        let goodbye = wire::read_frame(&mut s, 1 << 20).unwrap();
        assert_eq!(goodbye.status, Status::Timeout, "{goodbye:?}");
        assert!(goodbye.message().contains("deadline"), "{}", goodbye.message());
        let mut rest = Vec::new();
        assert_eq!(s.read_to_end(&mut rest).unwrap(), 0, "exactly one goodbye, then hang up");
    }

    // All slots freed: a well-behaved client is served (tolerate the
    // slot-release race like the single-connection variant).
    let mut served = None;
    for _ in 0..100 {
        let mut client = Client::connect(addr).unwrap();
        match client.infer(0, &probe()) {
            Ok(InferReply::Output(out)) => {
                served = Some((client, out));
                break;
            }
            Ok(_) | Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let (mut client, out) = served.expect("freed slots never served a well-behaved client");
    assert_eq!(out, probe());
    let health = client.health().unwrap();
    assert!(health.read_timeouts >= stalled_n as u64, "{health:?}");
    server.shutdown();
}

/// Best-effort OS thread count for this process (`None` off Linux).
fn thread_count() -> Option<usize> {
    std::fs::read_dir("/proc/self/task").ok().map(|d| d.count())
}

/// A deadline the queue backlog makes infeasible is answered
/// `Expired` at admission; deadline-free requests behind the same
/// backlog are all still served, and `Health` reports the tally.
#[test]
fn infeasible_deadline_is_expired_at_admission_over_tcp() {
    let server = slow_echo_server(30, 64, ServeConfig::default());
    let addr = server.local_addr();
    let mut client = Client::connect(addr).unwrap();

    // Warm the admission estimator with served requests.
    for _ in 0..3 {
        match client.infer(0, &probe()).unwrap() {
            InferReply::Output(out) => assert_eq!(out, probe()),
            other => panic!("warmup failed: {other:?}"),
        }
    }

    // Wedge the single worker behind a backlog, then ask for the
    // impossible: a 1 ms budget against a ~30 ms/request pool.
    let mut pending = Vec::new();
    for _ in 0..6 {
        pending.push(client.send_infer(0, &probe()).unwrap());
    }
    let doomed = client.send_infer_qos(0, "", Qos::with_deadline_us(1_000), &probe()).unwrap();

    let mut replies = HashMap::new();
    for _ in 0..pending.len() + 1 {
        let (id, reply) = client.recv_infer().unwrap();
        replies.insert(id, reply);
    }
    match replies.remove(&doomed).expect("no reply for the doomed request") {
        InferReply::Failed { status, message } => {
            assert_eq!(status, Status::Expired, "{message}");
            assert!(message.contains("infeasible"), "{message}");
        }
        other => panic!("expected admission rejection, got {other:?}"),
    }
    for id in pending {
        match replies.remove(&id).expect("backlogged request lost") {
            InferReply::Output(out) => assert_eq!(out, probe()),
            other => panic!("deadline-free request must still be served: {other:?}"),
        }
    }

    let health = client.health().unwrap();
    assert_eq!(health.pools.len(), 1, "{health:?}");
    let pool = &health.pools[0];
    assert_eq!(pool.name, "slow");
    assert_eq!(pool.queue_capacity, 64);
    assert_eq!(pool.replicas, 1);
    assert!(pool.expired >= 1, "{health:?}");
    assert!(!health.degraded, "{health:?}");

    // Health is v3-only: a v2-framed Health request is a BadRequest,
    // not a protocol violation that kills the connection.
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let req = Frame {
        version: 2,
        opcode: Opcode::Health,
        status: Status::Ok,
        request_id: 9,
        payload: Vec::new(),
    };
    wire::write_frame(&mut raw, &req).unwrap();
    let resp = wire::read_frame(&mut raw, 1 << 20).unwrap();
    assert_eq!(resp.status, Status::BadRequest, "{resp:?}");
    assert_eq!(resp.request_id, 9);
    server.shutdown();
}

/// A v2-framed client round-trips unchanged against the v3 server,
/// and responses echo the request's protocol version.
#[test]
fn v2_framed_client_round_trips_against_the_v3_server() {
    let server = slow_echo_server(0, 64, ServeConfig::default());
    let addr = server.local_addr();
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();

    let payload = wire::encode_infer_v2(0, "default", &probe()).unwrap();
    let req =
        Frame { version: 2, opcode: Opcode::Infer, status: Status::Ok, request_id: 77, payload };
    wire::write_frame(&mut raw, &req).unwrap();
    let resp = wire::read_frame(&mut raw, 1 << 20).unwrap();
    assert_eq!(resp.version, 2, "responses must echo the request version");
    assert_eq!(resp.request_id, 77);
    assert_eq!(resp.status, Status::Ok, "{}", resp.message());
    let out = wire::decode_outputs(&resp.payload).unwrap();
    assert_eq!(out, probe());
    server.shutdown();
}

/// The retrying client is at-most-once over real TCP: all attempts of
/// one logical request share one wire id, an abandoned attempt's late
/// reply is never consumed, and distinct logical requests use
/// distinct ids.
#[test]
fn retried_request_keeps_one_wire_id_and_consumes_at_most_one_reply() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let seen = Arc::new(Mutex::new(Vec::new()));
    let seen_srv = seen.clone();
    let fake = std::thread::spawn(move || {
        // Attempt 1: swallow the request and reply only after the
        // client has abandoned the attempt — the duplicate-answer trap.
        let (mut c1, _) = listener.accept().unwrap();
        let f1 = wire::read_frame(&mut c1, 1 << 20).unwrap();
        seen_srv.lock().unwrap().push(f1.request_id);
        let late = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(400));
            let _ = wire::write_frame(
                &mut c1,
                &Frame::ok(Opcode::Infer, f1.request_id, wire::encode_outputs(&[9.0])),
            );
        });
        // Attempt 2 arrives on a fresh connection: answer immediately.
        let (mut c2, _) = listener.accept().unwrap();
        let f2 = wire::read_frame(&mut c2, 1 << 20).unwrap();
        seen_srv.lock().unwrap().push(f2.request_id);
        wire::write_frame(
            &mut c2,
            &Frame::ok(Opcode::Infer, f2.request_id, wire::encode_outputs(&[1.0, 2.0])),
        )
        .unwrap();
        // The connection is healthy, so the next logical request rides
        // it — under a new wire id.
        let f3 = wire::read_frame(&mut c2, 1 << 20).unwrap();
        seen_srv.lock().unwrap().push(f3.request_id);
        wire::write_frame(
            &mut c2,
            &Frame::ok(Opcode::Infer, f3.request_id, wire::encode_outputs(&[3.0])),
        )
        .unwrap();
        late.join().unwrap();
    });

    let policy = RetryPolicy {
        max_attempts: 3,
        base_backoff: Duration::from_millis(5),
        max_backoff: Duration::from_millis(20),
        jitter: 0.0,
        attempt_timeout: Duration::from_millis(150),
    };
    let mut rc = RetryingClient::new(addr, policy, 42);
    let (reply, attempts) = rc.infer_qos(0, "", Qos::NONE, &[0.5; 4]).unwrap();
    assert_eq!(attempts, 2, "first attempt should have timed out");
    match reply {
        InferReply::Output(out) => assert_eq!(out, vec![1.0, 2.0]),
        other => panic!("retry did not recover: {other:?}"),
    }
    let (reply2, attempts2) = rc.infer_qos(0, "", Qos::NONE, &[0.5; 4]).unwrap();
    assert_eq!(attempts2, 1);
    match reply2 {
        InferReply::Output(out) => {
            assert_eq!(out, vec![3.0], "late duplicate reply must never be consumed")
        }
        other => panic!("second logical request failed: {other:?}"),
    }

    fake.join().unwrap();
    let seen = seen.lock().unwrap();
    assert_eq!(seen.len(), 3, "{seen:?}");
    assert_eq!(seen[0], seen[1], "attempts of one logical request must reuse its wire id");
    assert_ne!(seen[1], seen[2], "distinct logical requests must use distinct ids");
}

/// Sustained saturation flips `BACKEND_ANY` routing into degraded
/// mode; an idle queue flips it back, and `Health` counts both
/// transitions. A zero-dwell policy makes the flips deterministic.
#[test]
fn degraded_mode_enters_under_saturation_and_recovers() {
    let server = slow_echo_server(
        2,
        64,
        ServeConfig {
            degrade: DegradePolicy {
                enter_occupancy: 0.01,
                exit_occupancy: 0.005,
                enter_after: Duration::ZERO,
                exit_after: Duration::ZERO,
            },
            ..ServeConfig::default()
        },
    );
    let addr = server.local_addr();
    let mut client = Client::connect(addr).unwrap();

    // Saturate: 48 pipelined BACKEND_ANY requests against a ~2 ms/req
    // single worker. The router samples queue occupancy on every
    // BACKEND_ANY decision, and the reader enqueues far faster than
    // the worker drains, so a saturated sample is guaranteed.
    for _ in 0..48 {
        client.send_infer(BACKEND_ANY, &probe()).unwrap();
    }
    for _ in 0..48 {
        let (_, reply) = client.recv_infer().unwrap();
        assert!(matches!(reply, InferReply::Output(_)), "{reply:?}");
    }
    let mut watcher = Client::connect(addr).unwrap();
    let health = watcher.health().unwrap();
    assert!(health.degraded, "sustained saturation must flip degraded mode: {health:?}");
    assert!(health.degraded_transitions >= 1, "{health:?}");

    // The queue is drained; the next BACKEND_ANY decision samples zero
    // occupancy and recovers.
    match client.infer(BACKEND_ANY, &probe()).unwrap() {
        InferReply::Output(out) => assert_eq!(out, probe()),
        other => panic!("recovery request failed: {other:?}"),
    }
    let health = watcher.health().unwrap();
    assert!(!health.degraded, "idle queue must recover normal mode: {health:?}");
    assert!(health.degraded_transitions >= 2, "{health:?}");
    server.shutdown();
}

/// Degraded mode must shed precision, not requests: on an engine mixing
/// f32, int8 and int4 pools, sustained saturation routes `BACKEND_ANY`
/// traffic onto the lowest-bytes-per-sample pool — packed int4 — and an
/// idle queue recovers least-loaded routing. The cheapest-pool choice is
/// `BackendKind::cost_rank`, which orders pools by weight footprint.
#[test]
fn degraded_mode_routes_backend_any_to_the_lowest_bytes_pool() {
    // A deliberately heavy head (≈217k MACs/sample, unoptimized test
    // build) so the connection reader enqueues far faster than the
    // worker pools drain — the saturated occupancy sample is guaranteed
    // mid-burst, as in the hysteresis test above.
    let mut rng = edgemlp::util::rng::Pcg32::new(7);
    let mlp = Mlp::new(
        MlpConfig {
            sizes: vec![784, 256, 64, 10],
            activations: vec![Activation::Sigmoid; 3],
        },
        &mut rng,
    );
    let registry = ModelRegistry::new("default", mlp, SpxConfig::sp2(5));
    let server = Server::serve(
        registry,
        "127.0.0.1:0",
        EngineConfig {
            replicas: 1,
            backends: vec![BackendKind::Cpu, BackendKind::Int8, BackendKind::Int4],
            coordinator: CoordinatorConfig {
                queue_capacity: 64,
                policy: BatchPolicy::immediate(1),
            },
            serve: ServeConfig {
                degrade: DegradePolicy {
                    enter_occupancy: 0.01,
                    exit_occupancy: 0.005,
                    enter_after: Duration::ZERO,
                    exit_after: Duration::ZERO,
                },
                ..ServeConfig::default()
            },
            autoscale: None,
            power_budget_w: None,
        },
    )
    .unwrap();
    let addr = server.local_addr();
    let mut client = Client::connect(addr).unwrap();

    // Saturate: 48 pipelined BACKEND_ANY requests. The mode flips as
    // soon as every pool holds work (≤ ~7 requests in), after which all
    // remaining routing decisions land on the cheapest pool.
    for _ in 0..48 {
        client.send_infer(BACKEND_ANY, &probe()).unwrap();
    }
    for _ in 0..48 {
        let (_, reply) = client.recv_infer().unwrap();
        match reply {
            InferReply::Output(out) => assert_eq!(out.len(), 10),
            other => panic!("{other:?}"),
        }
    }
    let mut watcher = Client::connect(addr).unwrap();
    let health = watcher.health().unwrap();
    assert!(health.degraded, "sustained saturation must flip degraded mode: {health:?}");

    // The degraded stretch routed the bulk of the burst to int4 — the
    // pool streaming the fewest weight bytes per sample — while the
    // pre-flip spread left at most a handful on the f32/int8 pools.
    let snap = server.metrics().snapshot();
    let served = |pool: &str| {
        snap.backends
            .get(pool)
            .unwrap_or_else(|| panic!("missing pool {pool}: {:?}", snap.backends.keys()))
            .requests
    };
    let (f32r, i8r, i4r) = (served("cpu/default"), served("int8/default"), served("int4/default"));
    assert_eq!(f32r + i8r + i4r, 48, "requests vanished");
    assert!(
        i4r > f32r && i4r > i8r,
        "degraded routing must concentrate on the int4 pool: cpu={f32r} int8={i8r} int4={i4r}"
    );
    let bytes = |pool: &str| snap.backends[pool].bytes_per_sample;
    assert!(
        bytes("int4/default") < bytes("int8/default")
            && bytes("int8/default") < bytes("cpu/default"),
        "cheapest pool must also be the smallest footprint"
    );

    // Drained queue: the next BACKEND_ANY decision samples zero
    // occupancy and recovers least-loaded routing.
    match client.infer(BACKEND_ANY, &probe()).unwrap() {
        InferReply::Output(out) => assert_eq!(out.len(), 10),
        other => panic!("recovery request failed: {other:?}"),
    }
    let health = watcher.health().unwrap();
    assert!(!health.degraded, "idle queue must recover normal mode: {health:?}");
    server.shutdown();
}

/// The graceful-degradation acceptance scenario: ~2× capacity offered
/// with deadlines. Infeasible work is shed (`Expired`/`Backpressure`),
/// accepted work overwhelmingly meets its deadline, and every request
/// is accounted for — nothing silently dropped.
#[test]
fn overload_sheds_gracefully_and_accounts_for_every_request() {
    // ~5 ms/request single worker ⇒ ~200 req/s capacity; offer ~2×
    // into a queue only 8 deep (worst-case wait ~45 ms « 100 ms
    // deadline, so accepted requests comfortably meet it).
    let server = slow_echo_server(5, 8, ServeConfig::default());
    let addr = server.local_addr();

    let mut client = Client::connect(addr).unwrap();
    for _ in 0..3 {
        match client.infer(0, &probe()).unwrap() {
            InferReply::Output(_) => {}
            other => panic!("warmup: {other:?}"),
        }
    }
    // With a warm estimator, a deadline smaller than one service time
    // is infeasible even against an empty queue: Expired at admission.
    match client.infer_qos(0, "", Qos::with_deadline_us(1_000), &probe()).unwrap() {
        InferReply::Failed { status, message } => {
            assert_eq!(status, Status::Expired, "{message}")
        }
        other => panic!("sub-service-time deadline admitted: {other:?}"),
    }

    let report = run_loadgen(
        addr,
        LoadGenConfig {
            requests: 300,
            connections: 4,
            backend: 0,
            dim: 784,
            rate_rps: 400.0,
            pipeline: 16,
            deadline_us: 100_000,
            seed: 11,
            ..LoadGenConfig::default()
        },
    )
    .unwrap();

    assert_eq!(report.sent, 300, "{report:?}");
    assert_eq!(
        report.ok + report.shed + report.expired + report.errors,
        report.sent,
        "requests vanished: {report:?}"
    );
    assert_eq!(report.errors, 0, "{report:?}");
    assert!(report.ok > 0, "{report:?}");
    // 2× overload into an 8-deep queue must shed or expire something.
    assert!(report.shed + report.expired > 0, "{report:?}");
    // Accepted work meets the SLO (the ≥95% acceptance bar; asserted
    // at 90% to absorb CI scheduling noise).
    let attainment = report.attainment().expect("deadline set and requests served");
    assert!(attainment >= 0.9, "attainment {attainment}: {report:?}");
    server.shutdown();
}
