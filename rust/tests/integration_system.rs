//! Cross-module integration tests that need no PJRT artifacts: the
//! quantize → simulate → verify path, failure injection, and
//! end-to-end invariants across substrates.

use edgemlp::coordinator::backend::{Backend, FnBackend};
use edgemlp::coordinator::batcher::BatchPolicy;
use edgemlp::coordinator::server::{BackendFactory, Coordinator, CoordinatorConfig};
use edgemlp::data::load_digits;
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::fpga::clock::ClockConfig;
use edgemlp::fpga::pipeline::PipelineConfig;
use edgemlp::fpga::verilog::{emit_design, VerilogConfig};
use edgemlp::nn::metrics::accuracy;
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::nn::train::{train, TrainConfig};
use edgemlp::quant::spx::{SpxConfig, SpxTensor};
use edgemlp::quant::Calibration;
use edgemlp::util::check::assert_allclose;
use edgemlp::util::rng::Pcg32;
use std::time::Duration;

/// Full codesign loop: train → quantize → run on the simulator →
/// accuracy within a few points of fp32 at b=8.
#[test]
fn trained_model_survives_quantized_hardware_path() {
    let (train_set, test_set) = load_digits(1500, 300, 11);
    let mut rng = Pcg32::new(5);
    let mut mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let _ = train(
        &mut mlp,
        &train_set.inputs,
        &train_set.labels,
        &TrainConfig { epochs: 6, ..Default::default() },
    );
    let fp32 = accuracy(&mlp, &test_set.inputs, &test_set.labels);
    let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::spx(8, 2), Calibration::MaxAbs, None);
    let accel = Accelerator::new(q, AccelConfig::default_fpga());
    let mut correct = 0;
    let n = 150;
    for i in 0..n {
        let (pred, _) = accel.classify_one(test_set.inputs.row(i));
        if pred == test_set.labels[i] {
            correct += 1;
        }
    }
    let hw = correct as f64 / n as f64;
    assert!(
        hw > fp32 - 0.05,
        "hardware path accuracy {hw} fell more than 5 points below fp32 {fp32}"
    );
}

/// The ReLU Q-network also runs on the accelerator (identity output,
/// negative activations — exercises d_scale calibration).
#[test]
fn qnet_runs_on_accelerator_with_calibration() {
    let mut rng = Pcg32::new(9);
    let qnet = Mlp::new(MlpConfig::paper_qnet(), &mut rng);
    // Calibration batch spanning acrobot-like ranges.
    let mut calib = edgemlp::nn::tensor::Matrix::zeros(32, 6);
    for r in 0..32 {
        for c in 0..6 {
            let range = if c < 4 { 1.0 } else { 12.0 };
            *calib.at_mut(r, c) = rng.range(-range, range) as f32;
        }
    }
    let q = QuantizedMlp::from_mlp(&qnet, &SpxConfig::spx(8, 2), Calibration::MaxAbs, Some(&calib));
    assert!(q.layers[0].d_scale > 1.0, "input layer must see the velocity range");
    let accel = Accelerator::new(q, AccelConfig::default_fpga());
    for _ in 0..8 {
        let obs: Vec<f32> = (0..6)
            .map(|c| {
                let range = if c < 4 { 1.0 } else { 10.0 };
                rng.range(-range, range) as f32
            })
            .collect();
        let (hw, _) = accel.infer_one(&obs);
        let sw = qnet.forward_one(&obs);
        // b=8 quantization + fixed point: coarse agreement is enough to
        // preserve argmax most of the time; check magnitudes track.
        assert_eq!(hw.len(), 3);
        assert_allclose(&hw, &sw, 0.5, 0.5);
    }
}

/// Streaming vs resident schedules compute identical numbers (only the
/// timing model differs).
#[test]
fn schedules_agree_numerically() {
    let mut rng = Pcg32::new(3);
    let wdata: Vec<f32> = (0..64 * 96).map(|_| rng.normal() as f32 * 0.3).collect();
    let w = SpxTensor::encode(&SpxConfig::sp2(6), &wdata, &[64, 96], Calibration::MaxAbs);
    let d: Vec<f32> = (0..96).map(|_| rng.uniform() as f32).collect();
    let resident = edgemlp::fpga::pipeline::run_matvec(&w, &d, 1.0, &PipelineConfig::default_fpga());
    let streaming = edgemlp::fpga::pipeline::run_matvec(&w, &d, 1.0, &PipelineConfig::streaming());
    assert_eq!(resident.outputs, streaming.outputs);
    // Resident schedule must be faster and touch less RAM.
    assert!(resident.stats.compute_cycles < streaming.stats.compute_cycles);
    assert!(resident.stats.ram_reads < streaming.stats.ram_reads);
}

/// Verilog emission stays multiplier-free for every supported config.
#[test]
fn verilog_multiplier_free_across_configs() {
    for (b, x) in [(3u32, 1u32), (5, 2), (7, 3), (9, 4)] {
        let cfg = VerilogConfig { spx: SpxConfig::spx(b, x), ..VerilogConfig::default_design() };
        let design = emit_design(&cfg);
        for line in design.lines() {
            assert!(!line.contains(" * "), "multiplier in (b={b},x={x}): {line}");
        }
        assert_eq!(design.matches(">>>").count(), x as usize, "b={b} x={x}");
    }
}

/// Coordinator drop (without explicit shutdown) joins workers and does
/// not hang or leak panics.
#[test]
fn coordinator_drop_is_clean() {
    let echo: (String, BackendFactory) = (
        "echo".into(),
        Box::new(|| {
            Ok(Box::new(FnBackend::new("echo", 8, |inputs: &[Vec<f32>]| {
                Ok(inputs.to_vec())
            })) as Box<dyn Backend>)
        }),
    );
    let coord = Coordinator::start(
        vec![echo],
        CoordinatorConfig { queue_capacity: 16, policy: BatchPolicy::immediate(8) },
    )
    .unwrap();
    let rx = coord.submit(vec![1.0]).unwrap();
    let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
    drop(coord); // must join workers, not deadlock
}

/// Degenerate-but-legal configurations don't panic anywhere in the
/// simulator (failure injection on the config surface).
#[test]
fn simulator_handles_degenerate_configs() {
    let mut rng = Pcg32::new(1);
    let wdata: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
    let w = SpxTensor::encode(&SpxConfig::spx(3, 1), &wdata, &[2, 2], Calibration::MaxAbs);
    let d = vec![0.5f32, -0.5];
    for cfg in [
        PipelineConfig {
            clocks: ClockConfig { clk_inbuff_mhz: 0.001, clk_compute_mhz: 1000.0, bandwidth_words: 1 },
            num_pus: 1,
            buffer_capacity_rows: 1,
            pipeline_depth: 0,
            lanes: 1,
            weight_resident: false,
        },
        PipelineConfig {
            clocks: ClockConfig { clk_inbuff_mhz: 1e6, clk_compute_mhz: 0.001, bandwidth_words: 4096 },
            num_pus: 64,
            buffer_capacity_rows: 4096,
            pipeline_depth: 100,
            lanes: 64,
            weight_resident: true,
        },
    ] {
        let run = edgemlp::fpga::pipeline::run_matvec(&w, &d, 1.0, &cfg);
        assert_eq!(run.outputs.len(), 2);
        assert!(run.stats.compute_cycles > 0);
    }
}

/// All-zero weights (alpha = 0) flow through the whole accelerator.
#[test]
fn zero_model_is_well_defined() {
    let mut rng = Pcg32::new(2);
    let mut mlp = Mlp::new(
        MlpConfig { sizes: vec![4, 3, 2], activations: MlpConfig::paper_mnist().activations },
        &mut rng,
    );
    for layer in &mut mlp.layers {
        layer.w.data.iter_mut().for_each(|w| *w = 0.0);
        layer.b.iter_mut().for_each(|b| *b = 0.0);
    }
    let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(4), Calibration::MaxAbs, None);
    let accel = Accelerator::new(q, AccelConfig::default_fpga());
    let (out, _) = accel.infer_one(&[1.0, 1.0, 1.0, 1.0]);
    // σ(0) = 0.5 everywhere.
    assert_allclose(&out, &[0.5, 0.5], 1e-3, 1e-3);
}
