//! Integration: the full serving stack — coordinator + all three
//! backends (CPU, FPGA-sim, XLA/PJRT) over real artifacts — agreeing on
//! classifications for the same trained model.

use edgemlp::coordinator::backend::{Backend, CpuBackend, FnBackend, FpgaBackend};
use edgemlp::coordinator::batcher::BatchPolicy;
use edgemlp::coordinator::server::{BackendFactory, Coordinator, CoordinatorConfig};
use edgemlp::data::load_digits;
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::nn::mlp::{argmax, Mlp, MlpConfig};
use edgemlp::nn::train::{train, TrainConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::Calibration;
use edgemlp::runtime::executable::mlp_fp32_inputs;
use edgemlp::runtime::{Registry, Runtime};
use edgemlp::util::rng::Pcg32;
use std::path::{Path, PathBuf};
use std::time::Duration;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Quickly trained model shared by the tests in this file.
fn trained() -> (Mlp, edgemlp::data::Dataset) {
    let (train_set, test_set) = load_digits(1500, 200, 77);
    let mut rng = Pcg32::new(1);
    let mut mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let _ = train(
        &mut mlp,
        &train_set.inputs,
        &train_set.labels,
        &TrainConfig { epochs: 4, ..Default::default() },
    );
    (mlp, test_set)
}

#[test]
fn three_backends_agree_through_coordinator() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (mlp, test_set) = trained();

    let cpu_mlp = mlp.clone();
    let cpu_factory: BackendFactory =
        Box::new(move || Ok(Box::new(CpuBackend::new(cpu_mlp)) as Box<dyn Backend>));

    let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::spx(8, 2), Calibration::MaxAbs, None);
    let fpga_factory: BackendFactory = Box::new(move || {
        Ok(Box::new(FpgaBackend::new(Accelerator::new(q, AccelConfig::default_fpga())))
            as Box<dyn Backend>)
    });

    // XLA backend: construct the non-Send runtime inside the worker.
    let xla_mlp = mlp.clone();
    let xla_factory: BackendFactory = Box::new(move || {
        let rt = Runtime::new(Registry::open(&dir)?)?;
        let model = rt.load("mlp_fp32_b1")?;
        Ok(Box::new(FnBackend::new("xla", 1, move |inputs: &[Vec<f32>]| {
            // _rt must stay alive as long as the model: keep both in the
            // closure's environment.
            let _keep_alive = &rt;
            let mut out = Vec::with_capacity(inputs.len());
            for x in inputs {
                out.push(model.run(&mlp_fp32_inputs(&xla_mlp, x))?);
            }
            Ok(out)
        })) as Box<dyn Backend>)
    });

    let coord = Coordinator::start(
        vec![
            ("cpu".into(), cpu_factory),
            ("fpga".into(), fpga_factory),
            ("xla".into(), xla_factory),
        ],
        CoordinatorConfig {
            queue_capacity: 64,
            policy: BatchPolicy::windowed(16, Duration::from_millis(1)),
        },
    )
    .unwrap();

    let n = 24;
    let mut agreements = 0usize;
    for i in 0..n {
        let x = test_set.inputs.row(i).to_vec();
        let mut preds = Vec::new();
        for backend in ["cpu", "fpga", "xla"] {
            let idx = coord.backend_index(backend).unwrap();
            let rx = coord.submit_to(idx, x.clone()).unwrap();
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
            assert_eq!(resp.output.len(), 10, "{backend} output size");
            preds.push(argmax(&resp.output));
        }
        // CPU and XLA compute the identical fp32 function.
        assert_eq!(preds[0], preds[2], "cpu vs xla disagree on sample {i}");
        if preds[0] == preds[1] {
            agreements += 1;
        }
    }
    // The 8-bit SPx accelerator should agree with fp32 on the vast
    // majority of samples.
    assert!(agreements * 10 >= n * 8, "fpga agreed on only {agreements}/{n}");

    let snap = coord.metrics().snapshot();
    assert_eq!(snap.backends.len(), 3);
    assert_eq!(snap.backends["xla"].requests, n as u64);
    // FPGA backend reported simulator cycles.
    assert!(snap.backends["fpga"].cycle_stats.compute_cycles > 0);
    coord.shutdown();
}

#[test]
fn coordinator_survives_mixed_load_with_real_xla() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (mlp, test_set) = trained();
    let xla_mlp = mlp.clone();
    let xla_factory: BackendFactory = Box::new(move || {
        let rt = Runtime::new(Registry::open(&dir)?)?;
        let model = rt.load("mlp_fp32_b1")?;
        Ok(Box::new(FnBackend::new("xla", 1, move |inputs: &[Vec<f32>]| {
            let _keep_alive = &rt;
            inputs.iter().map(|x| model.run(&mlp_fp32_inputs(&xla_mlp, x))).collect()
        })) as Box<dyn Backend>)
    });
    let coord = Coordinator::start(
        vec![("xla".into(), xla_factory)],
        CoordinatorConfig { queue_capacity: 128, policy: BatchPolicy::immediate(1) },
    )
    .unwrap();
    let receivers: Vec<_> = (0..40)
        .map(|i| coord.submit(test_set.inputs.row(i % test_set.len()).to_vec()).unwrap())
        .collect();
    for rx in receivers {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
        assert_eq!(resp.output.len(), 10);
    }
    coord.shutdown();
}
