//! Fault-injection suite (ISSUE 5 satellite): panics and shutdown
//! races in the serving engine must stay contained.
//!
//! * a worker/stage panic mid-batch fails only that batch's requests —
//!   error responses, no deadlock, and the pool/pipeline keeps serving;
//! * closing a queue during a partial multi-consumer drain loses zero
//!   accepted items (exactly-once delivery through the close race).

use edgemlp::coordinator::backend::{Backend, FnBackend};
use edgemlp::coordinator::queue::BoundedQueue;
use edgemlp::coordinator::server::{PoolSpec, SharedBackendFactory};
use edgemlp::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use edgemlp::nn::kernels::{StageFn, StagePipeline};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Echo backend that panics on any sample whose first element is
/// negative — the injected fault.
fn bomb_factory() -> SharedBackendFactory {
    Arc::new(|| {
        Ok(Box::new(FnBackend::new("bomb", 8, |inputs: &[Vec<f32>]| {
            if inputs.iter().any(|x| x[0] < 0.0) {
                panic!("injected worker fault");
            }
            Ok(inputs.to_vec())
        })) as Box<dyn Backend>)
    })
}

/// A replicated pool absorbs a panicking batch: the poisoned batch's
/// requests get error responses, every other request is answered
/// normally, and shutdown joins cleanly (no worker died, no deadlock).
#[test]
fn worker_panic_fails_only_its_batch() {
    let coord = Coordinator::start(
        vec![PoolSpec::replicated("bomb", 2, bomb_factory())],
        CoordinatorConfig { queue_capacity: 128, policy: BatchPolicy::immediate(1) },
    )
    .unwrap();
    // Interleave poisoned and good requests; immediate(1) batching
    // keeps each request in its own batch, so exactly the poisoned
    // ones must fail.
    let mut receivers = Vec::new();
    for i in 0..30usize {
        let x = if i % 5 == 0 { vec![-1.0, i as f32] } else { vec![1.0, i as f32] };
        receivers.push((i, coord.submit(x).unwrap()));
    }
    for (i, rx) in receivers {
        let result = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        if i % 5 == 0 {
            let err = result.unwrap_err();
            assert!(err.contains("panicked"), "request {i}: {err}");
            assert!(err.contains("injected worker fault"), "request {i}: {err}");
        } else {
            assert_eq!(result.unwrap().output, vec![1.0, i as f32], "request {i}");
        }
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.backends["bomb"].errors, 6);
    coord.shutdown();
}

/// With dynamic batching, requests co-batched with a poisoned one may
/// share its fate (batch-wide error) — but every request is answered,
/// and batches formed afterwards succeed.
#[test]
fn worker_panic_with_dynamic_batching_answers_everything() {
    let coord = Coordinator::start(
        vec![PoolSpec::replicated("bomb", 1, bomb_factory())],
        CoordinatorConfig {
            queue_capacity: 128,
            policy: BatchPolicy::windowed(8, Duration::from_millis(20)),
        },
    )
    .unwrap();
    // One poisoned request in a burst of 8 — likely co-batched.
    let mut receivers = Vec::new();
    for i in 0..8usize {
        let x = if i == 3 { vec![-1.0] } else { vec![0.5] };
        receivers.push(coord.submit(x).unwrap());
    }
    let mut answered = 0;
    for rx in receivers {
        // Ok (split into a clean batch) or the batch-wide panic error —
        // never a lost reply.
        let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        answered += 1;
    }
    assert_eq!(answered, 8);
    // The pool recovered: a fresh burst of clean requests all succeed.
    let receivers: Vec<_> = (0..8).map(|_| coord.submit(vec![0.5]).unwrap()).collect();
    for rx in receivers {
        rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
    }
    coord.shutdown();
}

/// Stage-pipeline analogue, beyond the lock-step unit test in
/// `nn/kernels/pipeline.rs`: a sustained stream at full depth with
/// *several* poisoned jobs in flight at once. Every result must come
/// back in submission order, exactly the poisoned ordinals must fail,
/// and the bombed stage must keep serving throughout.
#[test]
fn repeated_stage_panics_at_full_depth_preserve_order_and_survive() {
    let depth = 4usize;
    let stages: Vec<(String, StageFn<i64>)> = vec![
        ("double".into(), Box::new(|j: &mut i64| *j *= 2)),
        (
            "bomb".into(),
            Box::new(|j: &mut i64| {
                if *j < 0 {
                    panic!("injected stage fault");
                }
                *j += 1;
            }),
        ),
    ];
    let pipe = StagePipeline::new("fault", depth, stages);

    // Every 5th job is poisoned (negative). Keep the pipeline saturated
    // at `depth` in-flight jobs so poisoned and healthy jobs overlap
    // inside the stages.
    let n = 40usize;
    let poisoned = |i: usize| i % 5 == 3;
    let mut in_flight = 0usize;
    let mut next_out = 0usize;
    let check = |result: Result<i64, edgemlp::nn::kernels::StageError>, i: usize| {
        if poisoned(i) {
            let err = result.unwrap_err();
            assert_eq!(err.stage, 1, "job {i}");
            assert!(err.message.contains("injected stage fault"), "job {i}: {err}");
        } else {
            assert_eq!(result.unwrap(), i as i64 * 2 + 1, "job {i}");
        }
    };
    for i in 0..n {
        if in_flight == depth {
            check(pipe.recv().unwrap(), next_out);
            next_out += 1;
            in_flight -= 1;
        }
        let v = if poisoned(i) { -(i as i64) - 1 } else { i as i64 };
        assert!(pipe.submit(v), "submit {i}");
        in_flight += 1;
    }
    while next_out < n {
        check(pipe.recv().unwrap(), next_out);
        next_out += 1;
    }
    let snaps = pipe.snapshots();
    assert_eq!(snaps[0].processed as usize, n, "stage 0 sees every job");
    assert_eq!(snaps[1].failed as usize, n / 5, "one failure per poisoned job");
    assert_eq!(snaps[1].processed as usize, n - n / 5);
}

/// Closing the queue while multiple consumers are mid-drain (some in
/// their straggler window, some actively popping) must deliver every
/// accepted item exactly once — nothing lost, nothing duplicated.
#[test]
fn queue_close_during_partial_drain_loses_zero_accepted_items() {
    let q = Arc::new(BoundedQueue::<u32>::new(256));
    let accepted = Arc::new(AtomicUsize::new(0));

    // Four consumers drain concurrently with small batches and a
    // straggler window, so the close lands mid-drain for some of them.
    let consumers: Vec<_> = (0..4)
        .map(|_| {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    let batch = q.pop_batch(4, Duration::from_millis(1));
                    if batch.is_empty() {
                        return got; // closed + drained
                    }
                    got.extend(batch);
                }
            })
        })
        .collect();

    // Producer pushes monotonically until the close cuts it off; the
    // number of successful pushes is the accepted count.
    let producer = {
        let q = q.clone();
        let accepted = accepted.clone();
        std::thread::spawn(move || {
            for i in 0..100_000u32 {
                if q.push(i).is_err() {
                    return;
                }
                accepted.fetch_add(1, Ordering::SeqCst);
            }
        })
    };

    // Let the drain get going, then close mid-flight.
    std::thread::sleep(Duration::from_millis(20));
    q.close();
    producer.join().unwrap();

    let mut all: Vec<u32> = consumers.into_iter().flat_map(|t| t.join().unwrap()).collect();
    all.sort_unstable();
    let n = accepted.load(Ordering::SeqCst) as u32;
    assert!(n > 0, "producer never got an item in");
    assert_eq!(all.len() as u32, n, "accepted {n} items, delivered {}", all.len());
    for (i, &v) in all.iter().enumerate() {
        assert_eq!(v, i as u32, "item {i} lost or duplicated");
    }
}

/// Same race from the blocking-push side: a producer parked in `push`
/// on a full queue when `close` lands must get `Err` (not hang, not a
/// silent drop), and everything accepted before the close must drain.
#[test]
fn close_unblocks_parked_producer_without_losing_items() {
    let q = Arc::new(BoundedQueue::<u32>::new(4));
    for i in 0..4 {
        q.push(i).unwrap();
    }
    let parked = {
        let q = q.clone();
        std::thread::spawn(move || q.push(99))
    };
    std::thread::sleep(Duration::from_millis(20));
    q.close();
    assert!(parked.join().unwrap().is_err(), "parked push must fail on close");
    // The four accepted items drain exactly once.
    let mut got = Vec::new();
    loop {
        let batch = q.pop_batch(2, Duration::ZERO);
        if batch.is_empty() {
            break;
        }
        got.extend(batch);
    }
    assert_eq!(got, vec![0, 1, 2, 3]);
}
