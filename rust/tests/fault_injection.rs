//! Fault-injection suite (ISSUE 5 satellite): panics and shutdown
//! races in the serving engine must stay contained.
//!
//! * a worker/stage panic mid-batch fails only that batch's requests —
//!   error responses, no deadlock, and the pool/pipeline keeps serving;
//! * closing a queue during a partial multi-consumer drain loses zero
//!   accepted items (exactly-once delivery through the close race).

use edgemlp::coordinator::backend::{Backend, FnBackend};
use edgemlp::coordinator::queue::BoundedQueue;
use edgemlp::coordinator::request::FailureKind;
use edgemlp::coordinator::server::{PoolSpec, RequestQos, SharedBackendFactory, SubmitError};
use edgemlp::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use edgemlp::nn::kernels::{StageFn, StagePipeline};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Echo backend that panics on any sample whose first element is
/// negative — the injected fault.
fn bomb_factory() -> SharedBackendFactory {
    Arc::new(|| {
        Ok(Box::new(FnBackend::new("bomb", 8, |inputs: &[Vec<f32>]| {
            if inputs.iter().any(|x| x[0] < 0.0) {
                panic!("injected worker fault");
            }
            Ok(inputs.to_vec())
        })) as Box<dyn Backend>)
    })
}

/// A replicated pool absorbs a panicking batch: the poisoned batch's
/// requests get error responses, every other request is answered
/// normally, and shutdown joins cleanly (no worker died, no deadlock).
#[test]
fn worker_panic_fails_only_its_batch() {
    let coord = Coordinator::start(
        vec![PoolSpec::replicated("bomb", 2, bomb_factory())],
        CoordinatorConfig { queue_capacity: 128, policy: BatchPolicy::immediate(1) },
    )
    .unwrap();
    // Interleave poisoned and good requests; immediate(1) batching
    // keeps each request in its own batch, so exactly the poisoned
    // ones must fail.
    let mut receivers = Vec::new();
    for i in 0..30usize {
        let x = if i % 5 == 0 { vec![-1.0, i as f32] } else { vec![1.0, i as f32] };
        receivers.push((i, coord.submit(x).unwrap()));
    }
    for (i, rx) in receivers {
        let result = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        if i % 5 == 0 {
            let err = result.unwrap_err();
            assert!(err.message.contains("panicked"), "request {i}: {err}");
            assert!(err.message.contains("injected worker fault"), "request {i}: {err}");
        } else {
            assert_eq!(result.unwrap().output, vec![1.0, i as f32], "request {i}");
        }
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.backends["bomb"].errors, 6);
    coord.shutdown();
}

/// With dynamic batching, requests co-batched with a poisoned one may
/// share its fate (batch-wide error) — but every request is answered,
/// and batches formed afterwards succeed.
#[test]
fn worker_panic_with_dynamic_batching_answers_everything() {
    let coord = Coordinator::start(
        vec![PoolSpec::replicated("bomb", 1, bomb_factory())],
        CoordinatorConfig {
            queue_capacity: 128,
            policy: BatchPolicy::windowed(8, Duration::from_millis(20)),
        },
    )
    .unwrap();
    // One poisoned request in a burst of 8 — likely co-batched.
    let mut receivers = Vec::new();
    for i in 0..8usize {
        let x = if i == 3 { vec![-1.0] } else { vec![0.5] };
        receivers.push(coord.submit(x).unwrap());
    }
    let mut answered = 0;
    for rx in receivers {
        // Ok (split into a clean batch) or the batch-wide panic error —
        // never a lost reply.
        let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        answered += 1;
    }
    assert_eq!(answered, 8);
    // The pool recovered: a fresh burst of clean requests all succeed.
    let receivers: Vec<_> = (0..8).map(|_| coord.submit(vec![0.5]).unwrap()).collect();
    for rx in receivers {
        rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
    }
    coord.shutdown();
}

/// Stage-pipeline analogue, beyond the lock-step unit test in
/// `nn/kernels/pipeline.rs`: a sustained stream at full depth with
/// *several* poisoned jobs in flight at once. Every result must come
/// back in submission order, exactly the poisoned ordinals must fail,
/// and the bombed stage must keep serving throughout.
#[test]
fn repeated_stage_panics_at_full_depth_preserve_order_and_survive() {
    let depth = 4usize;
    let stages: Vec<(String, StageFn<i64>)> = vec![
        ("double".into(), Box::new(|j: &mut i64| *j *= 2)),
        (
            "bomb".into(),
            Box::new(|j: &mut i64| {
                if *j < 0 {
                    panic!("injected stage fault");
                }
                *j += 1;
            }),
        ),
    ];
    let pipe = StagePipeline::new("fault", depth, stages);

    // Every 5th job is poisoned (negative). Keep the pipeline saturated
    // at `depth` in-flight jobs so poisoned and healthy jobs overlap
    // inside the stages.
    let n = 40usize;
    let poisoned = |i: usize| i % 5 == 3;
    let mut in_flight = 0usize;
    let mut next_out = 0usize;
    let check = |result: Result<i64, edgemlp::nn::kernels::StageError>, i: usize| {
        if poisoned(i) {
            let err = result.unwrap_err();
            assert_eq!(err.stage, 1, "job {i}");
            assert!(err.message.contains("injected stage fault"), "job {i}: {err}");
        } else {
            assert_eq!(result.unwrap(), i as i64 * 2 + 1, "job {i}");
        }
    };
    for i in 0..n {
        if in_flight == depth {
            check(pipe.recv().unwrap(), next_out);
            next_out += 1;
            in_flight -= 1;
        }
        let v = if poisoned(i) { -(i as i64) - 1 } else { i as i64 };
        assert!(pipe.submit(v), "submit {i}");
        in_flight += 1;
    }
    while next_out < n {
        check(pipe.recv().unwrap(), next_out);
        next_out += 1;
    }
    let snaps = pipe.snapshots();
    assert_eq!(snaps[0].processed as usize, n, "stage 0 sees every job");
    assert_eq!(snaps[1].failed as usize, n / 5, "one failure per poisoned job");
    assert_eq!(snaps[1].processed as usize, n - n / 5);
}

/// A worker wedged on a long batch is itself a fault for everything
/// queued behind it: deadline-carrying requests stuck past their budget
/// must come back `Expired` — a structured answer, never a silent drop
/// — and must not reach the backend at all.
#[test]
fn requests_expiring_behind_wedged_worker_are_answered_not_run() {
    let ran = Arc::new(AtomicUsize::new(0));
    let wedge_factory: SharedBackendFactory = {
        let ran = ran.clone();
        Arc::new(move || {
            let ran = ran.clone();
            Ok(Box::new(FnBackend::new("wedge", 1, move |inputs: &[Vec<f32>]| {
                ran.fetch_add(1, Ordering::SeqCst);
                // The first (marker < 0) request wedges the worker long
                // enough for everything queued behind it to expire.
                if inputs[0][0] < 0.0 {
                    std::thread::sleep(Duration::from_millis(150));
                }
                Ok(inputs.to_vec())
            })) as Box<dyn Backend>)
        })
    };
    let coord = Coordinator::start(
        vec![PoolSpec::replicated("wedge", 1, wedge_factory)],
        CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
    )
    .unwrap();
    let wedge = coord.submit(vec![-1.0]).unwrap();
    std::thread::sleep(Duration::from_millis(30)); // worker picks it up
    // Five doomed requests: 20 ms budgets behind a 150 ms wedge. The
    // estimator is still cold (no completed batch), so admission lets
    // them through — the dequeue-side gate must catch them.
    let doomed: Vec<_> = (0..5)
        .map(|i| {
            let qos = RequestQos::with_deadline(Instant::now() + Duration::from_millis(20));
            coord.submit_to_qos(0, vec![i as f32], qos).unwrap()
        })
        .collect();
    for (i, rx) in doomed.into_iter().enumerate() {
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert_eq!(err.kind, FailureKind::Expired, "request {i}: {err}");
    }
    wedge.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
    // Only the wedge request ever reached the backend.
    assert_eq!(ran.load(Ordering::SeqCst), 1, "expired requests must not run");
    assert_eq!(coord.metrics().snapshot().expired, 5);
    // The pool is healthy afterwards: a deadline-free request succeeds.
    let rx = coord.submit(vec![7.0]).unwrap();
    assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap().output, vec![7.0]);
    coord.shutdown();
}

/// Once the service-time estimator is warm, a saturated pool rejects
/// infeasible deadlines at admission — synchronously, before anything
/// is enqueued — while feasible and deadline-free traffic keeps
/// flowing.
#[test]
fn admission_control_sheds_infeasible_work_under_backlog() {
    let slow: SharedBackendFactory = Arc::new(|| {
        Ok(Box::new(FnBackend::new("slow", 1, |inputs: &[Vec<f32>]| {
            std::thread::sleep(Duration::from_millis(30));
            Ok(inputs.to_vec())
        })) as Box<dyn Backend>)
    });
    let coord = Coordinator::start(
        vec![PoolSpec::replicated("slow", 1, slow)],
        CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
    )
    .unwrap();
    // Warm the estimator, then build a backlog.
    for _ in 0..3 {
        coord.submit(vec![0.0]).unwrap().recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
    }
    let backlog: Vec<_> = (0..12).map(|_| coord.submit_to(0, vec![0.0]).unwrap()).collect();
    // ~12 × 30 ms of queue ahead; a 5 ms budget is hopeless.
    let qos = RequestQos::with_deadline(Instant::now() + Duration::from_millis(5));
    match coord.try_submit_to_qos(0, vec![1.0], qos) {
        Err(SubmitError::Expired { estimated_wait }) => {
            assert!(estimated_wait >= Duration::from_millis(5), "wait {estimated_wait:?}");
        }
        other => panic!("expected admission Expired, got {other:?}"),
    }
    // Deadline-free traffic is untouched by admission control.
    let rx = coord.try_submit_to(0, vec![2.0]).unwrap();
    for b in backlog {
        b.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
    }
    assert_eq!(rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap().output, vec![2.0]);
    assert!(coord.metrics().snapshot().expired >= 1);
    coord.shutdown();
}

/// Closing the queue while multiple consumers are mid-drain (some in
/// their straggler window, some actively popping) must deliver every
/// accepted item exactly once — nothing lost, nothing duplicated.
#[test]
fn queue_close_during_partial_drain_loses_zero_accepted_items() {
    let q = Arc::new(BoundedQueue::<u32>::new(256));
    let accepted = Arc::new(AtomicUsize::new(0));

    // Four consumers drain concurrently with small batches and a
    // straggler window, so the close lands mid-drain for some of them.
    let consumers: Vec<_> = (0..4)
        .map(|_| {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    let batch = q.pop_batch(4, Duration::from_millis(1));
                    if batch.is_empty() {
                        return got; // closed + drained
                    }
                    got.extend(batch);
                }
            })
        })
        .collect();

    // Producer pushes monotonically until the close cuts it off; the
    // number of successful pushes is the accepted count.
    let producer = {
        let q = q.clone();
        let accepted = accepted.clone();
        std::thread::spawn(move || {
            for i in 0..100_000u32 {
                if q.push(i).is_err() {
                    return;
                }
                accepted.fetch_add(1, Ordering::SeqCst);
            }
        })
    };

    // Let the drain get going, then close mid-flight.
    std::thread::sleep(Duration::from_millis(20));
    q.close();
    producer.join().unwrap();

    let mut all: Vec<u32> = consumers.into_iter().flat_map(|t| t.join().unwrap()).collect();
    all.sort_unstable();
    let n = accepted.load(Ordering::SeqCst) as u32;
    assert!(n > 0, "producer never got an item in");
    assert_eq!(all.len() as u32, n, "accepted {n} items, delivered {}", all.len());
    for (i, &v) in all.iter().enumerate() {
        assert_eq!(v, i as u32, "item {i} lost or duplicated");
    }
}

/// Same race from the blocking-push side: a producer parked in `push`
/// on a full queue when `close` lands must get `Err` (not hang, not a
/// silent drop), and everything accepted before the close must drain.
#[test]
fn close_unblocks_parked_producer_without_losing_items() {
    let q = Arc::new(BoundedQueue::<u32>::new(4));
    for i in 0..4 {
        q.push(i).unwrap();
    }
    let parked = {
        let q = q.clone();
        std::thread::spawn(move || q.push(99))
    };
    std::thread::sleep(Duration::from_millis(20));
    q.close();
    assert!(parked.join().unwrap().is_err(), "parked push must fail on close");
    // The four accepted items drain exactly once.
    let mut got = Vec::new();
    loop {
        let batch = q.pop_batch(2, Duration::ZERO);
        if batch.is_empty() {
            break;
        }
        got.extend(batch);
    }
    assert_eq!(got, vec![0, 1, 2, 3]);
}
