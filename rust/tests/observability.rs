//! Integration: the observability surface end-to-end over real TCP —
//! Prometheus sidecar scrapes (including racing a live load), the
//! `StatsV2` and `DumpTrace` v4 opcodes, version gating for pre-v4
//! clients, the Health v4 extension counters, trace-ring overflow
//! semantics, and energy figures consistent with the `EnergyModel`
//! applied to the server's aggregate cycle stats.

use edgemlp::coordinator::{AutoscalePolicy, BatchPolicy, CoordinatorConfig};
use edgemlp::fpga::accelerator::AccelConfig;
use edgemlp::fpga::power::EnergyModel;
use edgemlp::nn::activations::Activation;
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::obs::pool_energy;
use edgemlp::quant::spx::SpxConfig;
use edgemlp::serve::wire;
use edgemlp::serve::{
    run_loadgen, BackendKind, Client, EngineConfig, InferReply, LoadGenConfig, ModelRegistry,
    ServeConfig, Server, Status, BACKEND_ANY,
};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

fn mnist_shaped(seed: u64) -> Mlp {
    let mut rng = edgemlp::util::rng::Pcg32::new(seed);
    Mlp::new(
        MlpConfig {
            sizes: vec![784, 32, 10],
            activations: vec![Activation::Sigmoid, Activation::Sigmoid],
        },
        &mut rng,
    )
}

fn start_engine(backends: Vec<BackendKind>, serve: ServeConfig) -> Server {
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    Server::serve(
        registry,
        "127.0.0.1:0",
        EngineConfig {
            replicas: 1,
            backends,
            coordinator: CoordinatorConfig {
                queue_capacity: 1024,
                policy: BatchPolicy::windowed(16, Duration::from_millis(1)),
            },
            serve,
            autoscale: None,
            power_budget_w: None,
        },
    )
    .unwrap()
}

fn probe() -> Vec<f32> {
    vec![0.37f32; 784]
}

/// One HTTP/1.1 scrape of the sidecar; returns (status line, body).
fn scrape(addr: std::net::SocketAddr) -> (String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: edgemlp\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let (head, body) = text.split_once("\r\n\r\n").expect("no header/body split");
    (head.lines().next().unwrap_or("").to_string(), body.to_string())
}

fn sample_value(line: &str) -> f64 {
    line.rsplit(' ').next().unwrap().parse().unwrap()
}

/// Families the exposition must always carry, regardless of backends.
const REQUIRED_FAMILIES: &[&str] = &[
    "edgemlp_uptime_seconds",
    "edgemlp_degraded",
    "edgemlp_degraded_transitions_total",
    "edgemlp_read_timeouts_total",
    "edgemlp_busy_rejected_total",
    "edgemlp_shed_total",
    "edgemlp_expired_total",
    "edgemlp_trace_buffer_events",
    "edgemlp_trace_dropped_total",
    "edgemlp_static_power_watts",
    "edgemlp_loop_registered_connections",
    "edgemlp_loop_ready_events_total",
    "edgemlp_loop_poll_ticks_total",
    "edgemlp_loop_pending_writeback_bytes",
    "edgemlp_loop_timer_wheel_depth",
    "edgemlp_pool_requests_total",
    "edgemlp_pool_samples_total",
    "edgemlp_pool_batches_total",
    "edgemlp_pool_bytes_per_sample",
    "edgemlp_pool_queue_depth",
    "edgemlp_pool_queue_capacity",
    "edgemlp_pool_replicas",
    "edgemlp_pool_replicas_current",
    "edgemlp_pool_replicas_min",
    "edgemlp_pool_replicas_max",
    "edgemlp_autoscale_scale_ups_total",
    "edgemlp_autoscale_scale_downs_total",
    "edgemlp_autoscale_power_watts",
    "edgemlp_autoscale_power_budget_watts",
    "edgemlp_autoscale_power_degraded",
    "edgemlp_request_latency_seconds",
];

/// Structural validity: required families present, every `# HELP`
/// immediately followed by its `# TYPE`, histogram buckets cumulative
/// and capped by `_count`. Mirrors `tools/check_metrics.py` so CI and
/// the test suite enforce the same contract.
fn assert_valid_exposition(text: &str) {
    assert!(text.ends_with('\n'), "exposition must end with a newline");
    for fam in REQUIRED_FAMILIES {
        assert!(
            text.contains(&format!("# TYPE {fam} ")),
            "missing family {fam}\n---\n{text}"
        );
    }
    let lines: Vec<&str> = text.lines().collect();
    for (i, line) in lines.iter().enumerate() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let fam = rest.split(' ').next().unwrap();
            let next = lines.get(i + 1).copied().unwrap_or("");
            assert!(
                next.starts_with(&format!("# TYPE {fam} ")),
                "HELP for {fam} not followed by its TYPE: {next:?}"
            );
        }
    }
    // Histogram invariants per pool: buckets non-decreasing in le
    // order, +Inf bucket equal to the series count.
    let mut buckets: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let mut counts: BTreeMap<String, f64> = BTreeMap::new();
    for line in &lines {
        if let Some(rest) = line.strip_prefix("edgemlp_request_latency_seconds_bucket{pool=\"") {
            let pool = rest.split('"').next().unwrap().to_string();
            buckets.entry(pool).or_default().push(sample_value(line));
        } else if let Some(rest) =
            line.strip_prefix("edgemlp_request_latency_seconds_count{pool=\"")
        {
            let pool = rest.split('"').next().unwrap().to_string();
            counts.insert(pool, sample_value(line));
        }
    }
    assert!(!buckets.is_empty(), "no latency histogram rendered");
    for (pool, vs) in &buckets {
        for w in vs.windows(2) {
            assert!(w[1] >= w[0], "pool {pool}: buckets not cumulative: {vs:?}");
        }
        let count = counts.get(pool).unwrap_or_else(|| panic!("no _count for pool {pool}"));
        assert_eq!(*vs.last().unwrap(), *count, "pool {pool}: +Inf bucket != count");
    }
}

/// The sidecar serves valid exposition while a load generator hammers
/// the engine — every scrape during the run must be a complete,
/// internally consistent snapshot (no torn reads, no 5xx).
#[test]
fn sidecar_scrapes_stay_valid_under_load() {
    let server = start_engine(
        vec![BackendKind::Cpu, BackendKind::PipelineCpu { depth: 3 }],
        ServeConfig { metrics_addr: Some("127.0.0.1:0".into()), ..ServeConfig::default() },
    );
    let addr = server.local_addr();
    let maddr = server.metrics_local_addr().expect("sidecar did not start");

    let load = std::thread::spawn(move || {
        run_loadgen(
            addr,
            LoadGenConfig {
                requests: 3000,
                connections: 4,
                backend: edgemlp::serve::BACKEND_ANY,
                dim: 784,
                pipeline: 8,
                ..LoadGenConfig::default()
            },
        )
        .unwrap()
    });

    let mut nonzero_requests_seen = false;
    for round in 0..20 {
        let (status, body) = scrape(maddr);
        assert!(status.contains("200"), "scrape {round}: {status}");
        assert_valid_exposition(&body);
        if body
            .lines()
            .any(|l| l.starts_with("edgemlp_pool_requests_total{") && sample_value(l) > 0.0)
        {
            nonzero_requests_seen = true;
        }
    }
    let report = load.join().unwrap();
    assert_eq!(report.ok, report.sent, "{report:?}");

    // A post-run scrape accounts for everything the loadgen sent.
    let (_, body) = scrape(maddr);
    assert_valid_exposition(&body);
    let total: f64 = body
        .lines()
        .filter(|l| l.starts_with("edgemlp_pool_requests_total{"))
        .map(sample_value)
        .sum();
    assert!(total >= report.sent as f64, "metrics lost requests: {total} < {}", report.sent);
    assert!(nonzero_requests_seen, "no scrape ever observed live traffic");
    server.shutdown();
}

/// An unknown path is 404, and the sidecar keeps serving afterwards.
#[test]
fn sidecar_unknown_path_is_404() {
    let server = start_engine(
        vec![BackendKind::Cpu],
        ServeConfig { metrics_addr: Some("127.0.0.1:0".into()), ..ServeConfig::default() },
    );
    let maddr = server.metrics_local_addr().unwrap();
    let mut s = TcpStream::connect(maddr).unwrap();
    s.write_all(b"GET /nope HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.read_to_string(&mut buf).unwrap();
    assert!(buf.starts_with("HTTP/1.1 404"), "{buf}");
    let (status, body) = scrape(maddr);
    assert!(status.contains("200"), "{status}");
    assert_valid_exposition(&body);
    server.shutdown();
}

/// `StatsV2` returns the same exposition text in-band — no sidecar
/// needed — and it validates under the same structural rules.
#[test]
fn statsv2_opcode_returns_valid_exposition() {
    let server = start_engine(vec![BackendKind::Cpu], ServeConfig::default());
    let mut client = Client::connect(server.local_addr()).unwrap();
    for _ in 0..25 {
        match client.infer(0, &probe()).unwrap() {
            InferReply::Output(out) => assert_eq!(out.len(), 10),
            other => panic!("{other:?}"),
        }
    }
    let text = client.metrics_text().unwrap();
    assert_valid_exposition(&text);
    let served: f64 = text
        .lines()
        .filter(|l| l.starts_with("edgemlp_pool_requests_total{"))
        .map(sample_value)
        .sum();
    assert!(served >= 25.0, "{served}");
    server.shutdown();
}

/// The readiness event loop exports its gauges on all three surfaces:
/// the human-readable `Stats` summary line, the trailing gauge block
/// on v4 `Health` payloads, and the `edgemlp_loop_*` Prometheus
/// families — with values consistent with a loop that is actually
/// ticking and holding this test's connections registered.
#[test]
fn event_loop_gauges_on_stats_health_and_metrics() {
    let server = start_engine(vec![BackendKind::Cpu], ServeConfig::default());
    let addr = server.local_addr();
    let mut client = Client::connect(addr).unwrap();
    for _ in 0..10 {
        match client.infer(0, &probe()).unwrap() {
            InferReply::Output(out) => assert_eq!(out.len(), 10),
            other => panic!("{other:?}"),
        }
    }

    // Human-readable Stats carries the one-line loop summary.
    let stats = client.stats().unwrap();
    let line = stats
        .lines()
        .find(|l| l.starts_with("event loop: "))
        .unwrap_or_else(|| panic!("no event-loop line in Stats:\n{stats}"));
    for needle in ["registered", "ready events", "ticks", "writeback bytes", "timers"] {
        assert!(line.contains(needle), "{line}");
    }

    // The v4 Health payload ends with the gauge block: this connection
    // is registered with the loop, and the loop has ticked.
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let req = wire::Frame::ok(wire::Opcode::Health, 7, Vec::new());
    wire::write_frame(&mut raw, &req).unwrap();
    let resp = wire::read_frame(&mut raw, wire::DEFAULT_MAX_PAYLOAD).unwrap();
    assert_eq!(resp.status, Status::Ok);
    let (_, gauges) = wire::decode_health_loop(&resp.payload).unwrap();
    let gauges = gauges.expect("v4 Health must carry the loop gauge block");
    assert!(gauges.registered_conns >= 1, "{gauges:?}");
    assert!(gauges.poll_ticks >= 1, "{gauges:?}");
    assert!(gauges.ready_events >= 1, "{gauges:?}");
    drop(raw);

    // A v3 Health payload must not grow the block (framing unchanged
    // for pre-v4 clients).
    let mut old = TcpStream::connect(addr).unwrap();
    old.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let req = wire::Frame::ok(wire::Opcode::Health, 8, Vec::new()).at_version(3);
    wire::write_frame(&mut old, &req).unwrap();
    let resp = wire::read_frame(&mut old, wire::DEFAULT_MAX_PAYLOAD).unwrap();
    assert_eq!(resp.status, Status::Ok);
    let (_, gauges) = wire::decode_health_loop(&resp.payload).unwrap();
    assert_eq!(gauges, None, "v3 Health must omit the gauge block");
    drop(old);

    // And the Prometheus families, already pinned by REQUIRED_FAMILIES
    // via assert_valid_exposition — additionally check live values.
    let text = client.metrics_text().unwrap();
    assert_valid_exposition(&text);
    let find = |fam: &str| {
        text.lines()
            .find(|l| l.starts_with(&format!("{fam} ")))
            .map(sample_value)
            .unwrap_or_else(|| panic!("no sample for {fam}\n---\n{text}"))
    };
    assert!(find("edgemlp_loop_registered_connections") >= 1.0);
    assert!(find("edgemlp_loop_poll_ticks_total") >= 1.0);
    assert!(find("edgemlp_loop_ready_events_total") >= 1.0);
    assert!(find("edgemlp_loop_pending_writeback_bytes") >= 0.0);
    assert!(find("edgemlp_loop_timer_wheel_depth") >= 0.0);
    server.shutdown();
}

/// `DumpTrace` over TCP for a stage-pipelined backend: the Chrome
/// trace JSON must carry the full request lifecycle — connection
/// instants, queue spans, worker infer spans, per-request writebacks,
/// and one per-stage "run" span row per pipeline stage.
#[test]
fn dump_trace_carries_per_stage_spans() {
    let server =
        start_engine(vec![BackendKind::PipelineCpu { depth: 3 }], ServeConfig::default());
    let mut client = Client::connect(server.local_addr()).unwrap();
    for _ in 0..30 {
        match client.infer(0, &probe()).unwrap() {
            InferReply::Output(out) => assert_eq!(out.len(), 10),
            other => panic!("{other:?}"),
        }
    }
    let json = client.dump_trace().unwrap();
    assert!(json.starts_with("{\"traceEvents\":["), "{json}");
    assert!(json.ends_with('}'), "{json}");
    // Connection lifecycle instants.
    assert!(json.contains("\"name\":\"accept\",\"cat\":\"conn\""), "{json}");
    assert!(json.contains("\"name\":\"decode\",\"cat\":\"conn\""), "{json}");
    // Queueing: enqueue instants plus queued-duration spans.
    assert!(json.contains("\"name\":\"enqueue\",\"cat\":\"queue\""), "{json}");
    assert!(json.contains("\"name\":\"queued\",\"cat\":\"queue\",\"ph\":\"X\""), "{json}");
    // Worker execution span and per-request writebacks.
    assert!(json.contains("\"name\":\"infer\",\"cat\":\"worker\",\"ph\":\"X\""), "{json}");
    assert!(json.contains("\"name\":\"writeback\",\"cat\":\"worker\""), "{json}");
    // Per-stage rows: the 784→32→10 MLP pipelines as 2 layer stages,
    // each a named thread row with "run" duration spans.
    assert!(json.contains("\"name\":\"run\",\"cat\":\"stage\",\"ph\":\"X\""), "{json}");
    for stage in ["layer0", "layer1"] {
        assert!(json.contains(stage), "stage {stage} missing from trace rows: {json}");
    }
    assert!(json.contains("\"dropped_events\":\"0\""), "{json}");
    server.shutdown();
}

/// `trace_capacity` bounds the ring: under overflow the newest events
/// win, `len()` stays pinned at capacity, and the drop count is
/// surfaced both in the export and on /metrics.
#[test]
fn trace_ring_overflow_drops_oldest_and_reports_it() {
    let server = start_engine(
        vec![BackendKind::Cpu],
        ServeConfig { trace_capacity: 64, ..ServeConfig::default() },
    );
    let mut client = Client::connect(server.local_addr()).unwrap();
    for _ in 0..200 {
        match client.infer(0, &probe()).unwrap() {
            InferReply::Output(_) => {}
            other => panic!("{other:?}"),
        }
    }
    let tracer = server.tracer();
    assert_eq!(tracer.len(), 64, "ring must cap at capacity");
    assert!(tracer.dropped() > 0, "overflow must count drops");
    let json = client.dump_trace().unwrap();
    assert!(!json.contains("\"dropped_events\":\"0\""), "{json}");
    let text = client.metrics_text().unwrap();
    let dropped_line = text
        .lines()
        .find(|l| l.starts_with("edgemlp_trace_dropped_total "))
        .expect("no trace_dropped family");
    assert!(sample_value(dropped_line) > 0.0, "{dropped_line}");
    let buffered_line = text
        .lines()
        .find(|l| l.starts_with("edgemlp_trace_buffer_events "))
        .unwrap();
    assert_eq!(sample_value(buffered_line), 64.0, "{buffered_line}");
    server.shutdown();
}

/// With `trace_capacity: 0` tracing is fully disabled: `DumpTrace`
/// still answers (an empty trace), and nothing accumulates.
#[test]
fn trace_capacity_zero_disables_tracing() {
    let server = start_engine(
        vec![BackendKind::Cpu],
        ServeConfig { trace_capacity: 0, ..ServeConfig::default() },
    );
    let mut client = Client::connect(server.local_addr()).unwrap();
    for _ in 0..10 {
        client.infer(0, &probe()).unwrap();
    }
    assert_eq!(server.tracer().len(), 0);
    assert_eq!(server.tracer().dropped(), 0);
    let json = client.dump_trace().unwrap();
    assert!(json.contains("\"traceEvents\":[]"), "{json}");
    server.shutdown();
}

/// The v4 opcodes are version-gated: a v3 client sending `StatsV2` or
/// `DumpTrace` gets `BadRequest` framed at v3, and the connection —
/// plus its pre-v4 feature set — keeps working.
#[test]
fn v4_opcodes_rejected_below_v4() {
    let server = start_engine(vec![BackendKind::Cpu], ServeConfig::default());
    let mut raw = TcpStream::connect(server.local_addr()).unwrap();
    for (id, opcode) in [(1u64, wire::Opcode::StatsV2), (2, wire::Opcode::DumpTrace)] {
        wire::write_frame(&mut raw, &wire::Frame::ok(opcode, id, Vec::new()).at_version(3))
            .unwrap();
        let resp = wire::read_frame(&mut raw, wire::DEFAULT_MAX_PAYLOAD).unwrap();
        assert_eq!(resp.status, Status::BadRequest, "{opcode:?}: {resp:?}");
        assert_eq!(resp.request_id, id);
        assert_eq!(resp.version, 3, "gating reply must echo the request version");
        assert!(resp.message().contains("protocol"), "{}", resp.message());
    }
    // The same connection still serves v3 traffic.
    wire::write_frame(
        &mut raw,
        &wire::Frame::ok(wire::Opcode::Ping, 3, Vec::new()).at_version(3),
    )
    .unwrap();
    assert_eq!(wire::read_frame(&mut raw, wire::DEFAULT_MAX_PAYLOAD).unwrap().status, Status::Ok);
    // And those rejections were themselves counted by cause.
    let mut client = Client::connect(server.local_addr()).unwrap();
    let health = client.health().unwrap();
    let gated: u64 = health
        .bad_requests
        .iter()
        .filter(|(c, _)| c == "version_gate")
        .map(|(_, n)| *n)
        .sum();
    assert!(gated >= 2, "{:?}", health.bad_requests);
    server.shutdown();
}

/// The Health v4 extension end-to-end: busy rejections (connection cap)
/// and bad requests (wrong input dimension) are counted, labeled by
/// cause, and visible to a v4 client — while a raw v3 health request
/// still decodes cleanly without the extension block.
#[test]
fn health_extension_counts_busy_and_bad_requests() {
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    let server = Server::serve(
        registry,
        "127.0.0.1:0",
        EngineConfig {
            replicas: 1,
            backends: vec![BackendKind::Cpu],
            coordinator: CoordinatorConfig {
                queue_capacity: 64,
                policy: BatchPolicy::immediate(8),
            },
            serve: ServeConfig { max_conns: 1, ..ServeConfig::default() },
            autoscale: None,
            power_budget_w: None,
        },
    )
    .unwrap();
    let addr = server.local_addr();
    let mut client = Client::connect(addr).unwrap();
    client.ping().unwrap();

    // Over-limit connection → Busy frame → busy_rejected counter.
    let mut second = TcpStream::connect(addr).unwrap();
    let frame = wire::read_frame(&mut second, wire::DEFAULT_MAX_PAYLOAD).unwrap();
    assert_eq!(frame.status, Status::Busy);
    drop(second);

    // Wrong input dimension → BadRequest with cause "input_dim".
    match client.infer(0, &[1.0, 2.0, 3.0]).unwrap() {
        InferReply::Failed { status, .. } => assert_eq!(status, Status::BadRequest),
        other => panic!("{other:?}"),
    }

    let health = client.health().unwrap();
    assert!(health.busy_rejected >= 1, "{health:?}");
    let input_dim: u64 = health
        .bad_requests
        .iter()
        .filter(|(c, _)| c == "input_dim")
        .map(|(_, n)| *n)
        .sum();
    assert!(input_dim >= 1, "{:?}", health.bad_requests);

    // A v3 client's Health decode must not see the extension block.
    // max_conns is 1 and `client` holds the slot — free it, then retry
    // until the acceptor reclaims the slot (Busy frames race the drop).
    drop(client);
    let mut resp = None;
    for _ in 0..100 {
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let req = wire::Frame::ok(wire::Opcode::Health, 9, Vec::new()).at_version(3);
        if wire::write_frame(&mut raw, &req).is_err() {
            std::thread::sleep(Duration::from_millis(20));
            continue;
        }
        match wire::read_frame(&mut raw, wire::DEFAULT_MAX_PAYLOAD) {
            Ok(f) if f.status == Status::Busy => std::thread::sleep(Duration::from_millis(20)),
            Ok(f) => {
                resp = Some(f);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let resp = resp.expect("connection slot never freed");
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(resp.version, 3);
    let report = wire::decode_health(&resp.payload).unwrap();
    assert_eq!(report.busy_rejected, 0, "v3 payload must omit the extension");
    assert!(report.bad_requests.is_empty());
    server.shutdown();
}

/// The per-pool weight-footprint gauge end-to-end: an engine mixing
/// f32, int8 and int4 pools must expose one `bytes_per_sample` sample
/// per pool, strictly ordered int4 < int8 < f32 — and the quantized
/// pools still answer correct-looking inferences.
#[test]
fn pool_bytes_per_sample_orders_precisions() {
    let server = start_engine(
        vec![BackendKind::Cpu, BackendKind::Int8, BackendKind::Int4],
        ServeConfig::default(),
    );
    let mut client = Client::connect(server.local_addr()).unwrap();
    // One inference per explicit backend index: every pool serves.
    for backend in 0..3u32 {
        match client.infer(backend, &probe()).unwrap() {
            InferReply::Output(out) => assert_eq!(out.len(), 10, "backend {backend}"),
            other => panic!("backend {backend}: {other:?}"),
        }
    }
    let text = client.metrics_text().unwrap();
    assert_valid_exposition(&text);
    let bytes = |pool: &str| -> f64 {
        text.lines()
            .find(|l| l.starts_with(&format!("edgemlp_pool_bytes_per_sample{{pool=\"{pool}\"}}")))
            .map(sample_value)
            .unwrap_or_else(|| panic!("no bytes_per_sample for {pool}\n{text}"))
    };
    let (f32b, i8b, i4b) = (bytes("cpu/default"), bytes("int8/default"), bytes("int4/default"));
    assert!(i4b < i8b, "int4 {i4b} !< int8 {i8b}");
    assert!(i8b < f32b, "int8 {i8b} !< f32 {f32b}");
    // The 784→32→10 f32 model weighs 4·(784·32+32 + 32·10+10) bytes.
    assert_eq!(f32b, 4.0 * ((784.0 * 32.0 + 32.0) + (32.0 * 10.0 + 10.0)));
    // The human-readable Stats lines carry the same figures.
    let stats = client.stats().unwrap();
    assert!(stats.contains(&format!("bytes_per_sample={}", i4b as u64)), "{stats}");
    server.shutdown();
}

/// Energy accounting end-to-end on a simulated SPx pool: nonzero
/// joules/request on Stats, the Prometheus families, and — the
/// consistency contract — exactly `EnergyModel::default_fpga()` applied
/// to the pool's aggregate `CycleStats`.
#[test]
fn fpga_pool_reports_consistent_nonzero_energy() {
    let server =
        start_engine(vec![BackendKind::FpgaSim(AccelConfig::default_fpga())], ServeConfig::default());
    let mut client = Client::connect(server.local_addr()).unwrap();
    let n = 40;
    for _ in 0..n {
        match client.infer(0, &probe()).unwrap() {
            InferReply::Output(out) => assert_eq!(out.len(), 10),
            other => panic!("{other:?}"),
        }
    }

    // Traffic has quiesced (closed loop): snapshot and scrape agree.
    let snap = server.metrics().snapshot();
    let pool = &snap.backends["fpga/default"];
    assert_eq!(pool.requests, n);
    let want = pool_energy(&EnergyModel::default_fpga(), pool, 1.0);
    assert!(want.dynamic_j > 0.0, "simulated SPx pool must draw dynamic energy");
    assert!(want.j_per_request > 0.0);

    // Human-readable Stats lines.
    let stats = client.stats().unwrap();
    assert!(stats.contains("energy fpga/default:"), "{stats}");
    assert!(stats.contains("J/req"), "{stats}");
    assert!(stats.contains("energy static: 2.50 W"), "{stats}");

    // Prometheus families carry the same numbers.
    let text = client.metrics_text().unwrap();
    let find = |name: &str| -> f64 {
        text.lines()
            .find(|l| l.starts_with(&format!("{name}{{pool=\"fpga/default\"}}")))
            .map(sample_value)
            .unwrap_or_else(|| panic!("no {name} sample\n{text}"))
    };
    let joules = find("edgemlp_pool_energy_joules_total");
    let per_req = find("edgemlp_pool_energy_joules_per_request");
    let mj_per_sample = find("edgemlp_pool_energy_mj_per_sample");
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-3 * b.abs().max(1e-12);
    assert!(close(joules, want.dynamic_j), "{joules} vs {}", want.dynamic_j);
    assert!(close(per_req, want.j_per_request), "{per_req} vs {}", want.j_per_request);
    assert!(close(mj_per_sample, want.mj_per_sample), "{mj_per_sample} vs {}", want.mj_per_sample);

    // Pure-CPU pools carry no dynamic energy: the absence is the
    // paper's comparison point, and the model covers SPx only.
    assert!(!stats.contains("energy cpu/"), "{stats}");
    server.shutdown();
}

/// The power-budget loop end-to-end: with a budget below the 2.5 W
/// static floor, the gate must latch accuracy-for-power degradation,
/// re-route `BACKEND_ANY` onto the cheapest quantized pool, surface the
/// state on the Health autoscale block and the Prometheus exposition —
/// and shed nothing while doing it.
#[test]
fn power_budget_degrades_routing_before_shedding() {
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    let server = Server::serve(
        registry,
        "127.0.0.1:0",
        EngineConfig {
            replicas: 1,
            backends: vec![
                BackendKind::FpgaSim(AccelConfig::default_fpga()),
                BackendKind::Int8,
                BackendKind::Int4,
            ],
            coordinator: CoordinatorConfig {
                queue_capacity: 1024,
                policy: BatchPolicy::windowed(16, Duration::from_millis(1)),
            },
            serve: ServeConfig::default(),
            autoscale: Some(AutoscalePolicy {
                sample_every: Duration::from_millis(10),
                dwell: Duration::from_millis(30),
                ..AutoscalePolicy::band(1, 2)
            }),
            power_budget_w: Some(1.0),
        },
    )
    .unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();

    // The static floor alone (2.5 W) exceeds the 1 W budget, so the
    // gate must latch after its dwell. Poll the Health autoscale block.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let auto = loop {
        let (_, _, auto) = client.health_full().unwrap();
        let auto = auto.expect("v4 health must carry the autoscale block");
        assert!(auto.enabled);
        if auto.power_degraded {
            break auto;
        }
        assert!(std::time::Instant::now() < deadline, "budget gate never latched: {auto:?}");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!((auto.min_replicas, auto.max_replicas), (1, 2));
    assert_eq!(auto.budget_mw, 1_000);
    assert!(auto.power_mw >= 2_500, "power below the static floor: {auto:?}");

    // Degraded `BACKEND_ANY` traffic lands on the cheapest pool (int4).
    let n: u64 = 24;
    for _ in 0..n {
        match client.infer(BACKEND_ANY, &probe()).unwrap() {
            InferReply::Output(out) => assert_eq!(out.len(), 10),
            other => panic!("{other:?}"),
        }
    }
    let snap = server.metrics().snapshot();
    assert_eq!(snap.backends["int4/default"].requests, n, "ANY must route to int4");
    let (health, _, _) = client.health_full().unwrap();
    assert!(health.degraded, "power degrade must show on the health flag");
    let shed: u64 = health.pools.iter().map(|p| p.shed).sum();
    assert_eq!(shed, 0, "degradation must precede shedding");

    // The exposition carries the same story.
    let text = client.metrics_text().unwrap();
    assert_valid_exposition(&text);
    let scalar = |name: &str| -> f64 {
        text.lines()
            .find(|l| l.starts_with(name) && !l.starts_with("# "))
            .map(sample_value)
            .unwrap_or_else(|| panic!("no {name} sample\n{text}"))
    };
    assert_eq!(scalar("edgemlp_autoscale_power_degraded "), 1.0);
    assert_eq!(scalar("edgemlp_autoscale_power_budget_watts "), 1.0);
    assert!(scalar("edgemlp_autoscale_power_watts ") >= 2.5);
    let band = |name: &str| -> f64 {
        text.lines()
            .find(|l| l.starts_with(&format!("{name}{{")))
            .map(sample_value)
            .unwrap_or_else(|| panic!("no {name} sample\n{text}"))
    };
    assert_eq!(band("edgemlp_pool_replicas_min"), 1.0);
    assert_eq!(band("edgemlp_pool_replicas_max"), 2.0);
    server.shutdown();
}
