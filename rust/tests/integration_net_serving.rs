//! Integration: the network serving subsystem end-to-end over real TCP
//! — load generator traffic, mixed single/batch frames, mid-run model
//! swaps, multi-model routing with per-slot swaps, v1 protocol
//! compatibility, load shedding under saturation, and protocol error
//! handling.

use edgemlp::coordinator::backend::{Backend, FnBackend};
use edgemlp::coordinator::server::BackendFactory;
use edgemlp::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use edgemlp::nn::activations::Activation;
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::serve::wire;
use edgemlp::serve::{
    run_loadgen, swappable_cpu_factory, BackendKind, BatchReply, Client, EngineConfig,
    InferReply, LoadGenConfig, ModelRegistry, ServeConfig, Server, Status,
};
use edgemlp::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

/// An MNIST-shaped model (784 in, 10 out) small enough for debug-build
/// test runs; weights are random — serving correctness does not need a
/// trained network, only a deterministic one.
fn mnist_shaped(seed: u64) -> Mlp {
    let mut rng = Pcg32::new(seed);
    Mlp::new(
        MlpConfig {
            sizes: vec![784, 32, 10],
            activations: vec![Activation::Sigmoid, Activation::Sigmoid],
        },
        &mut rng,
    )
}

/// Server with a swappable CPU backend pool, "default" (seed 1) active
/// and "retrained" (seed 2) registered as a swap candidate.
fn start_model_server(
    queue_capacity: usize,
    policy: BatchPolicy,
) -> (Server, Arc<ModelRegistry>) {
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    registry.register_mlp("retrained", mnist_shaped(2));
    let coord = Coordinator::start(
        vec![("cpu".into(), swappable_cpu_factory(registry.default_slot()))],
        CoordinatorConfig { queue_capacity, policy },
    )
    .unwrap();
    let server =
        Server::start(coord, registry.clone(), "127.0.0.1:0", ServeConfig::default()).unwrap();
    (server, registry)
}

fn probe() -> Vec<f32> {
    vec![0.37f32; 784]
}

fn assert_vec_close(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol, "elem {i}: {x} vs {y}");
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn ping_and_stats_roundtrip() {
    let (server, _registry) =
        start_model_server(256, BatchPolicy::windowed(16, Duration::from_millis(1)));
    let mut client = Client::connect(server.local_addr()).unwrap();
    client.ping().unwrap();
    match client.infer(0, &probe()).unwrap() {
        InferReply::Output(out) => assert_eq!(out.len(), 10),
        other => panic!("expected output, got {other:?}"),
    }
    let stats = client.stats().unwrap();
    assert!(stats.contains("default v1"), "{stats}");
    assert!(stats.contains("pool cpu"), "{stats}");
    assert!(stats.contains("p50="), "{stats}");
    assert!(stats.contains("p99="), "{stats}");
    assert!(stats.contains("p99.9="), "{stats}");
    server.shutdown();
}

/// The acceptance scenario: ≥10k mixed single/batch requests over TCP
/// with a mid-run `SwapModel`, zero lost responses, and served outputs
/// that verifiably change with the swap.
#[test]
fn e2e_mixed_traffic_with_midrun_swap() {
    let (server, _registry) =
        start_model_server(4096, BatchPolicy::windowed(64, Duration::from_millis(1)));
    let addr = server.local_addr();
    let v1 = mnist_shaped(1);
    let v2 = mnist_shaped(2);
    let want1 = v1.forward_one(&probe());
    let want2 = v2.forward_one(&probe());
    assert!(
        max_abs_diff(&want1, &want2) > 1e-3,
        "test models must disagree on the probe"
    );

    let mut ctl = Client::connect(addr).unwrap();
    match ctl.infer(0, &probe()).unwrap() {
        InferReply::Output(out) => assert_vec_close(&out, &want1, 1e-5),
        other => panic!("probe before swap: {other:?}"),
    }

    // Single-sample pipelined traffic on 6 connections…
    let single = std::thread::spawn(move || {
        run_loadgen(
            addr,
            LoadGenConfig {
                requests: 7200,
                connections: 6,
                backend: 0,
                dim: 784,
                pipeline: 8,
                ..LoadGenConfig::default()
            },
        )
        .unwrap()
    });
    // …plus InferBatch traffic on 2 more (mixed frame types).
    let batched = std::thread::spawn(move || {
        run_loadgen(
            addr,
            LoadGenConfig {
                requests: 2880,
                connections: 2,
                backend: 0,
                dim: 784,
                batch: 16,
                ..LoadGenConfig::default()
            },
        )
        .unwrap()
    });

    // Swap while traffic is in flight.
    std::thread::sleep(Duration::from_millis(30));
    let ack = ctl.swap_model("retrained").unwrap();
    assert!(ack.contains("retrained"), "{ack}");

    let single = single.join().unwrap();
    let batched = batched.join().unwrap();
    let total_sent = single.sent + batched.sent;
    assert!(total_sent >= 10_000, "only {total_sent} requests sent");
    // Zero lost responses: every request came back, none shed (the
    // queue is deep and clients are closed-loop), none errored.
    assert_eq!(single.ok, single.sent, "single: {single:?}");
    assert_eq!(batched.ok, batched.sent, "batched: {batched:?}");
    assert_eq!(single.shed + batched.shed, 0);
    assert_eq!(single.errors + batched.errors, 0);

    // The swap took effect without dropping anything.
    match ctl.infer(0, &probe()).unwrap() {
        InferReply::Output(out) => {
            assert_vec_close(&out, &want2, 1e-5);
            assert!(
                max_abs_diff(&out, &want1) > 1e-3,
                "served outputs did not change after swap"
            );
        }
        other => panic!("probe after swap: {other:?}"),
    }

    // Server-side accounting agrees: nothing vanished.
    let stats = ctl.stats().unwrap();
    assert!(stats.contains("generation 2"), "{stats}");
    let snap = server.metrics().snapshot();
    assert!(snap.backends["cpu"].requests >= total_sent as u64);
    assert_eq!(snap.rejected, 0);
    server.shutdown();
}

/// The multi-model acceptance scenario: two models served concurrently
/// by a replicated engine, every response verified against the network
/// it should have come from (no cross-routing), one model swapped
/// mid-run without disturbing the other, zero lost responses.
#[test]
fn two_models_concurrent_traffic_with_independent_swap() {
    let alpha_v1 = mnist_shaped(11);
    let alpha_v2 = mnist_shaped(12);
    let beta = mnist_shaped(13);
    let registry = ModelRegistry::new("alpha", alpha_v1.clone(), SpxConfig::sp2(5));
    registry.register_mlp("beta", beta.clone());
    registry.add_slot("beta").unwrap();
    registry.register_mlp("alpha-v2", alpha_v2.clone());
    let server = Server::serve(
        registry.clone(),
        "127.0.0.1:0",
        EngineConfig {
            replicas: 2,
            backends: vec![BackendKind::Cpu],
            coordinator: CoordinatorConfig {
                queue_capacity: 4096,
                policy: BatchPolicy::windowed(32, Duration::from_millis(1)),
            },
            serve: ServeConfig::default(),
            autoscale: None,
            power_budget_w: None,
        },
    )
    .unwrap();
    let addr = server.local_addr();

    // Distinct random probes per driver keep the verification honest.
    let n_per_driver = 1200usize;
    let window = 8usize;
    let alpha_want_v1 = Arc::new(alpha_v1);
    let alpha_want_v2 = Arc::new(alpha_v2);
    let beta_want = Arc::new(beta);

    // Drive `n` pipelined requests against `model`, verifying each
    // response with `verify(probe, output)`.
    fn drive(
        addr: std::net::SocketAddr,
        model: &str,
        n: usize,
        window: usize,
        seed: u64,
        mut verify: impl FnMut(&[f32], &[f32]),
    ) -> usize {
        let mut client = Client::connect(addr).unwrap();
        let mut rng = Pcg32::new(seed);
        let mut in_flight: std::collections::VecDeque<Vec<f32>> = Default::default();
        let mut done = 0usize;
        let drain =
            |client: &mut Client, in_flight: &mut std::collections::VecDeque<Vec<f32>>| {
                let x = in_flight.pop_front().unwrap();
                match client.recv_infer().unwrap().1 {
                    InferReply::Output(out) => (x, out),
                    other => panic!("{other:?}"),
                }
            };
        for _ in 0..n {
            if in_flight.len() >= window {
                let (x, out) = drain(&mut client, &mut in_flight);
                verify(&x, &out);
                done += 1;
            }
            let x: Vec<f32> = (0..784).map(|_| rng.uniform() as f32).collect();
            client.send_infer_model(0, model, &x).unwrap();
            in_flight.push_back(x);
        }
        while !in_flight.is_empty() {
            let (x, out) = drain(&mut client, &mut in_flight);
            verify(&x, &out);
            done += 1;
        }
        done
    }

    // Driver for model "beta": runs continuously through the whole test
    // — including across alpha's swap — and every output must match
    // beta's network (a cross-routed response would carry alpha's
    // weights and fail loudly).
    let beta_driver = {
        let beta_want = beta_want.clone();
        std::thread::spawn(move || {
            drive(addr, "beta", n_per_driver, window, 501, |x, out| {
                assert_vec_close(out, &beta_want.forward_one(x), 1e-5)
            })
        })
    };

    // Driver for model "alpha", phased around the swap so the
    // verification is exact: phase 1 must be served by alpha v1, and
    // phase 2 (every request submitted after the swap ack, window
    // drained at the barrier) must be served by alpha-v2. The
    // in-flight-swap path is covered by `e2e_mixed_traffic_with_midrun_swap`.
    let (phase1_done_tx, phase1_done_rx) = std::sync::mpsc::channel::<()>();
    let (swapped_tx, swapped_rx) = std::sync::mpsc::channel::<()>();
    let alpha_driver = {
        let (v1, v2) = (alpha_want_v1.clone(), alpha_want_v2.clone());
        std::thread::spawn(move || {
            let half = n_per_driver / 2;
            let done1 = drive(addr, "alpha", half, window, 502, |x, out| {
                assert_vec_close(out, &v1.forward_one(x), 1e-5)
            });
            phase1_done_tx.send(()).unwrap();
            swapped_rx.recv().unwrap();
            let done2 = drive(addr, "alpha", n_per_driver - half, window, 503, |x, out| {
                assert_vec_close(out, &v2.forward_one(x), 1e-5)
            });
            done1 + done2
        })
    };

    // Swap alpha's slot once its phase-1 traffic is verified; beta's
    // traffic keeps flowing throughout and its slot must not move.
    phase1_done_rx.recv_timeout(Duration::from_secs(60)).unwrap();
    let mut ctl = Client::connect(addr).unwrap();
    let ack = ctl.swap_model_into("alpha", "alpha-v2").unwrap();
    assert!(ack.contains("alpha-v2"), "{ack}");
    swapped_tx.send(()).unwrap();

    let beta_done = beta_driver.join().unwrap();
    let alpha_done = alpha_driver.join().unwrap();
    // Zero lost responses on both models.
    assert_eq!(beta_done, n_per_driver);
    assert_eq!(alpha_done, n_per_driver);

    // Post-run probes: alpha serves v2, beta untouched.
    let x = probe();
    match ctl.infer_model(0, "alpha", &x).unwrap() {
        InferReply::Output(out) => assert_vec_close(&out, &alpha_want_v2.forward_one(&x), 1e-5),
        other => panic!("alpha post-probe: {other:?}"),
    }
    match ctl.infer_model(0, "beta", &x).unwrap() {
        InferReply::Output(out) => assert_vec_close(&out, &beta_want.forward_one(&x), 1e-5),
        other => panic!("beta post-probe: {other:?}"),
    }

    // ListModels reflects the independent generations.
    let models = ctl.list_models().unwrap();
    assert_eq!(models.len(), 2);
    let alpha = models.iter().find(|m| m.slot == "alpha").unwrap();
    let beta_info = models.iter().find(|m| m.slot == "beta").unwrap();
    assert_eq!(alpha.model, "alpha-v2");
    assert_eq!(alpha.generation, 2);
    assert_eq!(beta_info.model, "beta");
    assert_eq!(beta_info.generation, 1);

    // Per-pool metrics carry the per-model labels, and nothing was
    // shed or lost server-side.
    let snap = server.metrics().snapshot();
    assert!(snap.backends["cpu/alpha"].requests >= n_per_driver as u64);
    assert!(snap.backends["cpu/beta"].requests >= n_per_driver as u64);
    assert_eq!(snap.rejected, 0);
    server.shutdown();
}

/// A v1-framed client (no model fields anywhere) must be served
/// correctly by the v2 server: Ping, Infer, InferBatch and the
/// single-string SwapModel all round-trip, and every response comes
/// back framed at version 1.
#[test]
fn v1_client_compat_round_trip() {
    let (server, _registry) =
        start_model_server(256, BatchPolicy::windowed(16, Duration::from_millis(1)));
    let want_v1 = mnist_shaped(1).forward_one(&probe());
    let want_v2 = mnist_shaped(2).forward_one(&probe());

    let mut raw = std::net::TcpStream::connect(server.local_addr()).unwrap();
    let send = |raw: &mut std::net::TcpStream, frame: &wire::Frame| {
        wire::write_frame(raw, &frame.clone().at_version(1)).unwrap();
    };
    let recv = |raw: &mut std::net::TcpStream| -> wire::Frame {
        let frame = wire::read_frame(raw, wire::DEFAULT_MAX_PAYLOAD).unwrap();
        assert_eq!(frame.version, 1, "server answered a v1 request with {frame:?}");
        frame
    };

    // Ping.
    send(&mut raw, &wire::Frame::ok(wire::Opcode::Ping, 1, b"v1".to_vec()));
    let pong = recv(&mut raw);
    assert_eq!(pong.status, Status::Ok);
    assert_eq!(pong.payload, b"v1");

    // Infer with the v1 payload layout (no model name).
    send(
        &mut raw,
        &wire::Frame::ok(wire::Opcode::Infer, 2, wire::encode_infer_v1(0, &probe())),
    );
    let resp = recv(&mut raw);
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(resp.request_id, 2);
    assert_vec_close(&wire::decode_outputs(&resp.payload).unwrap(), &want_v1, 1e-5);

    // InferBatch, v1 layout.
    let samples = vec![probe(), probe(), probe()];
    send(
        &mut raw,
        &wire::Frame::ok(
            wire::Opcode::InferBatch,
            3,
            wire::encode_infer_batch_v1(0, &samples).unwrap(),
        ),
    );
    let resp = recv(&mut raw);
    assert_eq!(resp.status, Status::Ok);
    let rows = wire::decode_batch_outputs(&resp.payload).unwrap();
    assert_eq!(rows.len(), 3);
    for row in &rows {
        assert_vec_close(row, &want_v1, 1e-5);
    }

    // v1 single-string SwapModel targets the default slot.
    send(
        &mut raw,
        &wire::Frame::ok(wire::Opcode::SwapModel, 4, wire::encode_str("retrained")),
    );
    let resp = recv(&mut raw);
    assert_eq!(resp.status, Status::Ok, "{}", resp.message());

    // The swap is visible to the same v1 client.
    send(
        &mut raw,
        &wire::Frame::ok(wire::Opcode::Infer, 5, wire::encode_infer_v1(0, &probe())),
    );
    let resp = recv(&mut raw);
    assert_eq!(resp.status, Status::Ok);
    assert_vec_close(&wire::decode_outputs(&resp.payload).unwrap(), &want_v2, 1e-5);

    // ListModels is v2-only: a v1 frame gets BadRequest, and the
    // connection survives.
    send(&mut raw, &wire::Frame::ok(wire::Opcode::ListModels, 6, Vec::new()));
    let resp = recv(&mut raw);
    assert_eq!(resp.status, Status::BadRequest);
    send(&mut raw, &wire::Frame::ok(wire::Opcode::Ping, 7, Vec::new()));
    assert_eq!(recv(&mut raw).status, Status::Ok);

    server.shutdown();
}

/// Malformed v2 model-name lengths (truncated names, lengths past the
/// cap) are answered with `BadRequest` frames, not crashes — and a
/// syntactically valid frame carrying them never poisons the
/// connection's other traffic.
#[test]
fn malformed_model_name_lengths_are_bad_requests() {
    let (server, _registry) = start_model_server(64, BatchPolicy::immediate(8));
    let mut client = Client::connect(server.local_addr()).unwrap();
    let mut raw = std::net::TcpStream::connect(server.local_addr()).unwrap();

    let good = wire::encode_infer(0, "model", &probe()).unwrap();
    // Truncated (length points past the name), oversized (past the
    // cap), and length-runs-into-payload variants.
    for lied in [200u16, 256, 1000, u16::MAX] {
        let mut payload = good.clone();
        payload[4..6].copy_from_slice(&lied.to_le_bytes());
        wire::write_frame(
            &mut raw,
            &wire::Frame::ok(wire::Opcode::Infer, lied as u64, payload),
        )
        .unwrap();
        let resp = wire::read_frame(&mut raw, wire::DEFAULT_MAX_PAYLOAD).unwrap();
        assert_eq!(resp.status, Status::BadRequest, "lied length {lied}: {resp:?}");
        assert_eq!(resp.request_id, lied as u64);
    }
    // The abused connection still works (payload errors are not framing
    // errors), and an innocent concurrent client was never affected.
    wire::write_frame(&mut raw, &wire::Frame::ok(wire::Opcode::Ping, 9, Vec::new())).unwrap();
    assert_eq!(
        wire::read_frame(&mut raw, wire::DEFAULT_MAX_PAYLOAD).unwrap().status,
        Status::Ok
    );
    match client.infer(0, &probe()).unwrap() {
        InferReply::Output(out) => assert_eq!(out.len(), 10),
        other => panic!("innocent client poisoned: {other:?}"),
    }
    // An unknown (but well-formed) model name is UnknownModel.
    match client.infer_model(0, "nope", &probe()).unwrap() {
        InferReply::Failed { status, message } => {
            assert_eq!(status, Status::UnknownModel);
            assert!(message.contains("nope"), "{message}");
        }
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    server.shutdown();
}

/// A saturated coordinator queue must answer with `Backpressure` error
/// frames — the wire mapping of `SubmitError::Backpressure` — while
/// accepted requests still complete.
#[test]
fn saturation_sheds_with_backpressure_frames() {
    // Slow single-slot backend behind a capacity-1 queue.
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    let slow: BackendFactory = Box::new(|| {
        Ok(Box::new(FnBackend::new("slow", 1, |inputs: &[Vec<f32>]| {
            std::thread::sleep(Duration::from_millis(20));
            Ok(inputs.to_vec())
        })) as Box<dyn Backend>)
    });
    let coord = Coordinator::start(
        vec![("slow".into(), slow)],
        CoordinatorConfig { queue_capacity: 1, policy: BatchPolicy::immediate(1) },
    )
    .unwrap();
    let server =
        Server::start(coord, registry, "127.0.0.1:0", ServeConfig::default()).unwrap();

    let mut client = Client::connect(server.local_addr()).unwrap();
    let n = 40;
    let x = probe(); // dims must match the registry's model (784)
    for _ in 0..n {
        client.send_infer(0, &x).unwrap();
    }
    let (mut ok, mut shed) = (0, 0);
    for _ in 0..n {
        match client.recv_infer().unwrap().1 {
            InferReply::Output(out) => {
                assert_eq!(out, x, "echo backend must return the input");
                ok += 1;
            }
            InferReply::Shed(msg) => {
                assert!(!msg.is_empty());
                shed += 1;
            }
            InferReply::Failed { status, message } => panic!("unexpected {status} {message}"),
        }
    }
    assert_eq!(ok + shed, n);
    assert!(ok >= 1, "nothing served");
    assert!(shed >= 1, "nothing shed under saturation");
    assert_eq!(server.metrics().snapshot().rejected, shed as u64);

    // Batch frames shed as a unit with the same status.
    match client.infer_batch(0, &vec![vec![0.5f32; 784]; 30]).unwrap() {
        BatchReply::Outputs(_) | BatchReply::Shed(_) => {}
        BatchReply::Failed { status, message } => panic!("unexpected {status} {message}"),
    }
    server.shutdown();
}

/// One client's wrong-dimension request must bounce as `BadRequest` at
/// the server edge instead of poisoning a coordinator batch shared with
/// well-behaved connections.
#[test]
fn wrong_dimension_rejected_without_poisoning_batches() {
    let (server, _registry) =
        start_model_server(256, BatchPolicy::windowed(16, Duration::from_millis(1)));
    let mut good = Client::connect(server.local_addr()).unwrap();
    let mut bad = Client::connect(server.local_addr()).unwrap();
    // Interleave: bad sends garbage dims while good sends valid traffic.
    for _ in 0..20 {
        bad.send_infer(0, &[1.0, 2.0, 3.0]).unwrap();
        good.send_infer(0, &probe()).unwrap();
    }
    for _ in 0..20 {
        match bad.recv_infer().unwrap().1 {
            InferReply::Failed { status, message } => {
                assert_eq!(status, Status::BadRequest);
                assert!(message.contains("dimension"), "{message}");
            }
            other => panic!("expected BadRequest, got {other:?}"),
        }
        match good.recv_infer().unwrap().1 {
            InferReply::Output(out) => assert_eq!(out.len(), 10),
            other => panic!("good client poisoned: {other:?}"),
        }
    }
    server.shutdown();
}

#[test]
fn swap_to_unknown_model_is_error_frame() {
    let (server, _registry) = start_model_server(64, BatchPolicy::immediate(8));
    let mut client = Client::connect(server.local_addr()).unwrap();
    let err = client.swap_model("nope").unwrap_err().to_string();
    assert!(err.contains("UnknownModel"), "{err}");
    assert!(err.contains("nope"), "{err}");
    // Unknown slot is also an error frame, with its own message.
    let err = client.swap_model_into("ghost-slot", "retrained").unwrap_err().to_string();
    assert!(err.contains("ghost-slot"), "{err}");
    // The connection survives error frames.
    client.ping().unwrap();
    server.shutdown();
}

#[test]
fn unknown_backend_index_is_error_frame() {
    let (server, _registry) = start_model_server(64, BatchPolicy::immediate(8));
    let mut client = Client::connect(server.local_addr()).unwrap();
    match client.infer(7, &probe()).unwrap() {
        InferReply::Failed { status, message } => {
            assert_eq!(status, Status::UnknownBackend);
            assert!(message.contains("out of range"), "{message}");
        }
        other => panic!("expected UnknownBackend, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn bad_magic_answered_then_connection_closed() {
    use std::io::{Read, Write};
    let (server, _registry) = start_model_server(64, BatchPolicy::immediate(8));
    let mut raw = std::net::TcpStream::connect(server.local_addr()).unwrap();
    // More than one header's worth of garbage: the trailing bytes sit
    // unread server-side, so this also exercises the drain-before-close
    // path that keeps the error frame from being lost to a TCP RST.
    raw.write_all(&[0xde; 32]).unwrap();
    let frame = wire::read_frame(&mut raw, wire::DEFAULT_MAX_PAYLOAD).unwrap();
    assert_eq!(frame.status, Status::BadRequest);
    // Framed at v1 — parseable by every supported client generation.
    assert_eq!(frame.version, 1);
    assert!(frame.message().contains("magic"), "{}", frame.message());
    // Server closes after a framing error.
    let mut rest = Vec::new();
    raw.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());
    server.shutdown();
}

/// The stage-pipelined backend end-to-end over TCP: registered as its
/// own engine backend kind, bitwise identical to the monolithic CPU
/// forward, with per-stage occupancy surfaced by the Stats opcode.
#[test]
fn pipeline_backend_serves_bitwise_and_reports_stage_occupancy() {
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    let server = Server::serve(
        registry,
        "127.0.0.1:0",
        EngineConfig {
            replicas: 1,
            backends: vec![BackendKind::Cpu, BackendKind::PipelineCpu { depth: 3 }],
            coordinator: CoordinatorConfig {
                queue_capacity: 1024,
                policy: BatchPolicy::windowed(16, Duration::from_millis(1)),
            },
            serve: ServeConfig::default(),
            autoscale: None,
            power_budget_w: None,
        },
    )
    .unwrap();
    let addr = server.local_addr();
    let want = mnist_shaped(1).forward_one(&probe());

    let mut client = Client::connect(addr).unwrap();
    // Backend 1 is the pipelined pool; its outputs must equal the
    // monolithic forward bit for bit — the tentpole contract, observed
    // over the real wire.
    for round in 0..40 {
        match client.infer(1, &probe()).unwrap() {
            InferReply::Output(out) => {
                assert_eq!(out.len(), want.len());
                for (a, b) in out.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "round {round}");
                }
            }
            other => panic!("pipeline backend failed: {other:?}"),
        }
    }
    // The monolithic CPU pool (backend 0) returns the same bits.
    match client.infer(0, &probe()).unwrap() {
        InferReply::Output(out) => {
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        other => panic!("cpu backend failed: {other:?}"),
    }
    let stats = client.stats().unwrap();
    assert!(stats.contains("pool pipeline/default"), "{stats}");
    assert!(stats.contains("stage layer0"), "{stats}");
    assert!(stats.contains("occupancy="), "{stats}");
    server.shutdown();
}

#[test]
fn over_limit_connection_gets_busy_frame() {
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    let coord = Coordinator::start(
        vec![("cpu".into(), swappable_cpu_factory(registry.default_slot()))],
        CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(8) },
    )
    .unwrap();
    let server = Server::start(
        coord,
        registry,
        "127.0.0.1:0",
        ServeConfig { max_conns: 1, ..ServeConfig::default() },
    )
    .unwrap();
    let mut first = Client::connect(server.local_addr()).unwrap();
    first.ping().unwrap(); // guarantees the handler is registered
    let mut second = std::net::TcpStream::connect(server.local_addr()).unwrap();
    let frame = wire::read_frame(&mut second, wire::DEFAULT_MAX_PAYLOAD).unwrap();
    assert_eq!(frame.status, Status::Busy);
    assert_eq!(frame.version, 1, "pre-request frames must be v1-parseable");
    // The first connection is unaffected.
    first.ping().unwrap();
    server.shutdown();
}
