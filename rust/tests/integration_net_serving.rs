//! Integration: the network serving subsystem end-to-end over real TCP
//! — load generator traffic, mixed single/batch frames, a mid-run model
//! swap, load shedding under saturation, and protocol error handling.

use edgemlp::coordinator::backend::{Backend, FnBackend};
use edgemlp::coordinator::server::BackendFactory;
use edgemlp::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use edgemlp::nn::activations::Activation;
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::serve::wire;
use edgemlp::serve::{
    run_loadgen, swappable_cpu_factory, BatchReply, Client, InferReply, LoadGenConfig,
    ModelRegistry, ServeConfig, Server, Status,
};
use edgemlp::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

/// An MNIST-shaped model (784 in, 10 out) small enough for debug-build
/// test runs; weights are random — serving correctness does not need a
/// trained network, only a deterministic one.
fn mnist_shaped(seed: u64) -> Mlp {
    let mut rng = Pcg32::new(seed);
    Mlp::new(
        MlpConfig {
            sizes: vec![784, 32, 10],
            activations: vec![Activation::Sigmoid, Activation::Sigmoid],
        },
        &mut rng,
    )
}

/// Server with a swappable CPU backend, "default" (seed 1) active and
/// "retrained" (seed 2) registered.
fn start_model_server(
    queue_capacity: usize,
    policy: BatchPolicy,
) -> (Server, Arc<ModelRegistry>) {
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    registry.register_mlp("retrained", mnist_shaped(2));
    let coord = Coordinator::start(
        vec![("cpu".into(), swappable_cpu_factory(registry.clone()))],
        CoordinatorConfig { queue_capacity, policy },
    )
    .unwrap();
    let server =
        Server::start(coord, registry.clone(), "127.0.0.1:0", ServeConfig::default()).unwrap();
    (server, registry)
}

fn probe() -> Vec<f32> {
    vec![0.37f32; 784]
}

fn assert_vec_close(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol, "elem {i}: {x} vs {y}");
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn ping_and_stats_roundtrip() {
    let (server, _registry) =
        start_model_server(256, BatchPolicy::windowed(16, Duration::from_millis(1)));
    let mut client = Client::connect(server.local_addr()).unwrap();
    client.ping().unwrap();
    match client.infer(0, &probe()).unwrap() {
        InferReply::Output(out) => assert_eq!(out.len(), 10),
        other => panic!("expected output, got {other:?}"),
    }
    let stats = client.stats().unwrap();
    assert!(stats.contains("model: default v1"), "{stats}");
    assert!(stats.contains("backend cpu"), "{stats}");
    assert!(stats.contains("p50="), "{stats}");
    assert!(stats.contains("p99="), "{stats}");
    server.shutdown();
}

/// The acceptance scenario: ≥10k mixed single/batch requests over TCP
/// with a mid-run `SwapModel`, zero lost responses, and served outputs
/// that verifiably change with the swap.
#[test]
fn e2e_mixed_traffic_with_midrun_swap() {
    let (server, _registry) =
        start_model_server(4096, BatchPolicy::windowed(64, Duration::from_millis(1)));
    let addr = server.local_addr();
    let v1 = mnist_shaped(1);
    let v2 = mnist_shaped(2);
    let want1 = v1.forward_one(&probe());
    let want2 = v2.forward_one(&probe());
    assert!(
        max_abs_diff(&want1, &want2) > 1e-3,
        "test models must disagree on the probe"
    );

    let mut ctl = Client::connect(addr).unwrap();
    match ctl.infer(0, &probe()).unwrap() {
        InferReply::Output(out) => assert_vec_close(&out, &want1, 1e-5),
        other => panic!("probe before swap: {other:?}"),
    }

    // Single-sample pipelined traffic on 6 connections…
    let single = std::thread::spawn(move || {
        run_loadgen(
            addr,
            LoadGenConfig {
                requests: 7200,
                connections: 6,
                backend: 0,
                dim: 784,
                pipeline: 8,
                ..LoadGenConfig::default()
            },
        )
        .unwrap()
    });
    // …plus InferBatch traffic on 2 more (mixed frame types).
    let batched = std::thread::spawn(move || {
        run_loadgen(
            addr,
            LoadGenConfig {
                requests: 2880,
                connections: 2,
                backend: 0,
                dim: 784,
                batch: 16,
                ..LoadGenConfig::default()
            },
        )
        .unwrap()
    });

    // Swap while traffic is in flight.
    std::thread::sleep(Duration::from_millis(30));
    let ack = ctl.swap_model("retrained").unwrap();
    assert!(ack.contains("retrained"), "{ack}");

    let single = single.join().unwrap();
    let batched = batched.join().unwrap();
    let total_sent = single.sent + batched.sent;
    assert!(total_sent >= 10_000, "only {total_sent} requests sent");
    // Zero lost responses: every request came back, none shed (the
    // queue is deep and clients are closed-loop), none errored.
    assert_eq!(single.ok, single.sent, "single: {single:?}");
    assert_eq!(batched.ok, batched.sent, "batched: {batched:?}");
    assert_eq!(single.shed + batched.shed, 0);
    assert_eq!(single.errors + batched.errors, 0);

    // The swap took effect without dropping anything.
    match ctl.infer(0, &probe()).unwrap() {
        InferReply::Output(out) => {
            assert_vec_close(&out, &want2, 1e-5);
            assert!(
                max_abs_diff(&out, &want1) > 1e-3,
                "served outputs did not change after swap"
            );
        }
        other => panic!("probe after swap: {other:?}"),
    }

    // Server-side accounting agrees: nothing vanished.
    let stats = ctl.stats().unwrap();
    assert!(stats.contains("generation 2"), "{stats}");
    let snap = server.metrics().snapshot();
    assert!(snap.backends["cpu"].requests >= total_sent as u64);
    assert_eq!(snap.rejected, 0);
    server.shutdown();
}

/// A saturated coordinator queue must answer with `Backpressure` error
/// frames — the wire mapping of `SubmitError::Backpressure` — while
/// accepted requests still complete.
#[test]
fn saturation_sheds_with_backpressure_frames() {
    // Slow single-slot backend behind a capacity-1 queue.
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    let slow: BackendFactory = Box::new(|| {
        Ok(Box::new(FnBackend::new("slow", 1, |inputs: &[Vec<f32>]| {
            std::thread::sleep(Duration::from_millis(20));
            Ok(inputs.to_vec())
        })) as Box<dyn Backend>)
    });
    let coord = Coordinator::start(
        vec![("slow".into(), slow)],
        CoordinatorConfig { queue_capacity: 1, policy: BatchPolicy::immediate(1) },
    )
    .unwrap();
    let server =
        Server::start(coord, registry, "127.0.0.1:0", ServeConfig::default()).unwrap();

    let mut client = Client::connect(server.local_addr()).unwrap();
    let n = 40;
    let x = probe(); // dims must match the registry's model (784)
    for _ in 0..n {
        client.send_infer(0, &x).unwrap();
    }
    let (mut ok, mut shed) = (0, 0);
    for _ in 0..n {
        match client.recv_infer().unwrap().1 {
            InferReply::Output(out) => {
                assert_eq!(out, x, "echo backend must return the input");
                ok += 1;
            }
            InferReply::Shed(msg) => {
                assert!(!msg.is_empty());
                shed += 1;
            }
            InferReply::Failed { status, message } => panic!("unexpected {status} {message}"),
        }
    }
    assert_eq!(ok + shed, n);
    assert!(ok >= 1, "nothing served");
    assert!(shed >= 1, "nothing shed under saturation");
    assert_eq!(server.metrics().snapshot().rejected, shed as u64);

    // Batch frames shed as a unit with the same status.
    match client.infer_batch(0, &vec![vec![0.5f32; 784]; 30]).unwrap() {
        BatchReply::Outputs(_) | BatchReply::Shed(_) => {}
        BatchReply::Failed { status, message } => panic!("unexpected {status} {message}"),
    }
    server.shutdown();
}

/// One client's wrong-dimension request must bounce as `BadRequest` at
/// the server edge instead of poisoning a coordinator batch shared with
/// well-behaved connections.
#[test]
fn wrong_dimension_rejected_without_poisoning_batches() {
    let (server, _registry) =
        start_model_server(256, BatchPolicy::windowed(16, Duration::from_millis(1)));
    let mut good = Client::connect(server.local_addr()).unwrap();
    let mut bad = Client::connect(server.local_addr()).unwrap();
    // Interleave: bad sends garbage dims while good sends valid traffic.
    for _ in 0..20 {
        bad.send_infer(0, &[1.0, 2.0, 3.0]).unwrap();
        good.send_infer(0, &probe()).unwrap();
    }
    for _ in 0..20 {
        match bad.recv_infer().unwrap().1 {
            InferReply::Failed { status, message } => {
                assert_eq!(status, Status::BadRequest);
                assert!(message.contains("dimension"), "{message}");
            }
            other => panic!("expected BadRequest, got {other:?}"),
        }
        match good.recv_infer().unwrap().1 {
            InferReply::Output(out) => assert_eq!(out.len(), 10),
            other => panic!("good client poisoned: {other:?}"),
        }
    }
    server.shutdown();
}

#[test]
fn swap_to_unknown_model_is_error_frame() {
    let (server, _registry) = start_model_server(64, BatchPolicy::immediate(8));
    let mut client = Client::connect(server.local_addr()).unwrap();
    let err = client.swap_model("nope").unwrap_err().to_string();
    assert!(err.contains("UnknownModel"), "{err}");
    assert!(err.contains("nope"), "{err}");
    // The connection survives an error frame.
    client.ping().unwrap();
    server.shutdown();
}

#[test]
fn unknown_backend_index_is_error_frame() {
    let (server, _registry) = start_model_server(64, BatchPolicy::immediate(8));
    let mut client = Client::connect(server.local_addr()).unwrap();
    match client.infer(7, &probe()).unwrap() {
        InferReply::Failed { status, message } => {
            assert_eq!(status, Status::UnknownBackend);
            assert!(message.contains("out of range"), "{message}");
        }
        other => panic!("expected UnknownBackend, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn bad_magic_answered_then_connection_closed() {
    use std::io::{Read, Write};
    let (server, _registry) = start_model_server(64, BatchPolicy::immediate(8));
    let mut raw = std::net::TcpStream::connect(server.local_addr()).unwrap();
    // More than one header's worth of garbage: the trailing bytes sit
    // unread server-side, so this also exercises the drain-before-close
    // path that keeps the error frame from being lost to a TCP RST.
    raw.write_all(&[0xde; 32]).unwrap();
    let frame = wire::read_frame(&mut raw, wire::DEFAULT_MAX_PAYLOAD).unwrap();
    assert_eq!(frame.status, Status::BadRequest);
    assert!(frame.message().contains("magic"), "{}", frame.message());
    // Server closes after a framing error.
    let mut rest = Vec::new();
    raw.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());
    server.shutdown();
}

#[test]
fn over_limit_connection_gets_busy_frame() {
    let registry = ModelRegistry::new("default", mnist_shaped(1), SpxConfig::sp2(5));
    let coord = Coordinator::start(
        vec![("cpu".into(), swappable_cpu_factory(registry.clone()))],
        CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(8) },
    )
    .unwrap();
    let server = Server::start(
        coord,
        registry,
        "127.0.0.1:0",
        ServeConfig { max_conns: 1, ..ServeConfig::default() },
    )
    .unwrap();
    let mut first = Client::connect(server.local_addr()).unwrap();
    first.ping().unwrap(); // guarantees the handler is registered
    let mut second = std::net::TcpStream::connect(server.local_addr()).unwrap();
    let frame = wire::read_frame(&mut second, wire::DEFAULT_MAX_PAYLOAD).unwrap();
    assert_eq!(frame.status, Status::Busy);
    // The first connection is unaffected.
    first.ping().unwrap();
    server.shutdown();
}
