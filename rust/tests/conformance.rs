//! Cross-backend conformance suite (ISSUE 5 satellite): for seeded
//! random MLP shapes — ragged, 1-layer, wide-short, deep, paper-sized —
//! the CPU batched forward, the SPx accelerator path and the new
//! stage-pipelined backends must agree:
//!
//! * **bitwise** between each pipelined backend (depths 1..4) and its
//!   monolithic reference (`Mlp::forward_with` /
//!   `Accelerator::forward_batch`), on whatever dispatch path the
//!   process latched (CI runs this suite natively, under
//!   `EDGEMLP_FORCE_SCALAR=1`, and under `EDGEMLP_GEMM_THREADS=1`);
//! * **bitwise** between the SPx batched kernel and the per-sample
//!   stream engine, and across GEMM thread counts per path;
//! * within **FMA tolerance** between the f32 forward on forced-scalar
//!   and native SIMD paths (`test_paths()` drives both through
//!   `gemm_into_with` in one process);
//! * within **quantization tolerance** between the f32 and SPx
//!   backends on calibrated high-bit codes.

use edgemlp::coordinator::backend::Backend;
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::nn::activations::Activation;
use edgemlp::nn::kernels::gemm::{configured_threads, gemm_into_with};
use edgemlp::nn::kernels::simd::test_paths;
use edgemlp::nn::kernels::{active_path, vsq_matmul_batch, DispatchPath};
use edgemlp::nn::mlp::{ForwardScratch, Mlp, MlpConfig};
use edgemlp::nn::tensor::Matrix;
use edgemlp::nn::vsq::VsqMlp;
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::vsq::{data_step, quantize_data_i8_into, VsqTensor};
use edgemlp::quant::Calibration;
use edgemlp::serve::{PipelineCpuBackend, PipelineFpgaBackend};
use edgemlp::util::check::assert_allclose;
use edgemlp::util::rng::Pcg32;

/// The shape zoo: ragged widths, a 1-layer net, wide-short (the
/// column-banded GEMM shape), a deep narrow net, and the paper's MNIST
/// network (large enough to trigger multi-band GEMM plans).
fn shapes() -> Vec<Vec<usize>> {
    vec![
        vec![9, 7],
        vec![12, 8, 4],
        vec![17, 5, 9, 3],
        vec![300, 9],
        vec![6, 64, 64, 3],
        vec![33, 128, 1],
        vec![784, 128, 10],
    ]
}

fn sigmoid_mlp(sizes: &[usize], rng: &mut Pcg32) -> Mlp {
    Mlp::new(
        MlpConfig {
            sizes: sizes.to_vec(),
            activations: vec![Activation::Sigmoid; sizes.len() - 1],
        },
        rng,
    )
}

fn batches() -> [usize; 3] {
    [1, 3, 8]
}

#[track_caller]
fn assert_bitwise(a: &Matrix, b: &Matrix, ctx: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}: {x} vs {y}");
    }
}

/// Layer-by-layer forward through `gemm_into_with` on an explicit
/// dispatch path and thread cap, with the same bias/activation tail as
/// `Layer::forward_into` — the path-pinned reference the cross-path
/// checks compare.
fn forward_with_path(path: DispatchPath, threads: usize, mlp: &Mlp, x: &Matrix) -> Matrix {
    let mut cur = x.clone();
    for layer in &mlp.layers {
        let mut next = Matrix::zeros(cur.rows, layer.w.rows);
        gemm_into_with(path, threads, &mut next, &cur, false, &layer.w, true);
        next.add_row_inplace(&layer.b);
        let act = layer.activation;
        next.map_inplace(|v| act.apply(v));
        cur = next;
    }
    cur
}

/// The pipelined CPU backend must reproduce `Mlp::forward_with` bit for
/// bit on every shape, batch size and depth 1..4 — the tentpole's
/// acceptance contract.
#[test]
fn cpu_pipeline_bitwise_across_shapes_batches_and_depths() {
    let mut rng = Pcg32::new(0x51);
    for sizes in shapes() {
        let mlp = sigmoid_mlp(&sizes, &mut rng);
        let mut scratch = ForwardScratch::new();
        for depth in 1..=4usize {
            let mut be = PipelineCpuBackend::new(mlp.clone(), depth);
            for &batch in &batches() {
                let x = Matrix::random_uniform(batch, mlp.input_dim(), 1.0, &mut rng);
                let want = mlp.forward_with(&x, &mut scratch).clone();
                let got = be.forward_batch(&x).unwrap();
                let ctx = format!("shape {sizes:?} depth {depth} batch {batch}");
                assert_bitwise(&got, &want, &ctx);
                // The Backend::infer path (staging + per-row extraction)
                // must carry the same bits.
                let inputs: Vec<Vec<f32>> = (0..batch).map(|r| x.row(r).to_vec()).collect();
                let (rows, stats) = be.infer(&inputs).unwrap();
                assert!(stats.is_none());
                for (r, row) in rows.iter().enumerate() {
                    for (a, b) in row.iter().zip(want.row(r)) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: infer row {r}");
                    }
                }
            }
        }
    }
}

/// The pipelined SPx backend must reproduce
/// `Accelerator::forward_batch` bit for bit (exact integer datapath) on
/// every shape, batch size and depth 1..4.
#[test]
fn spx_pipeline_bitwise_across_shapes_batches_and_depths() {
    let mut rng = Pcg32::new(0x52);
    for sizes in shapes() {
        let mlp = sigmoid_mlp(&sizes, &mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
        for depth in 1..=4usize {
            let accel = Accelerator::new(q.clone(), AccelConfig::default_fpga());
            let mut be = PipelineFpgaBackend::new(accel, depth);
            for &batch in &batches() {
                let x = Matrix::random_uniform(batch, mlp.input_dim(), 1.0, &mut rng);
                let want = be.accel.forward_batch(&x);
                let got = be.forward_batch(&x).unwrap();
                let ctx = format!("shape {sizes:?} depth {depth} batch {batch}");
                assert_bitwise(&got, &want, &ctx);
            }
        }
    }
}

/// The SPx batched kernel stays bit-identical to the per-sample stream
/// engine on every random shape (broader than the fixed-shape unit
/// test in `fpga/accelerator.rs`).
#[test]
fn spx_batch_bitwise_matches_per_sample_on_random_shapes() {
    let mut rng = Pcg32::new(0x53);
    for sizes in shapes() {
        let mlp = sigmoid_mlp(&sizes, &mut rng);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
        let accel = Accelerator::new(q, AccelConfig::default_fpga());
        let batch = 5usize;
        let x = Matrix::random_uniform(batch, mlp.input_dim(), 1.0, &mut rng);
        let batched = accel.forward_batch(&x);
        for b in 0..batch {
            let (single, _) = accel.infer_one(x.row(b));
            for (got, want) in batched.row(b).iter().zip(&single) {
                assert_eq!(got.to_bits(), want.to_bits(), "shape {sizes:?} sample {b}");
            }
        }
    }
}

/// Forced-scalar and native SIMD paths agree within FMA tolerance, and
/// each path is bitwise deterministic across GEMM thread counts —
/// `test_paths()` runs both in one process, no env gymnastics needed.
#[test]
fn dispatch_paths_agree_within_fma_tolerance() {
    let mut rng = Pcg32::new(0x54);
    for sizes in shapes() {
        let mlp = sigmoid_mlp(&sizes, &mut rng);
        let x = Matrix::random_uniform(6, mlp.input_dim(), 1.0, &mut rng);
        let scalar = forward_with_path(DispatchPath::Scalar, 1, &mlp, &x);
        for path in test_paths() {
            let single = forward_with_path(path, 1, &mlp, &x);
            let banded = forward_with_path(path, 4, &mlp, &x);
            let ctx = format!("shape {sizes:?} path {}", path.name());
            assert_bitwise(&banded, &single, &format!("{ctx}: thread-count determinism"));
            assert_allclose(&single.data, &scalar.data, 1e-4, 1e-3);
        }
    }
}

/// On the process's active dispatch path, the layer-by-layer
/// `gemm_into_with` reference IS the `Mlp::forward` code path — bit for
/// bit. Run natively this pins the SIMD path; under
/// `EDGEMLP_FORCE_SCALAR=1` (the CI forced-scalar pass) it pins the
/// scalar one.
#[test]
fn active_path_layerwise_reference_is_forward_bitwise() {
    let mut rng = Pcg32::new(0x55);
    for sizes in shapes() {
        let mlp = sigmoid_mlp(&sizes, &mut rng);
        let x = Matrix::random_uniform(4, mlp.input_dim(), 1.0, &mut rng);
        let manual = forward_with_path(active_path(), configured_threads(), &mlp, &x);
        let forward = mlp.forward(&x);
        assert_bitwise(&manual, &forward, &format!("shape {sizes:?}"));
    }
}

/// f32 and SPx backends agree within quantization tolerance on
/// calibrated high-bit codes — the cross-backend sanity bound (exact
/// agreement is impossible: the SPx path quantizes weights *and* data).
#[test]
fn cpu_and_spx_agree_within_quantization_tolerance() {
    let mut rng = Pcg32::new(0x56);
    for sizes in shapes() {
        let mlp = sigmoid_mlp(&sizes, &mut rng);
        let batch = 4usize;
        let x = Matrix::random_uniform(batch, mlp.input_dim(), 1.0, &mut rng);
        // Calibrate per-layer data ranges on the probe batch itself so
        // the Q1.15 staging never clips.
        let q =
            QuantizedMlp::from_mlp(&mlp, &SpxConfig::spx(8, 2), Calibration::MaxAbs, Some(&x));
        let accel = Accelerator::new(q, AccelConfig::default_fpga());
        let spx = accel.forward_batch(&x);
        let fp32 = mlp.forward(&x);
        assert_allclose(&spx.data, &fp32.data, 0.15, 0.15);
    }
}

/// Longhand exact-integer reference for the VSQ kernel, written out in
/// the test crate so it shares no code with the kernel under test: the
/// i8×i8 products are exact in i32, so whatever dispatch path the
/// process latched (native, `EDGEMLP_FORCE_SCALAR=1`, any
/// `EDGEMLP_GEMM_THREADS`) must reproduce it bit for bit.
fn vsq_reference(w: &VsqTensor, x_q: &[i8], batch: usize, d_scale: f32) -> Vec<f32> {
    let (m, n) = (w.rows(), w.cols());
    let step = data_step(d_scale);
    let mut out = vec![0.0f32; batch * m];
    for b in 0..batch {
        for r in 0..m {
            let mut acc = 0i32;
            for (j, &wj) in w.row(r).iter().enumerate() {
                acc += wj as i32 * x_q[b * n + j] as i32;
            }
            out[b * m + r] = acc as f32 * (w.scale_for_row(r) * step);
        }
    }
    out
}

/// The int8/int4 VSQ kernel on the process's active dispatch path is
/// bitwise identical to the longhand scalar reference, on ragged and
/// serving shapes. CI runs this suite natively, under
/// `EDGEMLP_FORCE_SCALAR=1`, and under `EDGEMLP_GEMM_THREADS=1`, so the
/// three passes together pin scalar-vs-SIMD identity and thread-count
/// invariance for the integer kernels.
#[test]
fn vsq_kernel_bitwise_matches_scalar_reference_on_active_path() {
    let mut rng = Pcg32::new(0x58);
    for &(m, n, batch) in
        &[(9usize, 7usize, 1usize), (12, 8, 3), (5, 300, 2), (128, 784, 8), (10, 128, 8)]
    {
        for bits in [8u8, 4] {
            let wdata: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32 * 0.2).collect();
            let w = VsqTensor::encode(bits, 16, &wdata, m, n, Calibration::MaxAbs);
            let d_scale = rng.range(0.5, 3.0) as f32;
            let flat: Vec<f32> =
                (0..batch * n).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let mut x_q = Vec::new();
            quantize_data_i8_into(&flat, d_scale, &mut x_q);
            let want = vsq_reference(&w, &x_q, batch, d_scale);
            let mut got = vec![0.0f32; batch * m];
            vsq_matmul_batch(&w, &x_q, batch, d_scale, &mut got);
            for (i, (a, e)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    e.to_bits(),
                    "bits {bits} shape {m}x{n} batch {batch} path {} element {i}: {a} vs {e}",
                    active_path().name(),
                );
            }
        }
    }
}

/// The full VSQ model forward is batch-size invariant bit for bit on
/// the shape zoo — the kernel never splits a reduction, so batching is
/// pure loop ordering. Together with the kernel-reference row above
/// (and the forced-scalar / single-thread CI passes re-running both)
/// this extends the f32/SPx bitwise conformance contract to the
/// int8/int4 serving pools.
#[test]
fn vsq_forward_batched_matches_per_sample_across_shapes() {
    let mut rng = Pcg32::new(0x59);
    for sizes in shapes() {
        let mlp = sigmoid_mlp(&sizes, &mut rng);
        for bits in [8u8, 4] {
            let v = VsqMlp::from_mlp(&mlp, bits, 16, Calibration::MaxAbs, None);
            let batch = 5usize;
            let x = Matrix::random_uniform(batch, mlp.input_dim(), 1.0, &mut rng);
            let batched = v.forward_batch(&x);
            for b in 0..batch {
                let single = v.forward_one(x.row(b));
                for (got, want) in batched.row(b).iter().zip(&single) {
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "shape {sizes:?} bits {bits} sample {b}"
                    );
                }
            }
            // Requantize-and-rerun determinism: the whole encode +
            // forward pipeline reproduces itself.
            let v2 = VsqMlp::from_mlp(&mlp, bits, 16, Calibration::MaxAbs, None);
            assert_bitwise(
                &v2.forward_batch(&x),
                &batched,
                &format!("shape {sizes:?} bits {bits} requantized"),
            );
        }
    }
}

/// int8 end to end stays within quantization tolerance of the f32
/// forward on sigmoid networks — the cross-precision sanity bound the
/// MNIST ablation tightens to a 1% accuracy budget.
#[test]
fn cpu_and_vsq_int8_agree_within_quantization_tolerance() {
    let mut rng = Pcg32::new(0x5a);
    for sizes in shapes() {
        let mlp = sigmoid_mlp(&sizes, &mut rng);
        let v = VsqMlp::from_mlp(&mlp, 8, 16, Calibration::MaxAbs, None);
        let x = Matrix::random_uniform(4, mlp.input_dim(), 1.0, &mut rng);
        let got = v.forward_batch(&x);
        let want = mlp.forward(&x);
        assert_allclose(&got.data, &want.data, 5e-2, 5e-2);
    }
}

/// Relu/identity networks (unbounded activations — the Q-network
/// family) hold the same bitwise pipeline contract as sigmoid ones.
#[test]
fn qnet_activations_hold_the_bitwise_contract() {
    let mut rng = Pcg32::new(0x57);
    let mlp = Mlp::new(MlpConfig::paper_qnet(), &mut rng);
    let mut scratch = ForwardScratch::new();
    for depth in 1..=4usize {
        let mut be = PipelineCpuBackend::new(mlp.clone(), depth);
        let x = Matrix::random_uniform(7, mlp.input_dim(), 2.0, &mut rng);
        let want = mlp.forward_with(&x, &mut scratch).clone();
        let got = be.forward_batch(&x).unwrap();
        assert_bitwise(&got, &want, &format!("qnet depth {depth}"));
    }
}
