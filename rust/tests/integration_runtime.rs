//! Integration: rust runtime ⇄ real AOT artifacts (requires
//! `make artifacts`). Every test is skipped gracefully when the
//! artifacts are absent so `cargo test` works pre-build, but the CI
//! flow (`make test`) always exercises them.

use edgemlp::fpga::accelerator::QuantizedMlp;
use edgemlp::nn::mlp::{Mlp, MlpConfig};
use edgemlp::nn::tensor::Matrix;
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::Calibration;
use edgemlp::runtime::executable::{mlp_fp32_inputs, mlp_spx_inputs, qnet_inputs};
use edgemlp::runtime::{Registry, Runtime};
use edgemlp::util::check::assert_allclose;
use edgemlp::util::rng::Pcg32;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn runtime(dir: &Path) -> Runtime {
    Runtime::new(Registry::open(dir).unwrap()).unwrap()
}

fn mnist_mlp(seed: u64) -> Mlp {
    let mut rng = Pcg32::new(seed);
    Mlp::new(MlpConfig::paper_mnist(), &mut rng)
}

#[test]
fn all_artifacts_compile() {
    let dir = require_artifacts!();
    let rt = runtime(&dir);
    for name in ["mlp_fp32_b1", "mlp_fp32_b64", "mlp_spx_b1", "mlp_spx_b64", "qnet_fp32_b1"] {
        let model = rt.load(name).unwrap_or_else(|e| panic!("load {name}: {e:#}"));
        assert_eq!(model.spec.name, name);
    }
}

#[test]
fn fp32_artifact_matches_rust_forward_b1() {
    let dir = require_artifacts!();
    let rt = runtime(&dir);
    let model = rt.load("mlp_fp32_b1").unwrap();
    let mlp = mnist_mlp(1);
    let mut rng = Pcg32::new(2);
    for _ in 0..4 {
        let x: Vec<f32> = (0..784).map(|_| rng.uniform() as f32).collect();
        let out = model.run(&mlp_fp32_inputs(&mlp, &x)).unwrap();
        let expect = mlp.forward_one(&x);
        assert_allclose(&out, &expect, 1e-5, 1e-4);
    }
}

#[test]
fn fp32_artifact_matches_rust_forward_b64() {
    let dir = require_artifacts!();
    let rt = runtime(&dir);
    let model = rt.load("mlp_fp32_b64").unwrap();
    let mlp = mnist_mlp(3);
    let mut rng = Pcg32::new(4);
    let x = Matrix::random_uniform(64, 784, 0.5, &mut rng);
    let out = model.run(&mlp_fp32_inputs(&mlp, &x.data)).unwrap();
    let expect = mlp.forward(&x);
    assert_eq!(out.len(), 64 * 10);
    assert_allclose(&out, &expect.data, 1e-5, 1e-4);
}

#[test]
fn spx_artifact_matches_dequantized_forward() {
    let dir = require_artifacts!();
    let rt = runtime(&dir);
    let model = rt.load("mlp_spx_b1").unwrap();
    let mlp = mnist_mlp(5);
    // The artifact is built for SP2 (x = 2) — see aot.py SPX_TERMS.
    let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
    let deq = q.to_dequantized_mlp(&mlp);
    let mut rng = Pcg32::new(6);
    for _ in 0..4 {
        let x: Vec<f32> = (0..784).map(|_| rng.uniform() as f32).collect();
        let out = model.run(&mlp_spx_inputs(&q, &x)).unwrap();
        // The artifact decodes the SPx codes inside the Pallas kernel;
        // the rust dequantized forward is the oracle.
        let expect = deq.forward_one(&x);
        assert_allclose(&out, &expect, 1e-4, 1e-3);
    }
}

#[test]
fn spx_artifact_b64_batches_correctly() {
    let dir = require_artifacts!();
    let rt = runtime(&dir);
    let model = rt.load("mlp_spx_b64").unwrap();
    let mlp = mnist_mlp(7);
    let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
    let deq = q.to_dequantized_mlp(&mlp);
    let mut rng = Pcg32::new(8);
    let x = Matrix::random_uniform(64, 784, 0.5, &mut rng);
    let out = model.run(&mlp_spx_inputs(&q, &x.data)).unwrap();
    let expect = deq.forward(&x);
    assert_allclose(&out, &expect.data, 1e-4, 1e-3);
}

#[test]
fn qnet_artifact_matches_rust_forward() {
    let dir = require_artifacts!();
    let rt = runtime(&dir);
    let model = rt.load("qnet_fp32_b1").unwrap();
    let mut rng = Pcg32::new(9);
    let qnet = Mlp::new(MlpConfig::paper_qnet(), &mut rng);
    for _ in 0..4 {
        let obs: Vec<f32> = (0..6).map(|_| rng.range(-1.0, 1.0) as f32).collect();
        let out = model.run(&qnet_inputs(&qnet, &obs)).unwrap();
        let expect = qnet.forward_one(&obs);
        assert_allclose(&out, &expect, 1e-5, 1e-4);
    }
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let dir = require_artifacts!();
    let rt = runtime(&dir);
    let model = rt.load("mlp_fp32_b1").unwrap();
    let mlp = mnist_mlp(10);
    // Wrong number of inputs.
    assert!(model.run(&[]).is_err());
    // Wrong element count in x.
    let mut inputs = mlp_fp32_inputs(&mlp, &vec![0.0f32; 10]);
    assert!(model.run(&inputs).is_err());
    // Wrong dtype (i32 where f32 expected).
    inputs = mlp_fp32_inputs(&mlp, &vec![0.0f32; 784]);
    inputs[0] = edgemlp::runtime::executable::InputValue::I32(vec![0; 784]);
    assert!(model.run(&inputs).is_err());
}

#[test]
fn artifact_is_weight_agnostic() {
    // One artifact, two different checkpoints — weights are runtime
    // inputs, so outputs must track the weights.
    let dir = require_artifacts!();
    let rt = runtime(&dir);
    let model = rt.load("mlp_fp32_b1").unwrap();
    let mlp_a = mnist_mlp(11);
    let mlp_b = mnist_mlp(12);
    let x: Vec<f32> = vec![0.5; 784];
    let out_a = model.run(&mlp_fp32_inputs(&mlp_a, &x)).unwrap();
    let out_b = model.run(&mlp_fp32_inputs(&mlp_b, &x)).unwrap();
    assert_ne!(out_a, out_b);
    assert_allclose(&out_a, &mlp_a.forward_one(&x), 1e-5, 1e-4);
    assert_allclose(&out_b, &mlp_b.forward_one(&x), 1e-5, 1e-4);
}
