//! Artifact manifest: what `python/compile/aot.py` built, parsed from
//! `artifacts/manifest.json` so the runtime can validate inputs before
//! PJRT sees them (shape bugs surface as readable errors, not XLA
//! aborts).

use crate::util::serde::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Supported tensor dtypes on the artifact boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// One input or output tensor of an artifact.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact: HLO file + typed I/O signature + build metadata.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// `meta.batch` from the manifest.
    pub batch: usize,
    /// `meta.model` tag (`mlp_fp32`, `mlp_spx`, `qnet_fp32`).
    pub model: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Registry {
    pub dir: PathBuf,
    artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_tensor(j: &Json, fallback_name: &str) -> Result<TensorSpec> {
    let shape = j
        .field("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<Vec<_>>>()?;
    let dtype = Dtype::parse(j.field("dtype")?.as_str()?)?;
    let name = match j.field("name") {
        Ok(n) => n.as_str()?.to_string(),
        Err(_) => fallback_name.to_string(),
    };
    Ok(TensorSpec { name, shape, dtype })
}

impl Registry {
    /// Load `dir/manifest.json`.
    pub fn open(dir: &Path) -> Result<Registry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest_path.display()))?;
        let json = Json::parse(&text).context("parse manifest.json")?;
        let format = json.field("format")?.as_str()?;
        if format != "hlo-text" {
            bail!("unsupported artifact format '{format}'");
        }
        let mut artifacts = BTreeMap::new();
        for (name, entry) in json.field("artifacts")?.as_obj()? {
            let file = entry.field("file")?.as_str()?;
            let inputs = entry
                .field("inputs")?
                .as_arr()?
                .iter()
                .enumerate()
                .map(|(i, t)| parse_tensor(t, &format!("in{i}")))
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .field("outputs")?
                .as_arr()?
                .iter()
                .enumerate()
                .map(|(i, t)| parse_tensor(t, &format!("out{i}")))
                .collect::<Result<Vec<_>>>()?;
            let meta = entry.field("meta")?;
            let spec = ArtifactSpec {
                name: name.clone(),
                path: dir.join(file),
                inputs,
                outputs,
                batch: meta.field("batch")?.as_usize()?,
                model: meta.field("model")?.as_str()?.to_string(),
            };
            if !spec.path.exists() {
                bail!("manifest references missing file {}", spec.path.display());
            }
            artifacts.insert(name.clone(), spec);
        }
        Ok(Registry { dir: dir.to_path_buf(), artifacts })
    }

    /// Default location: `$EDGEMLP_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Registry> {
        let dir = std::env::var("EDGEMLP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Registry::open(Path::new(&dir))
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("unknown artifact '{name}' (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unique per-test scratch directory, removed on drop. The old
    /// fixed `temp_dir()/edgemlp_registry_test{,2,3}` names collided
    /// under parallel or repeated `cargo test` runs.
    struct TestDir(PathBuf);

    impl TestDir {
        fn new(tag: &str) -> TestDir {
            use std::sync::atomic::{AtomicU32, Ordering};
            static SEQ: AtomicU32 = AtomicU32::new(0);
            let nanos = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .subsec_nanos();
            let dir = std::env::temp_dir().join(format!(
                "edgemlp_registry_{tag}_{}_{}_{nanos}",
                std::process::id(),
                SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            std::fs::create_dir_all(&dir).unwrap();
            TestDir(dir)
        }

        fn path(&self) -> &Path {
            &self.0
        }
    }

    impl Drop for TestDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    fn write_fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "format": "hlo-text",
              "artifacts": {
                "m_b2": {
                  "file": "m.hlo.txt",
                  "inputs": [
                    {"name": "x", "shape": [2, 4], "dtype": "float32"},
                    {"name": "codes", "shape": [2, 3, 4], "dtype": "int32"}
                  ],
                  "outputs": [{"shape": [2, 3], "dtype": "float32"}],
                  "meta": {"model": "mlp_fp32", "batch": 2, "sizes": [4, 3]}
                }
              }
            }"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_manifest() {
        let tmp = TestDir::new("parse");
        let dir = tmp.path();
        write_fake_manifest(dir);
        let reg = Registry::open(dir).unwrap();
        assert_eq!(reg.len(), 1);
        let spec = reg.get("m_b2").unwrap();
        assert_eq!(spec.batch, 2);
        assert_eq!(spec.inputs.len(), 2);
        assert_eq!(spec.inputs[0].shape, vec![2, 4]);
        assert_eq!(spec.inputs[1].dtype, Dtype::I32);
        assert_eq!(spec.outputs[0].numel(), 6);
    }

    #[test]
    fn unknown_artifact_is_error() {
        let tmp = TestDir::new("unknown");
        let dir = tmp.path();
        write_fake_manifest(dir);
        let reg = Registry::open(dir).unwrap();
        assert!(reg.get("nope").is_err());
    }

    #[test]
    fn missing_file_is_error() {
        let tmp = TestDir::new("missing");
        let dir = tmp.path();
        write_fake_manifest(dir);
        std::fs::remove_file(dir.join("m.hlo.txt")).unwrap();
        assert!(Registry::open(dir).is_err());
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let err = Registry::open(Path::new("/nonexistent_artifacts")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // When `make artifacts` has run, validate the real thing.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let reg = Registry::open(&dir).unwrap();
            assert!(reg.get("mlp_fp32_b1").is_ok());
            assert!(reg.get("mlp_spx_b64").is_ok());
            assert_eq!(reg.get("qnet_fp32_b1").unwrap().inputs.len(), 7);
        }
    }
}
