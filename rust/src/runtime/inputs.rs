//! Typed input buffers + marshalling helpers for the AOT artifacts.
//!
//! Pure rust (no PJRT types), so this module is shared verbatim by the
//! real `xla`-feature executable layer and its stub — keeping the two
//! build configurations' public API identical and edits single-sited.

use super::registry::Dtype;

/// An input buffer: f32 or i32, shape implied by the artifact signature.
#[derive(Debug, Clone)]
pub enum InputValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl InputValue {
    pub fn len(&self) -> usize {
        match self {
            InputValue::F32(v) => v.len(),
            InputValue::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            InputValue::F32(_) => Dtype::F32,
            InputValue::I32(_) => Dtype::I32,
        }
    }
}

/// Helper: build the input list for the fp32 MLP artifacts from a
/// trained [`crate::nn::Mlp`] (layers w2/b2, w3/b3) and a batch of
/// flattened images.
pub fn mlp_fp32_inputs(mlp: &crate::nn::Mlp, x: &[f32]) -> Vec<InputValue> {
    assert_eq!(mlp.layers.len(), 2, "fp32 MLP artifact is 2-layer");
    vec![
        InputValue::F32(x.to_vec()),
        InputValue::F32(mlp.layers[0].w.data.clone()),
        InputValue::F32(mlp.layers[0].b.clone()),
        InputValue::F32(mlp.layers[1].w.data.clone()),
        InputValue::F32(mlp.layers[1].b.clone()),
    ]
}

/// Helper: build the input list for the SPx MLP artifacts from a
/// [`crate::fpga::accelerator::QuantizedMlp`] and a batch of images.
/// Plane/sign integers widen to i32 (the artifact's dtype).
pub fn mlp_spx_inputs(
    q: &crate::fpga::accelerator::QuantizedMlp,
    x: &[f32],
) -> Vec<InputValue> {
    assert_eq!(q.layers.len(), 2, "SPx MLP artifact is 2-layer");
    let mut inputs = vec![InputValue::F32(x.to_vec())];
    for layer in &q.layers {
        let signs: Vec<i32> = layer.w.signs.iter().map(|&s| s as i32).collect();
        let mut planes: Vec<i32> = Vec::with_capacity(layer.w.numel() * layer.w.planes.len());
        for plane in &layer.w.planes {
            planes.extend(plane.iter().map(|&c| c as i32));
        }
        inputs.push(InputValue::I32(signs));
        inputs.push(InputValue::I32(planes));
        inputs.push(InputValue::F32(vec![layer.w.scale]));
        inputs.push(InputValue::F32(layer.b.clone()));
    }
    inputs
}

/// Helper: inputs for the Q-network artifact.
pub fn qnet_inputs(qnet: &crate::nn::Mlp, obs: &[f32]) -> Vec<InputValue> {
    assert_eq!(qnet.layers.len(), 3, "qnet artifact is 3-layer");
    let mut inputs = vec![InputValue::F32(obs.to_vec())];
    for layer in &qnet.layers {
        inputs.push(InputValue::F32(layer.w.data.clone()));
        inputs.push(InputValue::F32(layer.b.clone()));
    }
    inputs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_value_lengths() {
        assert_eq!(InputValue::F32(vec![1.0; 3]).len(), 3);
        assert_eq!(InputValue::I32(vec![1; 5]).len(), 5);
        assert_eq!(InputValue::F32(vec![]).len(), 0);
    }

    #[test]
    fn dtype_tags() {
        assert_eq!(InputValue::F32(vec![]).dtype(), Dtype::F32);
        assert_eq!(InputValue::I32(vec![]).dtype(), Dtype::I32);
    }

    #[test]
    fn fp32_input_marshalling_shapes() {
        let mut rng = crate::util::rng::Pcg32::new(1);
        let mlp = crate::nn::Mlp::new(crate::nn::MlpConfig::paper_mnist(), &mut rng);
        let x = vec![0.0f32; 784];
        let inputs = mlp_fp32_inputs(&mlp, &x);
        assert_eq!(inputs.len(), 5);
        assert_eq!(inputs[1].len(), 128 * 784);
        assert_eq!(inputs[4].len(), 10);
    }
}
