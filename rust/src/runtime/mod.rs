//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` and execute them from the L3 hot path.
//!
//! Python is *never* involved here — [`client::Runtime`] wraps the `xla`
//! crate's PJRT CPU client, [`registry::Registry`] reads
//! `artifacts/manifest.json` (written by `python/compile/aot.py`), and
//! [`executable::LoadedModel`] validates shapes and converts between
//! rust buffers and XLA literals.
//!
//! Threading note: the `xla` crate's types wrap raw PJRT pointers and
//! are not `Send`; a [`client::Runtime`] must be created *and used* on
//! one thread. The coordinator accommodates this by giving the XLA
//! backend its own worker thread that constructs the runtime in-place.

pub mod client;
pub mod executable;
pub mod registry;

pub use client::Runtime;
pub use executable::LoadedModel;
pub use registry::{ArtifactSpec, Registry, TensorSpec};
