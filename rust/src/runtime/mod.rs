//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` and execute them from the L3 hot path.
//!
//! Python is *never* involved here — [`client::Runtime`] wraps the `xla`
//! crate's PJRT CPU client, [`registry::Registry`] reads
//! `artifacts/manifest.json` (written by `python/compile/aot.py`), and
//! [`executable::LoadedModel`] validates shapes and converts between
//! rust buffers and XLA literals.
//!
//! Threading note: the `xla` crate's types wrap raw PJRT pointers and
//! are not `Send`; a [`client::Runtime`] must be created *and used* on
//! one thread. The coordinator accommodates this by giving the XLA
//! backend its own worker thread that constructs the runtime in-place.
//!
//! Build note: the `xla` bindings crate is not part of the offline
//! vendor set, so the PJRT-touching halves ([`client`]/[`executable`])
//! are compiled only under the `xla` cargo feature. The default build
//! substitutes API-compatible stubs whose [`client::Runtime::new`]
//! returns an error — every XLA-dependent code path already handles
//! that (it is indistinguishable from `make artifacts` not having run).

#[cfg(feature = "xla")]
pub mod client;
#[cfg(not(feature = "xla"))]
#[path = "client_stub.rs"]
pub mod client;
#[cfg(feature = "xla")]
pub mod executable;
#[cfg(not(feature = "xla"))]
#[path = "executable_stub.rs"]
pub mod executable;
pub mod inputs;
pub mod registry;

pub use client::Runtime;
pub use executable::LoadedModel;
pub use registry::{ArtifactSpec, Registry, TensorSpec};
