//! Stub of the executable layer compiled when the `xla` feature is off.
//!
//! Everything pure-rust ([`InputValue`], the `*_inputs` marshalling
//! helpers) lives in the shared [`super::inputs`] module and is merely
//! re-exported here, so both build configurations expose the identical
//! API from `runtime::executable::*`. Only [`LoadedModel`] is a
//! stand-in — it cannot be constructed because the stub
//! [`super::client::Runtime::new`] never succeeds, so
//! [`LoadedModel::run`] is unreachable.

use super::registry::ArtifactSpec;
use anyhow::{bail, Result};

pub use super::inputs::{mlp_fp32_inputs, mlp_spx_inputs, qnet_inputs, InputValue};

/// Stand-in for a compiled artifact. Never constructed in stub builds.
pub struct LoadedModel {
    pub spec: ArtifactSpec,
}

impl LoadedModel {
    /// Batch size this artifact was lowered for.
    pub fn batch(&self) -> usize {
        self.spec.batch
    }

    /// Unreachable in stub builds ([`super::client::Runtime::load`]
    /// never returns a model); kept for API parity.
    pub fn run(&self, _inputs: &[InputValue]) -> Result<Vec<f32>> {
        bail!(
            "cannot execute artifact '{}': built without the `xla` cargo feature",
            self.spec.name
        )
    }
}
