//! A compiled artifact plus typed input/output conversion.
//!
//! Callers hand over plain rust buffers ([`InputValue`]); the model
//! validates them against the manifest signature, builds XLA literals,
//! executes, and unwraps the 1-tuple result (`aot.py` lowers with
//! `return_tuple=True`) back into `Vec<f32>`.

use super::registry::{ArtifactSpec, Dtype, TensorSpec};
use anyhow::{bail, Context, Result};

/// An input buffer: f32 or i32, shape implied by the artifact signature.
#[derive(Debug, Clone)]
pub enum InputValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl InputValue {
    pub fn len(&self) -> usize {
        match self {
            InputValue::F32(v) => v.len(),
            InputValue::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype(&self) -> Dtype {
        match self {
            InputValue::F32(_) => Dtype::F32,
            InputValue::I32(_) => Dtype::I32,
        }
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            InputValue::F32(v) => xla::Literal::vec1(v),
            InputValue::I32(v) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims)
            .with_context(|| format!("reshape input '{}' to {:?}", spec.name, spec.shape))
    }
}

/// A compiled artifact ready to execute.
pub struct LoadedModel {
    pub spec: ArtifactSpec,
    executable: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    pub(crate) fn new(spec: ArtifactSpec, executable: xla::PjRtLoadedExecutable) -> Self {
        LoadedModel { spec, executable }
    }

    /// Batch size this artifact was lowered for.
    pub fn batch(&self) -> usize {
        self.spec.batch
    }

    /// Validate + convert + execute. Returns the flattened f32 output
    /// (shape `spec.outputs[0].shape`).
    pub fn run(&self, inputs: &[InputValue]) -> Result<Vec<f32>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (value, tensor_spec) in inputs.iter().zip(&self.spec.inputs) {
            if value.dtype() != tensor_spec.dtype {
                bail!(
                    "input '{}': dtype mismatch (artifact wants {:?})",
                    tensor_spec.name,
                    tensor_spec.dtype
                );
            }
            if value.len() != tensor_spec.numel() {
                bail!(
                    "input '{}': got {} elements, want {} (shape {:?})",
                    tensor_spec.name,
                    value.len(),
                    tensor_spec.numel(),
                    tensor_spec.shape
                );
            }
            literals.push(value.to_literal(tensor_spec)?);
        }
        let result = self
            .executable
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute '{}'", self.spec.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .context("device → host transfer")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = literal.to_tuple1().context("unwrap result tuple")?;
        let values = out.to_vec::<f32>().context("read f32 output")?;
        let want = self.spec.outputs[0].numel();
        if values.len() != want {
            bail!(
                "artifact '{}' returned {} elements, manifest says {}",
                self.spec.name,
                values.len(),
                want
            );
        }
        Ok(values)
    }
}

/// Helper: build the input list for the fp32 MLP artifacts from a
/// trained [`crate::nn::Mlp`] (layers w2/b2, w3/b3) and a batch of
/// flattened images.
pub fn mlp_fp32_inputs(mlp: &crate::nn::Mlp, x: &[f32]) -> Vec<InputValue> {
    assert_eq!(mlp.layers.len(), 2, "fp32 MLP artifact is 2-layer");
    vec![
        InputValue::F32(x.to_vec()),
        InputValue::F32(mlp.layers[0].w.data.clone()),
        InputValue::F32(mlp.layers[0].b.clone()),
        InputValue::F32(mlp.layers[1].w.data.clone()),
        InputValue::F32(mlp.layers[1].b.clone()),
    ]
}

/// Helper: build the input list for the SPx MLP artifacts from a
/// [`crate::fpga::accelerator::QuantizedMlp`] and a batch of images.
/// Plane/sign integers widen to i32 (the artifact's dtype).
pub fn mlp_spx_inputs(
    q: &crate::fpga::accelerator::QuantizedMlp,
    x: &[f32],
) -> Vec<InputValue> {
    assert_eq!(q.layers.len(), 2, "SPx MLP artifact is 2-layer");
    let mut inputs = vec![InputValue::F32(x.to_vec())];
    for layer in &q.layers {
        let signs: Vec<i32> = layer.w.signs.iter().map(|&s| s as i32).collect();
        let mut planes: Vec<i32> = Vec::with_capacity(layer.w.numel() * layer.w.planes.len());
        for plane in &layer.w.planes {
            planes.extend(plane.iter().map(|&c| c as i32));
        }
        inputs.push(InputValue::I32(signs));
        inputs.push(InputValue::I32(planes));
        inputs.push(InputValue::F32(vec![layer.w.scale]));
        inputs.push(InputValue::F32(layer.b.clone()));
    }
    inputs
}

/// Helper: inputs for the Q-network artifact.
pub fn qnet_inputs(qnet: &crate::nn::Mlp, obs: &[f32]) -> Vec<InputValue> {
    assert_eq!(qnet.layers.len(), 3, "qnet artifact is 3-layer");
    let mut inputs = vec![InputValue::F32(obs.to_vec())];
    for layer in &qnet.layers {
        inputs.push(InputValue::F32(layer.w.data.clone()));
        inputs.push(InputValue::F32(layer.b.clone()));
    }
    inputs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_value_lengths() {
        assert_eq!(InputValue::F32(vec![1.0; 3]).len(), 3);
        assert_eq!(InputValue::I32(vec![1; 5]).len(), 5);
        assert_eq!(InputValue::F32(vec![]).len(), 0);
    }

    #[test]
    fn dtype_tags() {
        assert_eq!(InputValue::F32(vec![]).dtype(), Dtype::F32);
        assert_eq!(InputValue::I32(vec![]).dtype(), Dtype::I32);
    }
}
