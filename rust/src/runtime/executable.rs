//! A compiled artifact plus typed input/output conversion (requires the
//! `xla` feature; the default build substitutes `executable_stub.rs`).
//!
//! Callers hand over plain rust buffers ([`InputValue`], defined in the
//! shared [`super::inputs`] module); the model validates them against
//! the manifest signature, builds XLA literals, executes, and unwraps
//! the 1-tuple result (`aot.py` lowers with `return_tuple=True`) back
//! into `Vec<f32>`.

use super::registry::{ArtifactSpec, TensorSpec};
use anyhow::{bail, Context, Result};

pub use super::inputs::{mlp_fp32_inputs, mlp_spx_inputs, qnet_inputs, InputValue};

/// PJRT-side conversion, kept out of [`super::inputs`] so the shared
/// half stays free of `xla` types.
fn to_literal(value: &InputValue, spec: &TensorSpec) -> Result<xla::Literal> {
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let lit = match value {
        InputValue::F32(v) => xla::Literal::vec1(v),
        InputValue::I32(v) => xla::Literal::vec1(v),
    };
    lit.reshape(&dims)
        .with_context(|| format!("reshape input '{}' to {:?}", spec.name, spec.shape))
}

/// A compiled artifact ready to execute.
pub struct LoadedModel {
    pub spec: ArtifactSpec,
    executable: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    pub(crate) fn new(spec: ArtifactSpec, executable: xla::PjRtLoadedExecutable) -> Self {
        LoadedModel { spec, executable }
    }

    /// Batch size this artifact was lowered for.
    pub fn batch(&self) -> usize {
        self.spec.batch
    }

    /// Validate + convert + execute. Returns the flattened f32 output
    /// (shape `spec.outputs[0].shape`).
    pub fn run(&self, inputs: &[InputValue]) -> Result<Vec<f32>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (value, tensor_spec) in inputs.iter().zip(&self.spec.inputs) {
            if value.dtype() != tensor_spec.dtype {
                bail!(
                    "input '{}': dtype mismatch (artifact wants {:?})",
                    tensor_spec.name,
                    tensor_spec.dtype
                );
            }
            if value.len() != tensor_spec.numel() {
                bail!(
                    "input '{}': got {} elements, want {} (shape {:?})",
                    tensor_spec.name,
                    value.len(),
                    tensor_spec.numel(),
                    tensor_spec.shape
                );
            }
            literals.push(to_literal(value, tensor_spec)?);
        }
        let result = self
            .executable
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute '{}'", self.spec.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .context("device → host transfer")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = literal.to_tuple1().context("unwrap result tuple")?;
        let values = out.to_vec::<f32>().context("read f32 output")?;
        let want = self.spec.outputs[0].numel();
        if values.len() != want {
            bail!(
                "artifact '{}' returned {} elements, manifest says {}",
                self.spec.name,
                values.len(),
                want
            );
        }
        Ok(values)
    }
}
