//! Stub PJRT client compiled when the `xla` feature is off (the `xla`
//! bindings crate is not in the offline vendor set).
//!
//! [`Runtime::new`] always returns an error, so a [`Runtime`] value can
//! never exist in a stub build; the remaining methods exist purely so
//! downstream code typechecks identically against both configurations.

use super::executable::LoadedModel;
use super::registry::Registry;
use anyhow::{bail, Result};

/// Stand-in for the PJRT client wrapper. Construction always fails in
/// builds without the `xla` feature.
pub struct Runtime {
    pub registry: Registry,
}

impl Runtime {
    /// Always fails: the PJRT runtime needs the `xla` feature.
    pub fn new(registry: Registry) -> Result<Runtime> {
        let _ = &registry;
        bail!("XLA/PJRT runtime unavailable: built without the `xla` cargo feature")
    }

    pub fn new_default() -> Result<Runtime> {
        Runtime::new(Registry::open_default()?)
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the `xla` feature)".to_string()
    }

    /// Unreachable in practice ([`Runtime::new`] never succeeds), kept
    /// for API parity with the real client.
    pub fn load(&self, name: &str) -> Result<LoadedModel> {
        bail!("cannot load artifact '{name}': built without the `xla` cargo feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_fails_with_clear_message() {
        let err = Runtime::new_default()
            .err()
            .map(|e| format!("{e:#}"))
            .unwrap_or_default();
        // Either the registry is missing (no artifacts) or the stub
        // reports the missing feature — both are descriptive.
        assert!(
            err.contains("xla") || err.contains("make artifacts"),
            "unhelpful stub error: {err}"
        );
    }
}
