//! PJRT CPU client wrapper: compile HLO text once, hand out
//! [`LoadedModel`]s.

use super::executable::LoadedModel;
use super::registry::{ArtifactSpec, Registry};
use anyhow::{Context, Result};

/// One PJRT client plus the artifact registry. Not `Send` — construct
/// and use on a single thread (see module docs).
pub struct Runtime {
    client: xla::PjRtClient,
    pub registry: Registry,
}

impl Runtime {
    /// Create a CPU PJRT client and open the registry at `dir`
    /// (or the default location).
    pub fn new(registry: Registry) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, registry })
    }

    pub fn new_default() -> Result<Runtime> {
        Runtime::new(Registry::open_default()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by name.
    pub fn load(&self, name: &str) -> Result<LoadedModel> {
        let spec: ArtifactSpec = self.registry.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .with_context(|| format!("parse HLO text {}", spec.path.display()))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let executable = self
            .client
            .compile(&computation)
            .with_context(|| format!("PJRT compile '{name}'"))?;
        Ok(LoadedModel::new(spec, executable))
    }
}

// Tests that need a live PJRT client live in `rust/tests/` (integration)
// because compiling artifacts requires `make artifacts` to have run.
#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn load_fails_cleanly_for_unknown_artifact() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let rt = Runtime::new(Registry::open(&dir).unwrap()).unwrap();
        assert!(rt.load("does_not_exist").is_err());
    }
}
