//! VSQ model execution: an [`Mlp`] whose weights are int8/int4 with
//! per-row-group scales ([`crate::quant::vsq`]), run through the
//! batched integer kernel ([`crate::nn::kernels::vsq_batch`]).
//!
//! This is the low-bit sibling of
//! [`crate::fpga::accelerator::QuantizedMlp`]: same layer sequencing,
//! same SIMD-dispatched bias+activation output stage, but the matmul
//! operand is 4–8× smaller than f32 — the serving win is memory
//! bandwidth, not arithmetic (EXPERIMENTS.md §Quantized serving).
//!
//! Bit-exactness contract: the integer dot is exact on every dispatch
//! path and the kernel never splits a reduction across threads, so a
//! `VsqMlp` forward is bit-identical across `test_paths()` and
//! `EDGEMLP_GEMM_THREADS` settings (pinned by the conformance suite).

use crate::nn::activations::Activation;
use crate::nn::kernels::{simd, vsq_matmul_batch};
use crate::nn::mlp::Mlp;
use crate::nn::tensor::Matrix;
use crate::quant::vsq::{quantize_data_i8_into, VsqTensor};
use crate::quant::Calibration;

/// Default per-vector scale granularity: one f32 scale per 16 output
/// rows — VS-Quant's sweet spot between per-tensor (too coarse at
/// 4 bits) and per-row (scale storage ≈ int4 payload on small layers).
pub const DEFAULT_GROUP_ROWS: usize = 16;

/// One VSQ layer: integer weights, f32 bias, and the layer's symmetric
/// int8 input range.
#[derive(Debug, Clone)]
pub struct VsqLayer {
    pub w: VsqTensor,
    pub b: Vec<f32>,
    pub activation: Activation,
    /// Symmetric int8 input range: inputs quantize as
    /// `round(x · 127 / d_scale)`.
    pub d_scale: f32,
}

impl VsqLayer {
    /// One layer of the batched path: quantize `src` to int8 codes, run
    /// the weight-stationary integer kernel into `dst` (resized in
    /// place — every element is overwritten), then bias + activation in
    /// the same SIMD-dispatched output stage the SPx path uses. `x_q`
    /// is a caller-owned staging buffer reused across calls.
    pub fn forward_batch_into(&self, src: &Matrix, dst: &mut Matrix, x_q: &mut Vec<i8>) {
        let batch = src.rows;
        let (m, n) = (self.w.rows(), self.w.cols());
        debug_assert_eq!(src.cols, n);
        quantize_data_i8_into(&src.data, self.d_scale, x_q);
        dst.rows = batch;
        dst.cols = m;
        dst.data.resize(batch * m, 0.0);
        vsq_matmul_batch(&self.w, x_q, batch, self.d_scale, &mut dst.data);
        simd::active_path().bias_activation(&mut dst.data, &self.b, self.activation);
    }
}

/// An MLP quantized to int8 or int4 with per-row-group scales.
#[derive(Debug, Clone)]
pub struct VsqMlp {
    pub layers: Vec<VsqLayer>,
    bits: u8,
}

impl VsqMlp {
    /// Quantize a trained MLP to `bits` ∈ {8, 4}. `calib_inputs` (if
    /// given) calibrates each layer's `d_scale` as the max-abs
    /// activation over the batch; otherwise scales default to 1.0
    /// (correct for sigmoid networks on `[0,1]` inputs — the paper's
    /// MNIST setting). Deterministic: requantizing the same `Mlp`
    /// reproduces the same codes and scales, which is what lets the
    /// registry derive VSQ artifacts on load without a blob format
    /// change.
    pub fn from_mlp(
        mlp: &Mlp,
        bits: u8,
        group_rows: usize,
        calibration: Calibration,
        calib_inputs: Option<&Matrix>,
    ) -> Self {
        let mut d_scales = vec![1.0f32; mlp.layers.len()];
        if let Some(x) = calib_inputs {
            let trace = mlp.forward_trace(x);
            for (i, scale) in d_scales.iter_mut().enumerate() {
                let max = trace[i].data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                if max > 0.0 {
                    *scale = max;
                }
            }
        }
        let layers = mlp
            .layers
            .iter()
            .zip(d_scales)
            .map(|(l, d_scale)| VsqLayer {
                w: VsqTensor::encode(
                    bits,
                    group_rows,
                    &l.w.data,
                    l.w.rows,
                    l.w.cols,
                    calibration,
                ),
                b: l.b.clone(),
                activation: l.activation,
                d_scale,
            })
            .collect();
        VsqMlp { layers, bits }
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0].w.cols()
    }

    pub fn output_dim(&self) -> usize {
        self.layers.last().expect("empty model").w.rows()
    }

    /// Batched forward: `x` is `B × input_dim`, result `B × output_dim`.
    /// Ping-pong buffers like the SPx path; the int8 staging vector is
    /// reused across layers.
    pub fn forward_batch(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.input_dim(), "input dim {} vs {}", x.cols, self.input_dim());
        let mut ping = Matrix::zeros(0, 0);
        let mut pong = Matrix::zeros(0, 0);
        let mut x_q: Vec<i8> = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            if li == 0 {
                layer.forward_batch_into(x, &mut ping, &mut x_q);
            } else if li % 2 == 1 {
                layer.forward_batch_into(&ping, &mut pong, &mut x_q);
            } else {
                layer.forward_batch_into(&pong, &mut ping, &mut x_q);
            }
        }
        if self.layers.len() % 2 == 1 {
            ping
        } else {
            pong
        }
    }

    /// Single-sample forward — a batch of one through the same kernel,
    /// so batch size can never change a bit.
    pub fn forward_one(&self, x: &[f32]) -> Vec<f32> {
        let m = Matrix::from_vec(1, x.len(), x.to_vec());
        self.forward_batch(&m).data
    }

    /// Packed weight bytes streamed per sample: integer codes (int4
    /// packs two per byte) + group scales + f32 biases. This is the
    /// lower-better `bytes_per_sample` number metrics and benches
    /// report per pool.
    pub fn weight_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.w.bytes_total() as u64 + 4 * l.b.len() as u64)
            .sum()
    }
}

/// The f32 weight footprint of a plain [`Mlp`] (weights + biases), the
/// baseline the VSQ/SPx `bytes_per_sample` numbers compare against.
pub fn f32_weight_bytes(mlp: &Mlp) -> u64 {
    mlp.layers
        .iter()
        .map(|l| 4 * (l.w.data.len() as u64 + l.b.len() as u64))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::MlpConfig;
    use crate::util::check::assert_allclose;
    use crate::util::rng::Pcg32;

    fn small_mlp(rng: &mut Pcg32) -> Mlp {
        Mlp::new(
            MlpConfig {
                sizes: vec![12, 8, 4],
                activations: vec![Activation::Sigmoid, Activation::Sigmoid],
            },
            rng,
        )
    }

    #[test]
    fn forward_batch_matches_forward_one_bitwise() {
        let mut rng = Pcg32::new(31);
        let mlp = small_mlp(&mut rng);
        for bits in [8u8, 4] {
            let v = VsqMlp::from_mlp(&mlp, bits, 4, Calibration::MaxAbs, None);
            for &batch in &[1usize, 2, 7] {
                let x = Matrix::random_uniform(batch, 12, 1.0, &mut rng);
                let batched = v.forward_batch(&x);
                assert_eq!((batched.rows, batched.cols), (batch, 4));
                for b in 0..batch {
                    let single = v.forward_one(x.row(b));
                    for (got, want) in batched.row(b).iter().zip(&single) {
                        assert_eq!(got.to_bits(), want.to_bits(), "bits {bits} sample {b}");
                    }
                }
            }
        }
    }

    #[test]
    fn int8_tracks_fp32_closely() {
        let mut rng = Pcg32::new(32);
        let mlp = small_mlp(&mut rng);
        let v = VsqMlp::from_mlp(&mlp, 8, 4, Calibration::MaxAbs, None);
        for _ in 0..8 {
            let x: Vec<f32> = (0..12).map(|_| rng.uniform() as f32).collect();
            let got = v.forward_one(&x);
            let want = mlp.forward_one(&x);
            // int8 weights + int8 data on a sigmoid net: a few ulps of
            // the activation, far inside 1e-2.
            assert_allclose(&got, &want, 2e-2, 2e-2);
        }
    }

    #[test]
    fn requantization_is_deterministic() {
        let mut rng = Pcg32::new(33);
        let mlp = small_mlp(&mut rng);
        let a = VsqMlp::from_mlp(&mlp, 4, 4, Calibration::MaxAbs, None);
        let b = VsqMlp::from_mlp(&mlp, 4, 4, Calibration::MaxAbs, None);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.w, lb.w);
        }
    }

    #[test]
    fn weight_bytes_shrink_with_bits() {
        let mut rng = Pcg32::new(34);
        let mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
        let v8 = VsqMlp::from_mlp(&mlp, 8, DEFAULT_GROUP_ROWS, Calibration::MaxAbs, None);
        let v4 = VsqMlp::from_mlp(&mlp, 4, DEFAULT_GROUP_ROWS, Calibration::MaxAbs, None);
        let f32b = f32_weight_bytes(&mlp);
        assert!(v8.weight_bytes() * 3 < f32b, "{} vs {}", v8.weight_bytes(), f32b);
        assert!(v4.weight_bytes() < v8.weight_bytes());
        // Packed int4 ≈ half of int8 (scales + biases add a sliver).
        assert!(v4.weight_bytes() * 2 < v8.weight_bytes() + f32b / 8);
    }

    #[test]
    fn calibration_sets_layer_scales() {
        let mut rng = Pcg32::new(35);
        let mlp = small_mlp(&mut rng);
        let x = Matrix::random_uniform(16, 12, 3.0, &mut rng);
        let v = VsqMlp::from_mlp(&mlp, 8, 4, Calibration::MaxAbs, Some(&x));
        assert!(v.layers[0].d_scale > 1.5);
        assert!(v.layers[1].d_scale <= 1.0 + 1e-6);
    }
}
