//! Classification metrics: accuracy (Eq 4.3 argmax decision) and a
//! confusion matrix for the examples' reports.

use super::mlp::{argmax, Mlp};
use super::tensor::Matrix;

/// Fraction of samples whose argmax matches the label.
pub fn accuracy(mlp: &Mlp, inputs: &Matrix, labels: &[usize]) -> f64 {
    assert_eq!(inputs.rows, labels.len());
    if labels.is_empty() {
        return 0.0;
    }
    let out = mlp.forward(inputs);
    let correct = labels
        .iter()
        .enumerate()
        .filter(|&(r, &label)| argmax(out.row(r)) == label)
        .count();
    correct as f64 / labels.len() as f64
}

/// Accuracy from precomputed predictions.
pub fn accuracy_from_preds(preds: &[usize], labels: &[usize]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    if labels.is_empty() {
        return 0.0;
    }
    let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    correct as f64 / labels.len() as f64
}

/// `classes × classes` confusion matrix; `m[true][pred]` counts.
pub fn confusion_matrix(preds: &[usize], labels: &[usize], classes: usize) -> Vec<Vec<usize>> {
    let mut m = vec![vec![0usize; classes]; classes];
    for (&p, &l) in preds.iter().zip(labels) {
        m[l][p] += 1;
    }
    m
}

/// Render a confusion matrix as an aligned text table.
pub fn format_confusion(m: &[Vec<usize>]) -> String {
    let mut s = String::from("true\\pred");
    for c in 0..m.len() {
        s.push_str(&format!("{c:>6}"));
    }
    s.push('\n');
    for (r, row) in m.iter().enumerate() {
        s.push_str(&format!("{r:>9}"));
        for &v in row {
            s.push_str(&format!("{v:>6}"));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_from_preds_basic() {
        assert_eq!(accuracy_from_preds(&[0, 1, 2], &[0, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy_from_preds(&[], &[]), 0.0);
    }

    #[test]
    fn confusion_diagonal_for_perfect() {
        let m = confusion_matrix(&[0, 1, 1, 2], &[0, 1, 1, 2], 3);
        assert_eq!(m[0][0], 1);
        assert_eq!(m[1][1], 2);
        assert_eq!(m[2][2], 1);
        assert_eq!(m[0][1] + m[1][0] + m[2][0], 0);
    }

    #[test]
    fn confusion_counts_sum_to_n() {
        let preds = [0usize, 1, 2, 0, 1];
        let labels = [1usize, 1, 2, 0, 0];
        let m = confusion_matrix(&preds, &labels, 3);
        let total: usize = m.iter().flatten().sum();
        assert_eq!(total, 5);
    }

    #[test]
    fn format_confusion_has_all_rows() {
        let m = confusion_matrix(&[0, 1], &[0, 1], 2);
        let s = format_confusion(&m);
        assert_eq!(s.lines().count(), 3);
    }
}
