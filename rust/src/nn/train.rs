//! SGD training with MSE loss — Eq 4.4–4.6 of the paper.
//!
//! The paper trains with mini-batch size `B = 64` and learning rate
//! `η = 0.5` (large, but appropriate for sigmoid+MSE where gradients are
//! small), estimating the full loss by Eq 4.5 and stepping by Eq 4.6.

use super::mlp::{argmax, Mlp};
use super::tensor::Matrix;
use crate::util::rng::Pcg32;

/// Training hyper-parameters (defaults = the paper's §4.1).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub batch_size: usize,
    pub learning_rate: f32,
    pub epochs: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { batch_size: 64, learning_rate: 0.5, epochs: 5, seed: 2021 }
    }
}

/// Per-epoch record returned by [`train`].
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub loss: f64,
    pub train_accuracy: f64,
}

/// Gradients of one mini-batch (same shapes as the model's layers).
pub struct Gradients {
    pub dw: Vec<Matrix>,
    pub db: Vec<Vec<f32>>,
}

/// MSE loss (Eq 4.5) against one-hot labels, averaged over the batch.
pub fn mse_loss(pred: &Matrix, labels: &[usize]) -> f64 {
    assert_eq!(pred.rows, labels.len());
    let mut total = 0.0f64;
    for (r, &label) in labels.iter().enumerate() {
        for (c, &p) in pred.row(r).iter().enumerate() {
            let y = if c == label { 1.0f32 } else { 0.0 };
            total += ((p - y) as f64).powi(2);
        }
    }
    total / labels.len() as f64
}

/// Backprop for MSE + per-layer activations.
///
/// With `L = (1/B) Σ ‖a_N − Y‖²`, the output delta is
/// `δ_N = (2/B)(a_N − Y) ⊙ σ'(z_N)` and recursively
/// `δ_i = (δ_{i+1} · W_{i+1}) ⊙ σ'(z_i)`; gradients are
/// `∂L/∂W_i = δ_iᵀ · a_{i-1}`, `∂L/∂b_i = Σ_batch δ_i`.
pub fn backward(mlp: &Mlp, activations: &[Matrix], labels: &[usize]) -> Gradients {
    let n_layers = mlp.layers.len();
    let batch = labels.len() as f32;
    let output = activations.last().unwrap();

    // δ for the output layer.
    let mut delta = Matrix::zeros(output.rows, output.cols);
    for (r, &label) in labels.iter().enumerate() {
        for c in 0..output.cols {
            let a = output.at(r, c);
            let y = if c == label { 1.0f32 } else { 0.0 };
            let dact = mlp.layers[n_layers - 1].activation.derivative_from_output(a);
            *delta.at_mut(r, c) = 2.0 / batch * (a - y) * dact;
        }
    }

    let mut dw = vec![Matrix::zeros(0, 0); n_layers];
    let mut db = vec![Vec::new(); n_layers];
    for i in (0..n_layers).rev() {
        // ∂L/∂W_i = δᵀ · a_{i-1}  (δ: B×out, a_{i-1}: B×in → out×in).
        dw[i] = delta.matmul_at(&activations[i]);
        db[i] = delta.col_sums();
        if i > 0 {
            // δ_{i-1} = (δ_i · W_i) ⊙ σ'(a_{i-1}).
            let mut prev = delta.matmul(&mlp.layers[i].w);
            let a_prev = &activations[i];
            debug_assert_eq!((prev.rows, prev.cols), (a_prev.rows, a_prev.cols));
            let act = mlp.layers[i - 1].activation;
            for (p, &a) in prev.data.iter_mut().zip(&a_prev.data) {
                *p *= act.derivative_from_output(a);
            }
            delta = prev;
        }
    }
    Gradients { dw, db }
}

/// Backprop for masked regression: loss `(1/B) Σ mask ⊙ (a_N − T)²`
/// where `T` is a dense target matrix. Used by Q-learning, where only
/// the taken action's Q-value receives gradient (mask one-hot per row).
pub fn backward_regression(
    mlp: &Mlp,
    activations: &[Matrix],
    targets: &Matrix,
    mask: Option<&Matrix>,
) -> Gradients {
    let n_layers = mlp.layers.len();
    let output = activations.last().unwrap();
    assert_eq!((output.rows, output.cols), (targets.rows, targets.cols));
    let batch = output.rows as f32;

    let mut delta = Matrix::zeros(output.rows, output.cols);
    for r in 0..output.rows {
        for c in 0..output.cols {
            let m = mask.map(|m| m.at(r, c)).unwrap_or(1.0);
            if m == 0.0 {
                continue;
            }
            let a = output.at(r, c);
            let dact = mlp.layers[n_layers - 1].activation.derivative_from_output(a);
            *delta.at_mut(r, c) = 2.0 / batch * m * (a - targets.at(r, c)) * dact;
        }
    }

    let mut dw = vec![Matrix::zeros(0, 0); n_layers];
    let mut db = vec![Vec::new(); n_layers];
    for i in (0..n_layers).rev() {
        dw[i] = delta.matmul_at(&activations[i]);
        db[i] = delta.col_sums();
        if i > 0 {
            let mut prev = delta.matmul(&mlp.layers[i].w);
            let a_prev = &activations[i];
            let act = mlp.layers[i - 1].activation;
            for (p, &a) in prev.data.iter_mut().zip(&a_prev.data) {
                *p *= act.derivative_from_output(a);
            }
            delta = prev;
        }
    }
    Gradients { dw, db }
}

/// One SGD step (Eq 4.6): `θ ← θ − η ∇L`.
pub fn apply_gradients(mlp: &mut Mlp, grads: &Gradients, lr: f32) {
    for (layer, (dw, db)) in mlp.layers.iter_mut().zip(grads.dw.iter().zip(&grads.db)) {
        layer.w.axpy_inplace(lr, dw);
        for (b, &g) in layer.b.iter_mut().zip(db) {
            *b -= lr * g;
        }
    }
}

/// Train `mlp` on `(inputs, labels)` for `config.epochs` epochs of
/// shuffled mini-batches; returns per-epoch loss/accuracy.
pub fn train(
    mlp: &mut Mlp,
    inputs: &Matrix,
    labels: &[usize],
    config: &TrainConfig,
) -> Vec<EpochStats> {
    assert_eq!(inputs.rows, labels.len());
    let mut rng = Pcg32::new(config.seed);
    let mut order: Vec<usize> = (0..inputs.rows).collect();
    let mut stats = Vec::with_capacity(config.epochs);
    // Reused across every mini-batch: the gather staging matrix and the
    // activation stack (see Mlp::forward_trace_into) — the training
    // loop allocates nothing per batch once these are warm.
    let mut x = Matrix::zeros(0, 0);
    let mut acts: Vec<Matrix> = Vec::new();
    for epoch in 0..config.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0f64;
        let mut correct = 0usize;
        let mut batches = 0usize;
        for chunk in order.chunks(config.batch_size) {
            // Gather the mini-batch.
            x.resize_zeroed(chunk.len(), inputs.cols);
            let mut y = Vec::with_capacity(chunk.len());
            for (bi, &si) in chunk.iter().enumerate() {
                x.data[bi * inputs.cols..(bi + 1) * inputs.cols]
                    .copy_from_slice(inputs.row(si));
                y.push(labels[si]);
            }
            mlp.forward_trace_into(&x, &mut acts);
            let out = acts.last().unwrap();
            epoch_loss += mse_loss(out, &y);
            for (r, &label) in y.iter().enumerate() {
                if argmax(out.row(r)) == label {
                    correct += 1;
                }
            }
            let grads = backward(mlp, &acts, &y);
            apply_gradients(mlp, &grads, config.learning_rate);
            batches += 1;
        }
        stats.push(EpochStats {
            epoch,
            loss: epoch_loss / batches as f64,
            train_accuracy: correct as f64 / inputs.rows as f64,
        });
    }
    stats
}

/// Gradient check helper: numerical ∂L/∂θ via central differences for a
/// single scalar parameter. Test-only but exported for the integration
/// suite.
pub fn numerical_grad_w(
    mlp: &mut Mlp,
    layer: usize,
    r: usize,
    c: usize,
    x: &Matrix,
    labels: &[usize],
    h: f32,
) -> f64 {
    let orig = mlp.layers[layer].w.at(r, c);
    *mlp.layers[layer].w.at_mut(r, c) = orig + h;
    let up = mse_loss(&mlp.forward(x), labels);
    *mlp.layers[layer].w.at_mut(r, c) = orig - h;
    let down = mse_loss(&mlp.forward(x), labels);
    *mlp.layers[layer].w.at_mut(r, c) = orig;
    (up - down) / (2.0 * h as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::activations::Activation;
    use crate::nn::mlp::MlpConfig;
    use crate::util::check::property;

    fn tiny_config() -> MlpConfig {
        MlpConfig {
            sizes: vec![3, 6, 2],
            activations: vec![Activation::Sigmoid, Activation::Sigmoid],
        }
    }

    #[test]
    fn gradients_match_numerical() {
        property("analytic grad == numerical grad", 8, |rng| {
            let mut mlp = Mlp::new(tiny_config(), rng);
            let x = Matrix::random_uniform(5, 3, 1.0, rng);
            let labels: Vec<usize> = (0..5).map(|_| rng.index(2)).collect();
            let acts = mlp.forward_trace(&x);
            let grads = backward(&mlp, &acts, &labels);
            for layer in 0..2 {
                let (rr, cc) = (
                    rng.index(mlp.layers[layer].w.rows),
                    rng.index(mlp.layers[layer].w.cols),
                );
                let num = numerical_grad_w(&mut mlp, layer, rr, cc, &x, &labels, 1e-3);
                let ana = grads.dw[layer].at(rr, cc) as f64;
                assert!(
                    (num - ana).abs() < 1e-3 + 0.05 * num.abs(),
                    "layer {layer} ({rr},{cc}): num {num} vs ana {ana}"
                );
            }
        });
    }

    #[test]
    fn loss_decreases_on_learnable_task() {
        // XOR-ish separable task: label = (x0 > 0).
        let mut rng = Pcg32::new(11);
        let n = 256;
        let mut data = Matrix::zeros(n, 3);
        let mut labels = Vec::with_capacity(n);
        for r in 0..n {
            for c in 0..3 {
                *data.at_mut(r, c) = rng.range(-1.0, 1.0) as f32;
            }
            labels.push(usize::from(data.at(r, 0) > 0.0));
        }
        let mut mlp = Mlp::new(tiny_config(), &mut rng);
        let config = TrainConfig { epochs: 30, learning_rate: 0.5, batch_size: 32, seed: 1 };
        let stats = train(&mut mlp, &data, &labels, &config);
        assert!(
            stats.last().unwrap().loss < stats[0].loss * 0.6,
            "loss {} → {}",
            stats[0].loss,
            stats.last().unwrap().loss
        );
        assert!(stats.last().unwrap().train_accuracy > 0.9);
    }

    #[test]
    fn mse_loss_perfect_prediction_is_zero() {
        let pred = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(mse_loss(&pred, &[0, 1]), 0.0);
    }

    #[test]
    fn apply_gradients_moves_weights() {
        let mut rng = Pcg32::new(3);
        let mut mlp = Mlp::new(tiny_config(), &mut rng);
        let before = mlp.layers[0].w.clone();
        let x = Matrix::random_uniform(4, 3, 1.0, &mut rng);
        let acts = mlp.forward_trace(&x);
        let grads = backward(&mlp, &acts, &[0, 1, 0, 1]);
        apply_gradients(&mut mlp, &grads, 0.5);
        assert_ne!(mlp.layers[0].w.data, before.data);
    }

    #[test]
    fn training_is_deterministic_given_seed() {
        let build = || {
            let mut rng = Pcg32::new(17);
            let mut mlp = Mlp::new(tiny_config(), &mut rng);
            let x = Matrix::random_uniform(64, 3, 1.0, &mut rng);
            let labels: Vec<usize> = (0..64).map(|i| i % 2).collect();
            let stats = train(&mut mlp, &x, &labels, &TrainConfig::default());
            (stats.last().unwrap().loss, mlp.layers[0].w.data.clone())
        };
        let (l1, w1) = build();
        let (l2, w2) = build();
        assert_eq!(l1, l2);
        assert_eq!(w1, w2);
    }
}
