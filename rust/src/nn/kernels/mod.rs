//! Software hot-path kernels (EXPERIMENTS.md §Perf).
//!
//! The paper's whole premise is that matrix multiplication dominates
//! MLP inference time, so the *software* baselines the experiments
//! measure against (the "CPU" row of Table I, the coordinator's
//! serving throughput) must be real kernels rather than naive loops:
//!
//! * [`gemm`] — a cache-blocked f32 GEMM in the BLIS style: an `MR×NR`
//!   register-tiled micro-kernel over packed operand panels, row-band
//!   parallelism via `std::thread::scope`, and a single-thread fallback
//!   for small shapes. It backs every `Matrix::matmul*` entry point
//!   through reusable thread-local packing scratch.
//! * [`spx_batch`] — a batched, weight-stationary SPx shift-add kernel
//!   over the element-major [`crate::quant::spx::PackedCodes`] stream:
//!   one pass over a weight row's codes serves the whole batch, where
//!   the per-sample path re-reads the codes for every sample. Bit-
//!   identical to [`crate::fpga::pu::dot_shift_add`] per sample (the
//!   accumulator is exact integer arithmetic, so summation order does
//!   not matter), which a property test pins down.

pub mod gemm;
pub mod spx_batch;

pub use gemm::gemm_into;
pub use spx_batch::{spx_matmul_batch, transpose_to_columns};
