//! Software hot-path kernels (EXPERIMENTS.md §Perf, §Perf gains).
//!
//! The paper's whole premise is that matrix multiplication dominates
//! MLP inference time, so the *software* baselines the experiments
//! measure against (the "CPU" row of Table I, the coordinator's
//! serving throughput) must be real kernels rather than naive loops:
//!
//! * [`gemm`] — a cache-blocked f32 GEMM in the BLIS style: a
//!   runtime-dispatched `MR×NR` register-tiled micro-kernel (AVX2+FMA /
//!   NEON / scalar — see [`simd`]) over packed operand panels, with
//!   row- or column-band parallelism on a persistent worker pool
//!   ([`pool`]). It backs every `Matrix::matmul*` entry point through
//!   reusable thread-local packing scratch.
//! * [`spx_batch`] — a batched, weight-stationary SPx shift-add kernel
//!   over the element-major [`crate::quant::spx::PackedCodes`] stream:
//!   one pass over a weight row's codes serves the whole batch, with
//!   the fast-row MAC vectorized as an exact widening `i32×i32→i64`
//!   multiply-accumulate. Bit-identical to
//!   [`crate::fpga::pu::dot_shift_add`] per sample on every dispatch
//!   path (integer arithmetic — summation order cannot matter), which
//!   property tests pin down.
//! * [`vsq_batch`] — the batched VSQ integer matmul (int8/int4 weights
//!   with per-row-group scales, [`crate::quant::vsq`]): a
//!   weight-stationary loop whose inner product is the SIMD-dispatched
//!   widening `i8×i8→i32` dot — exact, so bit-identical across paths
//!   and thread counts by construction.
//! * [`simd`] — the dispatch layer itself: runtime ISA detection,
//!   `EDGEMLP_FORCE_SCALAR=1` override, and the per-ISA kernels for
//!   the GEMM micro-tile, the SPx MAC, Q1.15 quantization, the batch
//!   transpose and the bias+activation output stage
//!   (docs/simd-dispatch.md).
//! * [`pipeline`] — the generic stage pipeline behind the
//!   stage-pipelined serving backend
//!   ([`crate::serve::pipeline_backend`]): one dedicated thread per
//!   stage, bounded SPSC channels, per-stage occupancy/stall counters,
//!   and panic containment that fails one job instead of the pipeline
//!   (docs/pipelined-engine.md).

pub mod gemm;
pub mod pipeline;
pub mod pool;
pub mod simd;
pub mod spx_batch;
pub mod vsq_batch;

pub use gemm::{gemm_into, gemm_into_with};
pub use pipeline::{StageError, StageFn, StagePipeline, StageSnapshot};
pub use simd::{active_path, force_scalar, native_path, DispatchPath};
pub use spx_batch::{spx_matmul_batch, transpose_to_columns};
pub use vsq_batch::vsq_matmul_batch;
