//! AVX2 + FMA kernels (x86_64). Every function carries
//! `#[target_feature(enable = "avx2,fma")]` and must only be called
//! after runtime detection — [`super::DispatchPath::Avx2Fma`] can only
//! be constructed on a host that passed `is_x86_feature_detected!`.
//!
//! Exactness notes:
//! * `mac_i32` uses `vpmuldq` (signed 32×32→64 multiply) — exact
//!   integer arithmetic, bit-identical to the scalar loop in any order;
//! * `quantize_into` rounds with the default nearest-even conversion
//!   then *fixes ties back to round-half-away-from-zero*, matching
//!   `f32::round` (and therefore `to_fixed`) bit-for-bit;
//! * `transpose_to_columns` is pure data movement;
//! * the f32 GEMM micro-kernel fuses multiply-adds, so it matches the
//!   scalar kernel only to FMA tolerance (docs/simd-dispatch.md).

use super::MicroOut;
use crate::nn::activations::{sigmoid_lut, Activation, SigmoidLut};
use core::arch::x86_64::*;

/// Full AVX2 tile: 6 rows × 16 columns (two `ymm` of C per row — 12
/// accumulator registers + 2 B streams + 1 broadcast stays inside the
/// 16-register file).
pub(crate) const MR: usize = 6;
pub(crate) const NR: usize = 16;

/// 6×16 f32 FMA micro-kernel: `out += Ap · Bp` over one depth block.
///
/// # Safety
/// Requires AVX2+FMA. `out.ptr` must be valid for writes of the clipped
/// `out.mr × out.nr` corner at row stride `out.ldc` and unaliased by
/// other threads; `ap`/`bp` must hold at least `6*kc` / `16*kc` values.
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn micro_6x16(ap: &[f32], bp: &[f32], kc: usize, out: MicroOut) {
    debug_assert!(ap.len() >= MR * kc && bp.len() >= NR * kc);
    debug_assert!(out.mr <= MR && out.nr <= NR);
    let mut acc = [[_mm256_setzero_ps(); 2]; MR];
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let b0 = _mm256_loadu_ps(b);
        let b1 = _mm256_loadu_ps(b.add(8));
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let ai = _mm256_set1_ps(*a.add(i));
            acc_row[0] = _mm256_fmadd_ps(ai, b0, acc_row[0]);
            acc_row[1] = _mm256_fmadd_ps(ai, b1, acc_row[1]);
        }
        a = a.add(MR);
        b = b.add(NR);
    }
    if out.mr == MR && out.nr == NR {
        // Full tile: vector read-modify-write straight into C.
        for (i, acc_row) in acc.iter().enumerate() {
            let c = out.ptr.add(i * out.ldc);
            _mm256_storeu_ps(c, _mm256_add_ps(_mm256_loadu_ps(c), acc_row[0]));
            let c8 = c.add(8);
            _mm256_storeu_ps(c8, _mm256_add_ps(_mm256_loadu_ps(c8), acc_row[1]));
        }
    } else {
        // Edge tile: spill the accumulators and add the valid corner.
        // Per-element arithmetic is identical to the full-tile path
        // (one f32 add of the same lane value), so tiling stays
        // deterministic across band splits.
        let mut buf = [[0.0f32; NR]; MR];
        for (acc_row, buf_row) in acc.iter().zip(buf.iter_mut()) {
            _mm256_storeu_ps(buf_row.as_mut_ptr(), acc_row[0]);
            _mm256_storeu_ps(buf_row.as_mut_ptr().add(8), acc_row[1]);
        }
        for (i, buf_row) in buf.iter().enumerate().take(out.mr) {
            let c = out.ptr.add(i * out.ldc);
            for (j, &v) in buf_row.iter().enumerate().take(out.nr) {
                *c.add(j) += v;
            }
        }
    }
}

/// `acc[i] += col[i] as i64 * v`, 4 lanes at a time. Exact: `vpmuldq`
/// multiplies the sign-extended low dwords into full 64-bit products.
///
/// # Safety
/// Requires AVX2. `acc` and `col` must be equal length; `v` must fit
/// in `i32`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn mac_i32(acc: &mut [i64], col: &[i32], v: i64) {
    debug_assert_eq!(acc.len(), col.len());
    let n = acc.len();
    let vb = _mm256_set1_epi64x(v);
    let mut i = 0;
    while i + 4 <= n {
        let df = _mm256_cvtepi32_epi64(_mm_loadu_si128(col.as_ptr().add(i) as *const __m128i));
        let prod = _mm256_mul_epi32(df, vb);
        let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
        _mm256_storeu_si256(
            acc.as_mut_ptr().add(i) as *mut __m256i,
            _mm256_add_epi64(a, prod),
        );
        i += 4;
    }
    while i < n {
        acc[i] += col[i] as i64 * v;
        i += 1;
    }
}

/// Widening i8 dot product `Σ a[i] as i32 * b[i] as i32`, 16 lanes per
/// iteration: sign-extend to i16 (`vpmovsxbw`), multiply-add adjacent
/// pairs into i32 (`vpmaddwd`), accumulate. Exact: products are
/// ≤ 127² so the i16 multiplies cannot saturate, and the per-lane i32
/// accumulators overflow only past ~10⁶ elements — far beyond any
/// layer fan-in — so this is bit-identical to the scalar loop.
///
/// # Safety
/// Requires AVX2. `a` and `b` must be equal length.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    while i + 16 <= n {
        let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(i) as *const __m128i));
        let vb = _mm256_cvtepi8_epi16(_mm_loadu_si128(b.as_ptr().add(i) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(va, vb));
        i += 16;
    }
    // Horizontal sum of the 8 i32 lanes.
    let s = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256::<1>(acc));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b00_00_11_10>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b00_00_00_01>(s));
    let mut sum = _mm_cvtsi128_si32(s);
    while i < n {
        sum += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    sum
}

/// Vectorized [`crate::fpga::pu::to_fixed`] over a slice: divide,
/// scale to Q1.15, clamp, round-half-away-from-zero, 8 lanes at a time.
///
/// The conversion instruction rounds ties to even; ties are then fixed
/// to away-from-zero (`diff == ±0.5` exactly iff the scaled value sat
/// halfway, because the subtraction of an f32 and its nearest integer
/// is exact), matching `f32::round` bit-for-bit. Clamping *before* the
/// round is equivalent to the scalar round-then-clamp for every finite
/// input and keeps the conversion in-range.
///
/// # Safety
/// Requires AVX2. `out.len()` must equal `d.len()`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn quantize_into(d: &[f32], d_scale: f32, out: &mut [i32]) {
    debug_assert_eq!(d.len(), out.len());
    if !(d_scale > 0.0) {
        // to_fixed maps everything to 0 when the scale is degenerate.
        out.fill(0);
        return;
    }
    let scale = _mm256_set1_ps(d_scale);
    let amp = _mm256_set1_ps(32768.0);
    let lo = _mm256_set1_ps(-32768.0);
    let hi = _mm256_set1_ps(32767.0);
    let half = _mm256_set1_ps(0.5);
    let neg_half = _mm256_set1_ps(-0.5);
    let zero = _mm256_setzero_ps();
    let n = d.len();
    let mut i = 0;
    while i + 8 <= n {
        let x = _mm256_loadu_ps(d.as_ptr().add(i));
        let y = _mm256_mul_ps(_mm256_div_ps(x, scale), amp);
        let yc = _mm256_min_ps(_mm256_max_ps(y, lo), hi);
        let r = _mm256_cvtps_epi32(yc); // nearest-even (default MXCSR)
        let diff = _mm256_sub_ps(yc, _mm256_cvtepi32_ps(r));
        let tie_up = _mm256_and_ps(
            _mm256_cmp_ps::<_CMP_EQ_OQ>(diff, half),
            _mm256_cmp_ps::<_CMP_GT_OQ>(yc, zero),
        );
        let tie_dn = _mm256_and_ps(
            _mm256_cmp_ps::<_CMP_EQ_OQ>(diff, neg_half),
            _mm256_cmp_ps::<_CMP_LT_OQ>(yc, zero),
        );
        // Masks are all-ones (-1): subtracting adds 1, adding subtracts 1.
        let r = _mm256_sub_epi32(r, _mm256_castps_si256(tie_up));
        let r = _mm256_add_epi32(r, _mm256_castps_si256(tie_dn));
        // NaN lanes: `max_ps` clamped them to `lo`, but the scalar cast
        // (`NaN as i32`) yields 0 — force the same here so every path
        // stays bit-identical even on hostile inputs.
        let nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(y, y);
        let r = _mm256_andnot_si256(_mm256_castps_si256(nan), r);
        _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, r);
        i += 8;
    }
    while i < n {
        out[i] = crate::fpga::pu::to_fixed(d[i], d_scale);
        i += 1;
    }
}

/// 8×8-blocked i32 transpose: `out[j*batch + b] = d[b*n + j]`.
///
/// # Safety
/// Requires AVX2. `d.len()` and `out.len()` must equal `batch * n`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn transpose_to_columns(d: &[i32], batch: usize, n: usize, out: &mut [i32]) {
    debug_assert_eq!(d.len(), batch * n);
    debug_assert_eq!(out.len(), batch * n);
    let bblocks = batch - batch % 8;
    let jblocks = n - n % 8;
    for b0 in (0..bblocks).step_by(8) {
        for j0 in (0..jblocks).step_by(8) {
            let src = d.as_ptr().add(b0 * n + j0);
            let r0 = _mm256_loadu_si256(src as *const __m256i);
            let r1 = _mm256_loadu_si256(src.add(n) as *const __m256i);
            let r2 = _mm256_loadu_si256(src.add(2 * n) as *const __m256i);
            let r3 = _mm256_loadu_si256(src.add(3 * n) as *const __m256i);
            let r4 = _mm256_loadu_si256(src.add(4 * n) as *const __m256i);
            let r5 = _mm256_loadu_si256(src.add(5 * n) as *const __m256i);
            let r6 = _mm256_loadu_si256(src.add(6 * n) as *const __m256i);
            let r7 = _mm256_loadu_si256(src.add(7 * n) as *const __m256i);
            // 32-bit interleave within 128-bit lanes…
            let t0 = _mm256_unpacklo_epi32(r0, r1);
            let t1 = _mm256_unpackhi_epi32(r0, r1);
            let t2 = _mm256_unpacklo_epi32(r2, r3);
            let t3 = _mm256_unpackhi_epi32(r2, r3);
            let t4 = _mm256_unpacklo_epi32(r4, r5);
            let t5 = _mm256_unpackhi_epi32(r4, r5);
            let t6 = _mm256_unpacklo_epi32(r6, r7);
            let t7 = _mm256_unpackhi_epi32(r6, r7);
            // …then 64-bit interleave…
            let u0 = _mm256_unpacklo_epi64(t0, t2);
            let u1 = _mm256_unpackhi_epi64(t0, t2);
            let u2 = _mm256_unpacklo_epi64(t1, t3);
            let u3 = _mm256_unpackhi_epi64(t1, t3);
            let u4 = _mm256_unpacklo_epi64(t4, t6);
            let u5 = _mm256_unpackhi_epi64(t4, t6);
            let u6 = _mm256_unpacklo_epi64(t5, t7);
            let u7 = _mm256_unpackhi_epi64(t5, t7);
            // …then stitch the 128-bit halves into whole columns.
            let c0 = _mm256_permute2x128_si256::<0x20>(u0, u4);
            let c1 = _mm256_permute2x128_si256::<0x20>(u1, u5);
            let c2 = _mm256_permute2x128_si256::<0x20>(u2, u6);
            let c3 = _mm256_permute2x128_si256::<0x20>(u3, u7);
            let c4 = _mm256_permute2x128_si256::<0x31>(u0, u4);
            let c5 = _mm256_permute2x128_si256::<0x31>(u1, u5);
            let c6 = _mm256_permute2x128_si256::<0x31>(u2, u6);
            let c7 = _mm256_permute2x128_si256::<0x31>(u3, u7);
            let dst = out.as_mut_ptr().add(j0 * batch + b0);
            _mm256_storeu_si256(dst as *mut __m256i, c0);
            _mm256_storeu_si256(dst.add(batch) as *mut __m256i, c1);
            _mm256_storeu_si256(dst.add(2 * batch) as *mut __m256i, c2);
            _mm256_storeu_si256(dst.add(3 * batch) as *mut __m256i, c3);
            _mm256_storeu_si256(dst.add(4 * batch) as *mut __m256i, c4);
            _mm256_storeu_si256(dst.add(5 * batch) as *mut __m256i, c5);
            _mm256_storeu_si256(dst.add(6 * batch) as *mut __m256i, c6);
            _mm256_storeu_si256(dst.add(7 * batch) as *mut __m256i, c7);
        }
        // Column tail for these 8 samples.
        for j in jblocks..n {
            for bi in 0..8 {
                out[j * batch + b0 + bi] = d[(b0 + bi) * n + j];
            }
        }
    }
    // Sample tail, all columns.
    for b in bblocks..batch {
        for j in 0..n {
            out[j * batch + b] = d[b * n + j];
        }
    }
}

/// Bias + activation over `bias.len()`-wide rows, bit-identical to the
/// scalar loop (the sigmoid LUT lerp reproduces the scalar expression
/// tree: separate multiplies and adds, no FMA contraction).
///
/// # Safety
/// Requires AVX2. `data.len()` must be a multiple of `bias.len()`,
/// which must be non-zero.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn bias_activation(data: &mut [f32], bias: &[f32], act: Activation) {
    for row in data.chunks_exact_mut(bias.len()) {
        match act {
            Activation::Sigmoid => bias_sigmoid_row(row, bias),
            Activation::Relu => bias_relu_row(row, bias),
            Activation::Identity => bias_identity_row(row, bias),
        }
    }
}

/// # Safety
/// Requires AVX2; `row.len() == bias.len()`.
#[target_feature(enable = "avx2")]
unsafe fn bias_identity_row(row: &mut [f32], bias: &[f32]) {
    let n = row.len();
    let mut i = 0;
    while i + 8 <= n {
        let x = _mm256_add_ps(
            _mm256_loadu_ps(row.as_ptr().add(i)),
            _mm256_loadu_ps(bias.as_ptr().add(i)),
        );
        _mm256_storeu_ps(row.as_mut_ptr().add(i), x);
        i += 8;
    }
    while i < n {
        row[i] += bias[i];
        i += 1;
    }
}

/// # Safety
/// Requires AVX2; `row.len() == bias.len()`.
#[target_feature(enable = "avx2")]
unsafe fn bias_relu_row(row: &mut [f32], bias: &[f32]) {
    let zero = _mm256_setzero_ps();
    let n = row.len();
    let mut i = 0;
    while i + 8 <= n {
        let x = _mm256_add_ps(
            _mm256_loadu_ps(row.as_ptr().add(i)),
            _mm256_loadu_ps(bias.as_ptr().add(i)),
        );
        // max(x, 0) with x first: a NaN sum yields 0, like f32::max.
        _mm256_storeu_ps(row.as_mut_ptr().add(i), _mm256_max_ps(x, zero));
        i += 8;
    }
    while i < n {
        row[i] = (row[i] + bias[i]).max(0.0);
        i += 1;
    }
}

/// Gather-based 256-entry sigmoid LUT, replicating
/// [`SigmoidLut::eval`]'s exact expression tree lane-wise (same
/// subtract/divide/multiply sequence, truncating index, same lerp; the
/// `x <= LO` / `x >= HI` saturation branches become blends).
///
/// # Safety
/// Requires AVX2; `row.len() == bias.len()`.
#[target_feature(enable = "avx2")]
unsafe fn bias_sigmoid_row(row: &mut [f32], bias: &[f32]) {
    let lut = sigmoid_lut();
    let table = lut.table().as_ptr();
    let lo = _mm256_set1_ps(SigmoidLut::LO);
    let hi = _mm256_set1_ps(SigmoidLut::HI);
    let span = _mm256_set1_ps(SigmoidLut::HI - SigmoidLut::LO);
    let entries = _mm256_set1_ps(256.0);
    let one = _mm256_set1_ps(1.0);
    let t_lo = _mm256_set1_ps(*table);
    let t_hi = _mm256_set1_ps(*table.add(256));
    let idx_max = _mm256_set1_epi32(255);
    let idx_min = _mm256_setzero_si256();
    let n = row.len();
    let mut i = 0;
    while i + 8 <= n {
        let x = _mm256_add_ps(
            _mm256_loadu_ps(row.as_ptr().add(i)),
            _mm256_loadu_ps(bias.as_ptr().add(i)),
        );
        let pos = _mm256_mul_ps(_mm256_div_ps(_mm256_sub_ps(x, lo), span), entries);
        // Truncate like `pos as usize`; clamp only to keep the gather
        // in-bounds for saturated lanes (their lerp is blended away).
        let idx = _mm256_min_epi32(_mm256_max_epi32(_mm256_cvttps_epi32(pos), idx_min), idx_max);
        let frac = _mm256_sub_ps(pos, _mm256_cvtepi32_ps(idx));
        let t0 = _mm256_i32gather_ps::<4>(table, idx);
        let t1 = _mm256_i32gather_ps::<4>(table.add(1), idx);
        let lerp = _mm256_add_ps(
            _mm256_mul_ps(t0, _mm256_sub_ps(one, frac)),
            _mm256_mul_ps(t1, frac),
        );
        let sat_lo = _mm256_cmp_ps::<_CMP_LE_OQ>(x, lo);
        let sat_hi = _mm256_cmp_ps::<_CMP_GE_OQ>(x, hi);
        let res = _mm256_blendv_ps(_mm256_blendv_ps(lerp, t_lo, sat_lo), t_hi, sat_hi);
        _mm256_storeu_ps(row.as_mut_ptr().add(i), res);
        i += 8;
    }
    while i < n {
        row[i] = lut.eval(row[i] + bias[i]);
        i += 1;
    }
}
