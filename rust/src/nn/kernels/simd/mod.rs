//! Runtime-dispatched SIMD back-ends for the hot-loop kernels
//! (EXPERIMENTS.md §Perf gains, docs/simd-dispatch.md).
//!
//! RedMulE and FantastIC4 get their efficiency from wide, register-
//! resident MAC datapaths; the CPU analogue is SIMD. Three hot loops
//! dispatch through [`DispatchPath`]:
//!
//! * the GEMM micro-kernel ([`super::gemm`]) — per-ISA `MR×NR` f32 FMA
//!   register tiles over the same packed panels (packing already
//!   produces unit-stride streams, so only the micro-kernel and the
//!   tile constants change per ISA);
//! * the batched SPx fast-row MAC ([`super::spx_batch`]) — a widening
//!   `i32 × i32 → i64` multiply-accumulate. Integer arithmetic is
//!   associative, so the vector form is **bit-identical** to the scalar
//!   shift-add datapath (pinned by property tests);
//! * the VSQ integer dot product ([`super::vsq_batch`]) — a widening
//!   `i8 × i8 → i32` dot (`vpmaddwd` / `SMULL`+`SADALP`), likewise
//!   exact and therefore bit-identical across paths;
//! * the batch staging around it — Q1.15 quantization
//!   ([`crate::fpga::pu::quantize_data_into`]), the batch transpose,
//!   and the bias + activation output stage.
//!
//! Detection happens once per process (`std::arch` feature detection on
//! x86_64; NEON is architecturally guaranteed on aarch64) and is
//! overridable with `EDGEMLP_FORCE_SCALAR=1`, which pins every kernel
//! to the portable scalar fallback. Tests and benches bypass the latch
//! with explicit-path entry points (`gemm_into_with`, the `*_path`
//! kernel internals) so both paths run in one process.
//!
//! Exactness contract: integer kernels (SPx MAC, quantization,
//! transpose) are bit-identical across paths; the f32 GEMM micro-kernel
//! may fuse multiply-adds, so its results match scalar only to FMA
//! tolerance (see docs/simd-dispatch.md for why that split is safe).

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(target_arch = "aarch64")]
pub mod neon;

use crate::nn::activations::Activation;
use once_cell::sync::Lazy;

/// One SIMD back-end. Variants exist only on architectures that can
/// execute them, so holding a non-`Scalar` path is proof the ISA is
/// compiled in (construction additionally proves it was detected).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPath {
    /// Portable fallback — the reference semantics for every kernel.
    Scalar,
    /// AVX2 + FMA: 8-lane f32 FMA, 4-lane widening i32 MAC.
    #[cfg(target_arch = "x86_64")]
    Avx2Fma,
    /// NEON: 4-lane f32 FMA, 2-lane widening i32 MAC.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// `EDGEMLP_FORCE_SCALAR` (any value except `0`/empty) pins
/// [`active_path`] to [`DispatchPath::Scalar`]. Latched on first read.
pub fn force_scalar() -> bool {
    static FORCE: Lazy<bool> = Lazy::new(|| {
        std::env::var("EDGEMLP_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    });
    *FORCE
}

/// Best path the host CPU supports, ignoring `EDGEMLP_FORCE_SCALAR`.
/// Used by tests/benches to exercise the native kernels explicitly.
pub fn native_path() -> DispatchPath {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return DispatchPath::Avx2Fma;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return DispatchPath::Neon;
        }
    }
    DispatchPath::Scalar
}

/// The process-wide dispatch decision: [`native_path`] unless
/// `EDGEMLP_FORCE_SCALAR` says otherwise. Latched on first use.
pub fn active_path() -> DispatchPath {
    static ACTIVE: Lazy<DispatchPath> = Lazy::new(|| {
        if force_scalar() {
            DispatchPath::Scalar
        } else {
            native_path()
        }
    });
    *ACTIVE
}

/// Destination of one micro-kernel call: the top-left corner of the
/// (clipped) `mr×nr` output tile, written with row stride `ldc`.
#[derive(Clone, Copy)]
pub(crate) struct MicroOut {
    pub ptr: *mut f32,
    /// Row stride of the full output matrix.
    pub ldc: usize,
    /// Valid rows of this tile (`<=` the path's full `MR`).
    pub mr: usize,
    /// Valid columns of this tile (`<=` the path's full `NR`).
    pub nr: usize,
}

impl DispatchPath {
    /// Human-readable name (bench JSON, docs).
    pub fn name(self) -> &'static str {
        match self {
            DispatchPath::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            DispatchPath::Avx2Fma => "avx2+fma",
            #[cfg(target_arch = "aarch64")]
            DispatchPath::Neon => "neon",
        }
    }

    /// GEMM micro-kernel rows (register-tile height). Sourced from the
    /// back-end modules' own constants — the unsafe kernels stride
    /// their pointers by these, so a single definition per ISA keeps
    /// packing and kernel in lock-step by construction.
    pub fn gemm_mr(self) -> usize {
        match self {
            DispatchPath::Scalar => scalar::MR,
            #[cfg(target_arch = "x86_64")]
            DispatchPath::Avx2Fma => avx2::MR,
            #[cfg(target_arch = "aarch64")]
            DispatchPath::Neon => neon::MR,
        }
    }

    /// GEMM micro-kernel columns (SIMD lanes of C per row); sourced
    /// from the back-end modules like [`DispatchPath::gemm_mr`].
    pub fn gemm_nr(self) -> usize {
        match self {
            DispatchPath::Scalar => scalar::NR,
            #[cfg(target_arch = "x86_64")]
            DispatchPath::Avx2Fma => avx2::NR,
            #[cfg(target_arch = "aarch64")]
            DispatchPath::Neon => neon::NR,
        }
    }

    /// GEMM row-block: the smallest multiple of the path's `MR` that
    /// is ≥ 64 rows, so packed A panels stay ~L2-resident and waste no
    /// partial strips mid-matrix (64 for 8-row tiles, 66 for AVX2's 6).
    pub fn gemm_mc(self) -> usize {
        64usize.div_ceil(self.gemm_mr()) * self.gemm_mr()
    }

    /// The register-tiled GEMM inner loop over one depth block:
    /// `out += Ap · Bp`. `ap` is `kc` column-slices of `MR` A values,
    /// `bp` is `kc` row-slices of `NR` B values (both zero-padded to the
    /// full tile); only the clipped `out.mr × out.nr` corner is written.
    ///
    /// # Safety
    /// `out.ptr` must be valid for writes of the clipped tile at row
    /// stride `out.ldc`, and must not alias memory any other thread is
    /// touching. `ap`/`bp` must hold at least `MR*kc` / `NR*kc` values.
    pub(crate) unsafe fn micro_kernel(self, ap: &[f32], bp: &[f32], kc: usize, out: MicroOut) {
        match self {
            DispatchPath::Scalar => scalar::micro_8x8(ap, bp, kc, out),
            #[cfg(target_arch = "x86_64")]
            DispatchPath::Avx2Fma => avx2::micro_6x16(ap, bp, kc, out),
            #[cfg(target_arch = "aarch64")]
            DispatchPath::Neon => neon::micro_8x8(ap, bp, kc, out),
        }
    }

    /// `acc[i] += col[i] as i64 * v` — the SPx fast-row MAC. `v` is a
    /// precomputed signed shift sum and must fit in `i32` (guaranteed:
    /// `|v| <= x · 2^(G-1) < 2^17`). Exact integer arithmetic, so every
    /// path produces bit-identical accumulators.
    pub(crate) fn mac_i32(self, acc: &mut [i64], col: &[i32], v: i64) {
        debug_assert_eq!(acc.len(), col.len());
        debug_assert!(i32::try_from(v).is_ok(), "shift sum {v} exceeds i32");
        match self {
            DispatchPath::Scalar => scalar::mac_i32(acc, col, v),
            #[cfg(target_arch = "x86_64")]
            // Safety: the variant only exists after AVX2 detection.
            DispatchPath::Avx2Fma => unsafe { avx2::mac_i32(acc, col, v) },
            #[cfg(target_arch = "aarch64")]
            // Safety: the variant only exists after NEON detection.
            DispatchPath::Neon => unsafe { neon::mac_i32(acc, col, v) },
        }
    }

    /// Widening i8 dot product `Σ a[i] as i32 * b[i] as i32` — the VSQ
    /// integer GEMM inner loop (`super::vsq_batch`). Exact on every
    /// path: products are ≤ 127², and i32 accumulation overflows only
    /// past ~10⁶ elements, so the SIMD forms are bit-identical to the
    /// scalar reference (pinned by `dot_i8_matches_scalar_bitwise`).
    pub(crate) fn dot_i8(self, a: &[i8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            DispatchPath::Scalar => scalar::dot_i8(a, b),
            #[cfg(target_arch = "x86_64")]
            // Safety: the variant only exists after AVX2 detection.
            DispatchPath::Avx2Fma => unsafe { avx2::dot_i8(a, b) },
            #[cfg(target_arch = "aarch64")]
            // Safety: the variant only exists after NEON detection.
            DispatchPath::Neon => unsafe { neon::dot_i8(a, b) },
        }
    }

    /// Q1.15 quantization of a whole vector: `out[i]` is bit-identical
    /// to [`crate::fpga::pu::to_fixed`]`(d[i], d_scale)` on every path
    /// (the x86 kernel fixes nearest-even ties back to the scalar
    /// round-half-away semantics; NEON's `FCVTAS` is ties-away
    /// natively). `out.len()` must equal `d.len()`.
    pub(crate) fn quantize_into(self, d: &[f32], d_scale: f32, out: &mut [i32]) {
        debug_assert_eq!(d.len(), out.len());
        match self {
            DispatchPath::Scalar => scalar::quantize_into(d, d_scale, out),
            #[cfg(target_arch = "x86_64")]
            // Safety: the variant only exists after AVX2 detection.
            DispatchPath::Avx2Fma => unsafe { avx2::quantize_into(d, d_scale, out) },
            #[cfg(target_arch = "aarch64")]
            // Safety: the variant only exists after NEON detection.
            DispatchPath::Neon => unsafe { neon::quantize_into(d, d_scale, out) },
        }
    }

    /// Transpose a row-major `batch×n` i32 batch into column-major
    /// `n×batch` (`out[j*batch + b] = d[b*n + j]`). Pure data movement —
    /// bit-identical on every path. `out.len()` must equal `d.len()`.
    pub(crate) fn transpose_to_columns(self, d: &[i32], batch: usize, n: usize, out: &mut [i32]) {
        debug_assert_eq!(d.len(), batch * n);
        debug_assert_eq!(out.len(), batch * n);
        match self {
            DispatchPath::Scalar => scalar::transpose_to_columns(d, batch, n, out),
            #[cfg(target_arch = "x86_64")]
            // Safety: the variant only exists after AVX2 detection.
            DispatchPath::Avx2Fma => unsafe { avx2::transpose_to_columns(d, batch, n, out) },
            #[cfg(target_arch = "aarch64")]
            // NEON has no gather/scatter win here; the scalar loop is
            // already load/store bound.
            DispatchPath::Neon => scalar::transpose_to_columns(d, batch, n, out),
        }
    }

    /// Output stage of the batched SPx path: per `bias.len()`-wide row,
    /// `x += bias` then the activation — bit-identical to the scalar
    /// per-element loop (sigmoid goes through the same 256-entry LUT
    /// with the same lerp expression tree). `data.len()` must be a
    /// multiple of `bias.len()`.
    pub(crate) fn bias_activation(self, data: &mut [f32], bias: &[f32], act: Activation) {
        if bias.is_empty() {
            return;
        }
        debug_assert_eq!(data.len() % bias.len(), 0);
        match self {
            DispatchPath::Scalar => scalar::bias_activation(data, bias, act),
            #[cfg(target_arch = "x86_64")]
            // Safety: the variant only exists after AVX2 detection.
            DispatchPath::Avx2Fma => unsafe { avx2::bias_activation(data, bias, act) },
            #[cfg(target_arch = "aarch64")]
            // NEON FMAX propagates NaN where `f32::max` quiets it; the
            // sigmoid LUT needs a gather. Vector bias+ReLU isn't worth
            // splitting semantics — keep the whole stage scalar on NEON.
            DispatchPath::Neon => scalar::bias_activation(data, bias, act),
        }
    }
}

/// The dispatch paths a parity test should cover on this host: always
/// `Scalar`, plus the native path when it differs.
pub fn test_paths() -> Vec<DispatchPath> {
    let mut paths = vec![DispatchPath::Scalar];
    let native = native_path();
    if native != DispatchPath::Scalar {
        paths.push(native);
    }
    paths
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::pu::to_fixed;
    use crate::util::check::property;

    #[test]
    fn active_path_is_consistent_and_named() {
        let p = active_path();
        assert_eq!(p, active_path(), "latched value must be stable");
        assert!(!p.name().is_empty());
        for p in test_paths() {
            assert!(p.gemm_mr() > 0 && p.gemm_nr() > 0);
            assert!(p.gemm_mc() >= p.gemm_mr());
        }
    }

    #[test]
    fn mac_i32_matches_scalar_bitwise() {
        property("SIMD i32·i64 MAC == scalar", 32, |rng| {
            let n = rng.index(40);
            let col: Vec<i32> =
                (0..n).map(|_| rng.range(-32768.0, 32768.0) as i32).collect();
            let v = rng.range(-65536.0, 65536.0) as i64;
            let init: Vec<i64> = (0..n).map(|_| rng.normal() as i64 * 1000).collect();
            let mut want = init.clone();
            scalar::mac_i32(&mut want, &col, v);
            for path in test_paths() {
                let mut got = init.clone();
                path.mac_i32(&mut got, &col, v);
                assert_eq!(got, want, "path {}", path.name());
            }
        });
    }

    #[test]
    fn dot_i8_matches_scalar_bitwise() {
        property("SIMD i8 dot == scalar", 32, |rng| {
            // Lengths straddle the 16-lane vector body, its tail, and
            // the serving fan-ins; values span the full int8 range and
            // the int4 subrange.
            let n = match rng.index(4) {
                0 => rng.index(40),
                1 => 784,
                2 => 128,
                _ => 16 * (1 + rng.index(8)) + rng.index(16),
            };
            let int4 = rng.uniform() < 0.5;
            let lim = if int4 { 7.0 } else { 127.0 };
            let gen = |rng: &mut crate::util::rng::Pcg32| -> Vec<i8> {
                (0..n).map(|_| rng.range(-lim - 0.49, lim + 0.49).round() as i8).collect()
            };
            let a = gen(rng);
            let b = gen(rng);
            let want = scalar::dot_i8(&a, &b);
            for path in test_paths() {
                assert_eq!(path.dot_i8(&a, &b), want, "path {} n {n}", path.name());
            }
        });
    }

    #[test]
    fn dot_i8_extremes_and_empty() {
        for path in test_paths() {
            assert_eq!(path.dot_i8(&[], &[]), 0, "path {}", path.name());
            // 784 × (-127·127) exercises the most negative realistic
            // accumulation at the serving fan-in.
            let a = vec![-127i8; 784];
            let b = vec![127i8; 784];
            assert_eq!(path.dot_i8(&a, &b), -127 * 127 * 784, "path {}", path.name());
            assert_eq!(path.dot_i8(&b, &b), 127 * 127 * 784, "path {}", path.name());
        }
    }

    #[test]
    fn quantize_matches_to_fixed_bitwise() {
        property("SIMD quantize == to_fixed", 32, |rng| {
            let n = rng.index(40);
            let scale = rng.range(0.1, 4.0) as f32;
            let d: Vec<f32> =
                (0..n).map(|_| rng.range(-2.0 * scale as f64, 2.0 * scale as f64) as f32).collect();
            let want: Vec<i32> = d.iter().map(|&x| to_fixed(x, scale)).collect();
            for path in test_paths() {
                let mut got = vec![0i32; n];
                path.quantize_into(&d, scale, &mut got);
                assert_eq!(got, want, "path {}", path.name());
            }
        });
    }

    #[test]
    fn quantize_ties_round_away_from_zero_on_every_path() {
        // Inputs engineered so `x/d_scale · 2^15` lands exactly on
        // k + 0.5 — where nearest-even and the scalar round-half-away
        // semantics disagree. (2k+1)/2^16 is exactly representable.
        let d_scale = 1.0f32;
        let mut d = Vec::new();
        for k in [0i32, 1, 2, 3, 100, 2001, 32700] {
            let x = (2 * k + 1) as f32 / 65536.0;
            d.push(x);
            d.push(-x);
        }
        // Saturation edges and zero, mixed in so the vector body (not
        // just the tail) sees them.
        d.extend_from_slice(&[0.0, 2.0, -2.0, 0.999_97, -0.999_99]);
        let want: Vec<i32> = d.iter().map(|&x| to_fixed(x, d_scale)).collect();
        for path in test_paths() {
            let mut got = vec![0i32; d.len()];
            path.quantize_into(&d, d_scale, &mut got);
            assert_eq!(got, want, "path {}", path.name());
        }
    }

    #[test]
    fn quantize_non_finite_inputs_match_scalar() {
        // NaN casts to 0 (`NaN as i32`), infinities saturate — on every
        // path, in vector-body and tail positions alike.
        let mut d = vec![0.25f32; 19];
        d[1] = f32::NAN;
        d[4] = f32::INFINITY;
        d[9] = f32::NEG_INFINITY;
        d[17] = f32::NAN; // scalar tail lane
        let want: Vec<i32> = d.iter().map(|&x| to_fixed(x, 1.0)).collect();
        assert_eq!((want[1], want[4], want[9]), (0, 32767, -32768));
        for path in test_paths() {
            let mut got = vec![7i32; d.len()];
            path.quantize_into(&d, 1.0, &mut got);
            assert_eq!(got, want, "path {}", path.name());
        }
    }

    #[test]
    fn quantize_degenerate_scale_yields_zeros() {
        let d = vec![0.5f32; 19];
        for path in test_paths() {
            for scale in [0.0f32, -1.0] {
                let mut got = vec![7i32; d.len()];
                path.quantize_into(&d, scale, &mut got);
                assert!(got.iter().all(|&v| v == 0), "path {}", path.name());
            }
        }
    }

    #[test]
    fn transpose_matches_scalar_bitwise() {
        property("SIMD transpose == scalar", 24, |rng| {
            let batch = rng.index(21);
            let n = rng.index(21);
            let d: Vec<i32> = (0..batch * n).map(|_| rng.next_u32() as i32).collect();
            let mut want = vec![0i32; batch * n];
            scalar::transpose_to_columns(&d, batch, n, &mut want);
            for path in test_paths() {
                let mut got = vec![0i32; batch * n];
                path.transpose_to_columns(&d, batch, n, &mut got);
                assert_eq!(got, want, "path {} batch {batch} n {n}", path.name());
            }
        });
    }

    #[test]
    fn bias_activation_matches_scalar_bitwise() {
        use crate::nn::activations::sigmoid_lut;
        property("SIMD bias+activation == scalar", 24, |rng| {
            let m = 1 + rng.index(20);
            let batch = 1 + rng.index(5);
            let bias: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
            // Cover the LUT saturation region (|x| > 8) as well as the
            // interpolated interior.
            let data: Vec<f32> =
                (0..batch * m).map(|_| rng.range(-12.0, 12.0) as f32).collect();
            for act in [Activation::Sigmoid, Activation::Relu, Activation::Identity] {
                let mut want = data.clone();
                scalar::bias_activation(&mut want, &bias, act);
                // Independent oracle for one row: the literal per-element
                // loop the accelerator used before this module existed.
                let lut = sigmoid_lut();
                for (w, (i, &x)) in want.iter().zip(data.iter().enumerate()).take(m) {
                    let z = x + bias[i % m];
                    let e = match act {
                        Activation::Sigmoid => lut.eval(z),
                        Activation::Relu => z.max(0.0),
                        Activation::Identity => z,
                    };
                    assert_eq!(w.to_bits(), e.to_bits());
                }
                for path in test_paths() {
                    let mut got = data.clone();
                    path.bias_activation(&mut got, &bias, act);
                    for (g, w) in got.iter().zip(&want) {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "path {} act {act:?}",
                            path.name()
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn bias_activation_hits_lut_boundaries_exactly() {
        // x == LO and x == HI must take the saturated branch on every
        // path (the scalar code returns table[0]/table[256] there).
        let bias = vec![0.0f32; 10];
        let data: Vec<f32> = vec![
            -8.0, 8.0, -7.999_999, 7.999_999, -100.0, 100.0, 0.0, -0.031_25, 0.031_25, 4.5,
        ];
        let mut want = data.clone();
        scalar::bias_activation(&mut want, &bias, Activation::Sigmoid);
        for path in test_paths() {
            let mut got = data.clone();
            path.bias_activation(&mut got, &bias, Activation::Sigmoid);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "path {} idx {i}", path.name());
            }
        }
    }
}
