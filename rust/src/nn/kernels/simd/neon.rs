//! NEON kernels (aarch64). NEON is architecturally mandatory on
//! aarch64, but every function still carries
//! `#[target_feature(enable = "neon")]` and is only reached through
//! [`super::DispatchPath::Neon`], which is constructed after
//! `is_aarch64_feature_detected!("neon")`.
//!
//! Exactness notes mirror the AVX2 back-end: the integer kernels
//! (`mac_i32` via `SMULL`, `quantize_into` via `FCVTAS` — which rounds
//! ties away from zero natively, exactly `f32::round`'s rule) are
//! bit-identical to scalar; the f32 GEMM micro-kernel fuses
//! multiply-adds and matches scalar only to FMA tolerance. The batch
//! transpose and the bias+activation stage stay on the scalar fallback
//! (see `DispatchPath::{transpose_to_columns, bias_activation}`).

use super::MicroOut;
use core::arch::aarch64::*;

/// Full NEON tile: 8 rows × 8 columns (two `float32x4` of C per row —
/// 16 accumulator registers out of the 32-register file).
pub(crate) const MR: usize = 8;
pub(crate) const NR: usize = 8;

/// 8×8 f32 FMA micro-kernel: `out += Ap · Bp` over one depth block.
///
/// # Safety
/// Requires NEON. `out.ptr` must be valid for writes of the clipped
/// `out.mr × out.nr` corner at row stride `out.ldc` and unaliased by
/// other threads; `ap`/`bp` must hold at least `8*kc` values each.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn micro_8x8(ap: &[f32], bp: &[f32], kc: usize, out: MicroOut) {
    debug_assert!(ap.len() >= MR * kc && bp.len() >= NR * kc);
    debug_assert!(out.mr <= MR && out.nr <= NR);
    let mut acc = [[vdupq_n_f32(0.0); 2]; MR];
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let b0 = vld1q_f32(b);
        let b1 = vld1q_f32(b.add(4));
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let ai = vdupq_n_f32(*a.add(i));
            acc_row[0] = vfmaq_f32(acc_row[0], ai, b0);
            acc_row[1] = vfmaq_f32(acc_row[1], ai, b1);
        }
        a = a.add(MR);
        b = b.add(NR);
    }
    if out.mr == MR && out.nr == NR {
        for (i, acc_row) in acc.iter().enumerate() {
            let c = out.ptr.add(i * out.ldc);
            vst1q_f32(c, vaddq_f32(vld1q_f32(c), acc_row[0]));
            let c4 = c.add(4);
            vst1q_f32(c4, vaddq_f32(vld1q_f32(c4), acc_row[1]));
        }
    } else {
        let mut buf = [[0.0f32; NR]; MR];
        for (acc_row, buf_row) in acc.iter().zip(buf.iter_mut()) {
            vst1q_f32(buf_row.as_mut_ptr(), acc_row[0]);
            vst1q_f32(buf_row.as_mut_ptr().add(4), acc_row[1]);
        }
        for (i, buf_row) in buf.iter().enumerate().take(out.mr) {
            let c = out.ptr.add(i * out.ldc);
            for (j, &v) in buf_row.iter().enumerate().take(out.nr) {
                *c.add(j) += v;
            }
        }
    }
}

/// `acc[i] += col[i] as i64 * v` via `SMULL` widening multiplies,
/// 4 lanes per iteration. Exact integer arithmetic.
///
/// # Safety
/// Requires NEON. `acc` and `col` must be equal length; `v` must fit
/// in `i32`.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn mac_i32(acc: &mut [i64], col: &[i32], v: i64) {
    debug_assert_eq!(acc.len(), col.len());
    let n = acc.len();
    let vv = vdup_n_s32(v as i32);
    let mut i = 0;
    while i + 4 <= n {
        let df = vld1q_s32(col.as_ptr().add(i));
        let lo = vmull_s32(vget_low_s32(df), vv);
        let hi = vmull_s32(vget_high_s32(df), vv);
        let a0 = vld1q_s64(acc.as_ptr().add(i));
        let a1 = vld1q_s64(acc.as_ptr().add(i + 2));
        vst1q_s64(acc.as_mut_ptr().add(i), vaddq_s64(a0, lo));
        vst1q_s64(acc.as_mut_ptr().add(i + 2), vaddq_s64(a1, hi));
        i += 4;
    }
    while i < n {
        acc[i] += col[i] as i64 * v;
        i += 1;
    }
}

/// Widening i8 dot product `Σ a[i] as i32 * b[i] as i32`, 16 lanes per
/// iteration: `SMULL` the i8 halves into i16 products, then pairwise
/// add-accumulate into i32 (`SADALP`). Exact — products are ≤ 127² and
/// the i32 accumulators overflow only past ~10⁶ elements, so this is
/// bit-identical to the scalar loop.
///
/// # Safety
/// Requires NEON. `a` and `b` must be equal length.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = vdupq_n_s32(0);
    let mut i = 0;
    while i + 16 <= n {
        let va = vld1q_s8(a.as_ptr().add(i));
        let vb = vld1q_s8(b.as_ptr().add(i));
        acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(va), vget_low_s8(vb)));
        acc = vpadalq_s16(acc, vmull_s8(vget_high_s8(va), vget_high_s8(vb)));
        i += 16;
    }
    let mut sum = vaddvq_s32(acc);
    while i < n {
        sum += a[i] as i32 * b[i] as i32;
        i += 1;
    }
    sum
}

/// Vectorized [`crate::fpga::pu::to_fixed`]: divide, scale to Q1.15,
/// round with `FCVTAS` (nearest, ties away from zero — `f32::round`'s
/// exact rule, saturating on overflow), then clamp to the Q1.15 range.
///
/// # Safety
/// Requires NEON. `out.len()` must equal `d.len()`.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn quantize_into(d: &[f32], d_scale: f32, out: &mut [i32]) {
    debug_assert_eq!(d.len(), out.len());
    if !(d_scale > 0.0) {
        out.fill(0);
        return;
    }
    let scale = vdupq_n_f32(d_scale);
    let amp = vdupq_n_f32(32768.0);
    let lo = vdupq_n_s32(-32768);
    let hi = vdupq_n_s32(32767);
    let n = d.len();
    let mut i = 0;
    while i + 4 <= n {
        let x = vld1q_f32(d.as_ptr().add(i));
        let y = vmulq_f32(vdivq_f32(x, scale), amp);
        let r = vcvtaq_s32_f32(y);
        vst1q_s32(out.as_mut_ptr().add(i), vminq_s32(vmaxq_s32(r, lo), hi));
        i += 4;
    }
    while i < n {
        out[i] = crate::fpga::pu::to_fixed(d[i], d_scale);
        i += 1;
    }
}
