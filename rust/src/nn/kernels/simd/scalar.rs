//! Portable scalar kernels — the universal fallback and the reference
//! semantics every SIMD path is tested against. These are the loops the
//! pre-dispatch code ran, so `EDGEMLP_FORCE_SCALAR=1` reproduces the
//! old behaviour exactly.

use super::MicroOut;
use crate::fpga::pu::to_fixed;
use crate::nn::activations::{sigmoid_lut, Activation};

/// Full scalar tile height/width (mirrors the pre-dispatch constants).
pub(crate) const MR: usize = 8;
pub(crate) const NR: usize = 8;

/// The 8×8 register-tiled inner loop: `out += Ap · Bp` over one depth
/// block. Eight independent accumulator rows let the compiler vectorize
/// the f32 reduction even without explicit intrinsics.
///
/// # Safety
/// `out.ptr` must be valid for writes of the clipped `out.mr × out.nr`
/// corner at row stride `out.ldc` and unaliased by other threads.
pub(crate) unsafe fn micro_8x8(ap: &[f32], bp: &[f32], kc: usize, out: MicroOut) {
    debug_assert!(ap.len() >= MR * kc && bp.len() >= NR * kc);
    debug_assert!(out.mr <= MR && out.nr <= NR);
    let mut acc = [[0.0f32; NR]; MR];
    for (ak, bk) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(kc) {
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let ai = ak[i];
            for (av, &bv) in acc_row.iter_mut().zip(bk) {
                *av += ai * bv;
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate().take(out.mr) {
        let row = out.ptr.add(i * out.ldc);
        for (j, &av) in acc_row.iter().enumerate().take(out.nr) {
            *row.add(j) += av;
        }
    }
}

/// `acc[i] += col[i] as i64 * v`.
pub(crate) fn mac_i32(acc: &mut [i64], col: &[i32], v: i64) {
    for (a, &df) in acc.iter_mut().zip(col) {
        *a += df as i64 * v;
    }
}

/// Widening i8 dot product: `Σ a[i] as i32 * b[i] as i32`. The VSQ
/// integer GEMM's reference semantics — exact, so the SIMD forms are
/// bit-identical by integer associativity.
pub(crate) fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as i32 * y as i32;
    }
    acc
}

/// Per-element [`to_fixed`].
pub(crate) fn quantize_into(d: &[f32], d_scale: f32, out: &mut [i32]) {
    for (o, &x) in out.iter_mut().zip(d) {
        *o = to_fixed(x, d_scale);
    }
}

/// `out[j*batch + b] = d[b*n + j]`.
pub(crate) fn transpose_to_columns(d: &[i32], batch: usize, n: usize, out: &mut [i32]) {
    if batch == 0 || n == 0 {
        return;
    }
    for (b, row) in d.chunks_exact(n).enumerate() {
        for (j, &v) in row.iter().enumerate() {
            out[j * batch + b] = v;
        }
    }
}

/// Bias broadcast + activation over `bias.len()`-wide rows — the exact
/// per-element loop the accelerator's batch path always used.
pub(crate) fn bias_activation(data: &mut [f32], bias: &[f32], act: Activation) {
    let lut = sigmoid_lut();
    for row in data.chunks_exact_mut(bias.len()) {
        for (o, &b) in row.iter_mut().zip(bias) {
            *o += b;
            *o = match act {
                Activation::Sigmoid => lut.eval(*o),
                Activation::Relu => o.max(0.0),
                Activation::Identity => *o,
            };
        }
    }
}
