//! Batched, weight-stationary VSQ integer matmul (int8/int4 weights,
//! per-row-group scales — see [`crate::quant::vsq`]).
//!
//! The dataflow mirrors [`super::spx_batch`]: weights stay resident
//! while the batch streams past, but here both operands are plain `i8`
//! rows, so no transpose is needed — a weight row and a sample row are
//! both contiguous, and the inner loop is the SIMD-dispatched widening
//! i8 dot product ([`super::simd::DispatchPath::dot_i8`]).
//!
//! Bit-exactness: the dot product is exact integer arithmetic (products
//! ≤ 127², i32 accumulation), so every dispatch path produces the
//! identical `i32`, and the single f32 scaling multiply per output
//! element (`dot · w_scale·d_step`, one rounding) is likewise
//! deterministic. The conformance suite pins batched-vs-per-sample and
//! scalar-vs-SIMD identity across `test_paths()` and thread counts —
//! thread-count invariance is structural (the kernel never splits a
//! dot product).

use crate::nn::kernels::simd::{self, DispatchPath};
use crate::quant::vsq::{data_step, VsqTensor};

/// `out[b][r] = (w_row_r · x_b) · scales[r/g] · d_scale/127` for every
/// sample `b`, on the active dispatch path.
///
/// * `w` — VSQ-quantized `m×n` weight matrix.
/// * `x_q` — row-major `batch×n` symmetric-int8 data codes (see
///   [`crate::quant::vsq::quantize_data_i8_into`]).
/// * `out` — row-major `batch×m` f32 output.
pub fn vsq_matmul_batch(w: &VsqTensor, x_q: &[i8], batch: usize, d_scale: f32, out: &mut [f32]) {
    vsq_matmul_batch_path(simd::active_path(), w, x_q, batch, d_scale, out);
}

/// [`vsq_matmul_batch`] pinned to an explicit dispatch path — parity
/// tests drive forced-scalar and native through this.
pub(crate) fn vsq_matmul_batch_path(
    path: DispatchPath,
    w: &VsqTensor,
    x_q: &[i8],
    batch: usize,
    d_scale: f32,
    out: &mut [f32],
) {
    let (m, n) = (w.rows(), w.cols());
    assert_eq!(x_q.len(), batch * n, "data {} vs {batch}×{n}", x_q.len());
    assert_eq!(out.len(), batch * m, "output {} vs {batch}×{m}", out.len());
    if batch == 0 || m == 0 {
        return;
    }
    let step = data_step(d_scale);
    for r in 0..m {
        let wr = w.row(r);
        // One multiply per output element, outside the integer loop —
        // the per-vector scale applied exactly once.
        let row_scale = w.scale_for_row(r) * step;
        for b in 0..batch {
            let xb = &x_q[b * n..(b + 1) * n];
            out[b * m + r] = path.dot_i8(wr, xb) as f32 * row_scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::vsq::quantize_data_i8_into;
    use crate::quant::Calibration;
    use crate::util::check::property;

    /// Literal per-element reference: the semantics every path must hit.
    fn reference(w: &VsqTensor, x_q: &[i8], batch: usize, d_scale: f32) -> Vec<f32> {
        let (m, n) = (w.rows(), w.cols());
        let step = data_step(d_scale);
        let mut out = vec![0.0f32; batch * m];
        for b in 0..batch {
            for r in 0..m {
                let mut acc = 0i32;
                for j in 0..n {
                    acc += w.row(r)[j] as i32 * x_q[b * n + j] as i32;
                }
                out[b * m + r] = acc as f32 * (w.scale_for_row(r) * step);
            }
        }
        out
    }

    fn assert_bitwise_eq(got: &[f32], want: &[f32], ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}");
        for (i, (a, e)) in got.iter().zip(want).enumerate() {
            assert_eq!(a.to_bits(), e.to_bits(), "{ctx} index {i}: {a} vs {e}");
        }
    }

    #[test]
    fn batched_matches_reference_bitwise_on_every_path() {
        property("batched VSQ == per-element reference", 24, |rng| {
            let bits = if rng.uniform() < 0.5 { 8u8 } else { 4 };
            let m = 1 + rng.index(12);
            let n = 1 + rng.index(100);
            let batch = 1 + rng.index(9);
            let group = 1 + rng.index(m);
            let wdata: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let w = VsqTensor::encode(bits, group, &wdata, m, n, Calibration::MaxAbs);
            let d_scale = rng.range(0.5, 4.0) as f32;
            let flat: Vec<f32> =
                (0..batch * n).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let mut x_q = Vec::new();
            quantize_data_i8_into(&flat, d_scale, &mut x_q);
            let want = reference(&w, &x_q, batch, d_scale);
            for path in simd::test_paths() {
                let mut got = vec![0.0f32; batch * m];
                vsq_matmul_batch_path(path, &w, &x_q, batch, d_scale, &mut got);
                assert_bitwise_eq(&got, &want, &format!("bits {bits} path {}", path.name()));
            }
        });
    }

    #[test]
    fn serving_shape_matches_across_paths() {
        // The 784→128 serving fan-in, where the SIMD body (not the
        // tail) does nearly all the work.
        let mut rng = crate::util::rng::Pcg32::new(23);
        let (m, n, batch) = (128usize, 784usize, 3usize);
        let wdata: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32 * 0.1).collect();
        let w = VsqTensor::encode(8, 16, &wdata, m, n, Calibration::MaxAbs);
        let flat: Vec<f32> = (0..batch * n).map(|_| rng.range(-1.0, 1.0) as f32).collect();
        let mut x_q = Vec::new();
        quantize_data_i8_into(&flat, 1.0, &mut x_q);
        let want = reference(&w, &x_q, batch, 1.0);
        for path in simd::test_paths() {
            let mut got = vec![0.0f32; batch * m];
            vsq_matmul_batch_path(path, &w, &x_q, batch, 1.0, &mut got);
            assert_bitwise_eq(&got, &want, path.name());
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let w = VsqTensor::encode(8, 2, &[0.25; 6], 2, 3, Calibration::MaxAbs);
        let mut out = Vec::new();
        vsq_matmul_batch(&w, &[], 0, 1.0, &mut out);
        assert!(out.is_empty());
    }
}
