//! Generic stage pipeline: the software analogue of the paper's §3.1 PU
//! stagger, lifted from rows to whole layers (docs/pipelined-engine.md).
//!
//! A [`StagePipeline`] is a fixed chain of worker threads, one per
//! stage, connected by bounded SPSC channels of capacity `depth`. Jobs
//! enter at stage 0 and exit after the last stage, strictly in
//! submission order; while job *i* is in stage *k*, job *i+1* can be in
//! stage *k−1* — up to `depth` jobs overlap in flight, exactly the
//! stagger [`crate::fpga::pipeline`] models analytically for the FPGA
//! fabric. The serving backends
//! ([`crate::serve::pipeline_backend`]) put one MLP layer in each
//! stage, so a batch streams through the layer chain the way a sample
//! streams through the paper's PU array.
//!
//! Fault containment: a stage that panics poisons only the job it was
//! holding. The panic is caught, the job is forwarded as a
//! [`StageError`] (later stages pass it through untouched), the stage
//! thread survives, and the driver receives `Err` for that job in its
//! ordinal position — subsequent jobs are unaffected. Pinned by the
//! fault-injection suite (`rust/tests/fault_injection.rs`).
//!
//! Observability: every stage counts jobs processed/failed and splits
//! its wall time into *busy* (running the stage function), *stall-in*
//! (waiting for upstream) and *stall-out* (blocked pushing downstream).
//! [`StagePipeline::snapshots`] exposes them as [`StageSnapshot`]s,
//! which the coordinator surfaces through
//! [`crate::coordinator::MetricsSnapshot::render`]. A pipeline built
//! with [`StagePipeline::new_traced`] additionally emits one `"run"`
//! span per job per stage into the given
//! [`crate::obs::trace::TraceRecorder`] (track `"<name>/<label>"`,
//! `request_id` = the job's submission sequence number), so the
//! per-stage stagger is visible on a Perfetto timeline.

use crate::obs::trace::TraceRecorder;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A stage body: transform the job in place. Runs on the stage's own
/// dedicated thread, so it may own heavyweight state (layer weights,
/// scratch buffers) captured by the closure.
pub type StageFn<J> = Box<dyn FnMut(&mut J) + Send + 'static>;

/// Why a job came out of the pipeline as `Err`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageError {
    /// Index of the stage whose function panicked.
    pub stage: usize,
    /// The panic message (best-effort downcast).
    pub message: String,
}

impl std::fmt::Display for StageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pipeline stage {} panicked: {}", self.stage, self.message)
    }
}

impl std::error::Error for StageError {}

/// Best-effort extraction of a panic payload's message.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Point-in-time view of one stage's counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageSnapshot {
    /// The stage's label (e.g. `layer0`).
    pub label: String,
    /// Jobs whose stage function completed.
    pub processed: u64,
    /// Jobs whose stage function panicked (forwarded as [`StageError`]).
    pub failed: u64,
    /// Seconds spent running the stage function.
    pub busy_s: f64,
    /// Seconds spent waiting for upstream input.
    pub stall_in_s: f64,
    /// Seconds spent blocked pushing downstream.
    pub stall_out_s: f64,
}

impl StageSnapshot {
    /// Fraction of observed wall time the stage spent computing —
    /// `busy / (busy + stall_in + stall_out)`, 0.0 before any work.
    pub fn occupancy(&self) -> f64 {
        let total = self.busy_s + self.stall_in_s + self.stall_out_s;
        if total > 0.0 {
            self.busy_s / total
        } else {
            0.0
        }
    }
}

/// Per-stage counters (nanosecond-resolution, lock-free updates).
#[derive(Default)]
struct StageCounter {
    processed: AtomicU64,
    failed: AtomicU64,
    busy_ns: AtomicU64,
    stall_in_ns: AtomicU64,
    stall_out_ns: AtomicU64,
}

/// What travels the channels: a live job, or the error that poisoned it.
enum Slot<J> {
    Ok(J),
    Failed(StageError),
}

struct ChanState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded SPSC channel (capacity = pipeline depth). `Mutex` + two
/// `Condvar`s, mirroring [`crate::coordinator::queue::BoundedQueue`]
/// minus the batch-draining pop this single-successor topology never
/// needs.
struct Chan<T> {
    state: Mutex<ChanState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> Chan<T> {
    fn new(capacity: usize) -> Arc<Chan<T>> {
        Arc::new(Chan {
            state: Mutex::new(ChanState { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        })
    }

    /// Blocking push; `Err` returns the item when the channel closed.
    fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.capacity {
                st.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Blocking pop; `None` means closed *and* drained.
    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// A running stage pipeline over jobs of type `J`. See the module docs
/// for the threading model; [`StagePipeline::submit`] /
/// [`StagePipeline::recv`] are the driver's two entry points, and
/// results come back in submission order.
///
/// The driver is responsible for bounding its in-flight count at
/// `depth` (submit at most `depth` jobs before draining): within that
/// bound neither call can deadlock, because the exit channel alone can
/// hold `depth` finished jobs.
pub struct StagePipeline<J: Send + 'static> {
    input: Arc<Chan<Slot<J>>>,
    output: Arc<Chan<Slot<J>>>,
    counters: Arc<Vec<StageCounter>>,
    labels: Vec<String>,
    threads: Vec<JoinHandle<()>>,
    depth: usize,
}

impl<J: Send + 'static> StagePipeline<J> {
    /// Spawn one thread per stage, chained by channels of capacity
    /// `depth` (clamped to ≥ 1). `name` prefixes the thread names.
    pub fn new(name: &str, depth: usize, stages: Vec<(String, StageFn<J>)>) -> StagePipeline<J> {
        Self::new_traced(name, depth, stages, None)
    }

    /// [`StagePipeline::new`] with a trace recorder: each stage emits a
    /// `"run"` span per job onto track `"<name>/<label>"`. Passing
    /// `None` (or a disabled recorder) costs nothing on the job path.
    pub fn new_traced(
        name: &str,
        depth: usize,
        stages: Vec<(String, StageFn<J>)>,
        tracer: Option<Arc<TraceRecorder>>,
    ) -> StagePipeline<J> {
        assert!(!stages.is_empty(), "pipeline needs at least one stage");
        let depth = depth.max(1);
        let n = stages.len();
        let chans: Vec<Arc<Chan<Slot<J>>>> = (0..=n).map(|_| Chan::new(depth)).collect();
        let counters: Arc<Vec<StageCounter>> =
            Arc::new((0..n).map(|_| StageCounter::default()).collect());
        let mut labels = Vec::with_capacity(n);
        let mut threads = Vec::with_capacity(n);
        for (k, (label, mut f)) in stages.into_iter().enumerate() {
            let input = chans[k].clone();
            let output = chans[k + 1].clone();
            let counters = counters.clone();
            let trace = tracer
                .as_ref()
                .map(|t| (t.clone(), Arc::<str>::from(format!("{name}/{label}").as_str())));
            let handle = std::thread::Builder::new()
                .name(format!("edgemlp-{name}-s{k}"))
                .spawn(move || stage_loop(k, &mut f, &input, &output, &counters[k], trace))
                .expect("spawn pipeline stage");
            labels.push(label);
            threads.push(handle);
        }
        StagePipeline {
            input: chans[0].clone(),
            output: chans[n].clone(),
            counters,
            labels,
            threads,
            depth,
        }
    }

    /// Maximum in-flight jobs the channels were sized for.
    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn num_stages(&self) -> usize {
        self.labels.len()
    }

    /// Enqueue a job at stage 0. Returns `false` if the pipeline was
    /// shut down. Blocks while the entry channel is full — which a
    /// driver that keeps ≤ `depth` jobs in flight never observes for
    /// long.
    pub fn submit(&self, job: J) -> bool {
        self.input.push(Slot::Ok(job)).is_ok()
    }

    /// Dequeue the next finished job, in submission order: the job
    /// itself, or the [`StageError`] that poisoned it. `None` means the
    /// pipeline was shut down and drained.
    pub fn recv(&self) -> Option<Result<J, StageError>> {
        match self.output.pop()? {
            Slot::Ok(job) => Some(Ok(job)),
            Slot::Failed(e) => Some(Err(e)),
        }
    }

    /// Current per-stage counters, in stage order.
    pub fn snapshots(&self) -> Vec<StageSnapshot> {
        self.labels
            .iter()
            .zip(self.counters.iter())
            .map(|(label, c)| StageSnapshot {
                label: label.clone(),
                processed: c.processed.load(Ordering::Relaxed),
                failed: c.failed.load(Ordering::Relaxed),
                busy_s: c.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                stall_in_s: c.stall_in_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                stall_out_s: c.stall_out_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            })
            .collect()
    }
}

impl<J: Send + 'static> Drop for StagePipeline<J> {
    fn drop(&mut self) {
        // Closing the entry channel cascades stage by stage: each stage
        // drains what it already has, then closes its own output. Any
        // jobs still in flight (≤ depth, which the exit channel can
        // hold) park in the exit channel and are dropped with it.
        self.input.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Body of one stage thread.
fn stage_loop<J, F: FnMut(&mut J)>(
    stage: usize,
    f: &mut F,
    input: &Chan<Slot<J>>,
    output: &Chan<Slot<J>>,
    counter: &StageCounter,
    trace: Option<(Arc<TraceRecorder>, Arc<str>)>,
) {
    // Local job ordinal: channels are SPSC and ordered, so this matches
    // the submission sequence — it labels the stage's trace spans.
    let mut seq: u64 = 0;
    loop {
        let t_in = Instant::now();
        let Some(slot) = input.pop() else {
            // Upstream closed and drained: propagate the close so the
            // next stage (or the driver) can wind down too.
            output.close();
            return;
        };
        counter.stall_in_ns.fetch_add(t_in.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let slot = match slot {
            // A job an earlier stage poisoned passes through untouched —
            // it must still come out in order so the driver can account
            // for it.
            Slot::Failed(e) => Slot::Failed(e),
            Slot::Ok(mut job) => {
                let t_busy = Instant::now();
                let result = catch_unwind(AssertUnwindSafe(|| f(&mut job)));
                counter.busy_ns.fetch_add(t_busy.elapsed().as_nanos() as u64, Ordering::Relaxed);
                seq += 1;
                if let Some((rec, track)) = &trace {
                    if rec.enabled() {
                        let start_us = rec.instant_us(t_busy);
                        rec.span("stage", "run", Some(track.clone()), start_us, seq);
                    }
                }
                match result {
                    Ok(()) => {
                        counter.processed.fetch_add(1, Ordering::Relaxed);
                        Slot::Ok(job)
                    }
                    Err(payload) => {
                        // The job's buffers are in an unknown state —
                        // drop them; only the error travels on.
                        counter.failed.fetch_add(1, Ordering::Relaxed);
                        Slot::Failed(StageError {
                            stage,
                            message: panic_message(payload.as_ref()),
                        })
                    }
                }
            }
        };
        let t_out = Instant::now();
        if output.push(slot).is_err() {
            return; // downstream closed mid-shutdown
        }
        counter.stall_out_ns.fetch_add(t_out.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn adder_stages(n: usize) -> Vec<(String, StageFn<i64>)> {
        let mut stages: Vec<(String, StageFn<i64>)> = Vec::new();
        for k in 0..n {
            stages.push((format!("s{k}"), Box::new(|j: &mut i64| *j += 1)));
        }
        stages
    }

    #[test]
    fn jobs_come_back_in_order() {
        let pipe = StagePipeline::new("order", 4, adder_stages(3));
        assert_eq!(pipe.num_stages(), 3);
        assert_eq!(pipe.depth(), 4);
        for round in 0..5 {
            for i in 0..4i64 {
                assert!(pipe.submit(round * 10 + i));
            }
            for i in 0..4i64 {
                assert_eq!(pipe.recv().unwrap().unwrap(), round * 10 + i + 3);
            }
        }
        let snaps = pipe.snapshots();
        assert_eq!(snaps.len(), 3);
        for s in &snaps {
            assert_eq!(s.processed, 20);
            assert_eq!(s.failed, 0);
            assert!((0.0..=1.0).contains(&s.occupancy()));
        }
    }

    #[test]
    fn stages_overlap_in_flight_jobs() {
        // 3 stages × 30 ms each, 4 jobs. Sequential would be 360 ms;
        // pipelined fill+drain is ~(3 + 3) × 30 = 180 ms. Sleeping
        // threads need no cores, so the bound holds on any CI box.
        let mut stages: Vec<(String, StageFn<u32>)> = Vec::new();
        for k in 0..3 {
            let f: StageFn<u32> = Box::new(|_| std::thread::sleep(Duration::from_millis(30)));
            stages.push((format!("s{k}"), f));
        }
        let pipe = StagePipeline::new("overlap", 4, stages);
        let t0 = Instant::now();
        for i in 0..4 {
            assert!(pipe.submit(i));
        }
        for _ in 0..4 {
            pipe.recv().unwrap().unwrap();
        }
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_millis(300),
            "4 jobs × 3 staggered 30 ms stages took {elapsed:?} (sequential would be 360 ms)"
        );
        // Interior stages saw real overlap: they stalled waiting for
        // input at least once after their first job.
        let snaps = pipe.snapshots();
        assert!(snaps[0].busy_s > 0.0);
    }

    #[test]
    fn panicking_stage_poisons_one_job_and_survives() {
        let stages: Vec<(String, StageFn<i64>)> = vec![
            ("double".into(), Box::new(|j: &mut i64| *j *= 2)),
            (
                "bomb".into(),
                Box::new(|j: &mut i64| {
                    if *j == 26 {
                        panic!("injected stage fault");
                    }
                    *j += 1;
                }),
            ),
        ];
        let pipe = StagePipeline::new("bomb", 4, stages);
        for i in [1i64, 13, 2] {
            assert!(pipe.submit(i));
        }
        assert_eq!(pipe.recv().unwrap().unwrap(), 3);
        let err = pipe.recv().unwrap().unwrap_err();
        assert_eq!(err.stage, 1);
        assert!(err.message.contains("injected stage fault"), "{err}");
        assert_eq!(pipe.recv().unwrap().unwrap(), 5);
        // The pipeline (including the stage that panicked) keeps
        // serving jobs afterwards.
        for i in 0..8i64 {
            assert!(pipe.submit(i));
            assert_eq!(pipe.recv().unwrap().unwrap(), i * 2 + 1);
        }
        let snaps = pipe.snapshots();
        assert_eq!(snaps[1].failed, 1);
        assert_eq!(snaps[1].processed, 10);
    }

    #[test]
    fn drop_with_jobs_in_flight_does_not_deadlock() {
        let pipe = StagePipeline::new("drop", 3, adder_stages(4));
        for i in 0..3 {
            assert!(pipe.submit(i));
        }
        drop(pipe); // joins all four stage threads
    }

    #[test]
    fn submit_after_drop_is_rejected_cleanly() {
        let pipe = StagePipeline::new("closed", 2, adder_stages(1));
        pipe.input.close();
        assert!(!pipe.submit(1));
        assert!(pipe.recv().is_none());
    }

    #[test]
    fn occupancy_of_empty_snapshot_is_zero() {
        let s = StageSnapshot::default();
        assert_eq!(s.occupancy(), 0.0);
    }

    #[test]
    fn traced_pipeline_emits_one_run_span_per_job_per_stage() {
        let rec = TraceRecorder::new(64);
        let pipe = StagePipeline::new_traced("tp", 2, adder_stages(2), Some(rec.clone()));
        for i in 0..3i64 {
            assert!(pipe.submit(i));
        }
        for i in 0..3i64 {
            assert_eq!(pipe.recv().unwrap().unwrap(), i + 2);
        }
        let events = rec.snapshot();
        let runs: Vec<_> =
            events.iter().filter(|e| e.cat == "stage" && e.name == "run").collect();
        assert_eq!(runs.len(), 6, "2 stages × 3 jobs");
        assert!(runs.iter().all(|e| e.dur_us.is_some()));
        for stage in ["tp/s0", "tp/s1"] {
            let seqs: Vec<u64> = runs
                .iter()
                .filter(|e| e.track.as_deref() == Some(stage))
                .map(|e| e.request_id)
                .collect();
            assert_eq!(seqs, vec![1, 2, 3], "{stage}");
        }
        // The untraced constructor records nothing anywhere.
        let quiet = StagePipeline::new("quiet", 2, adder_stages(1));
        assert!(quiet.submit(1));
        quiet.recv().unwrap().unwrap();
        assert_eq!(rec.snapshot().len(), events.len());
    }
}
