//! Cache-blocked, multithreaded f32 GEMM (EXPERIMENTS.md §Perf).
//!
//! Structure follows the BLIS/GotoBLAS decomposition:
//!
//! ```text
//! for jc in 0..n  step NC        // C/B column block   (shared per band)
//!   for pc in 0..k  step KC      // depth block → pack B (KC×NC, NR strips)
//!     for ic in 0..m  step MC    // row block   → pack A (MC×KC, MR strips)
//!       for jr, ir ...           // MR×NR micro-kernel over packed panels
//! ```
//!
//! The micro-kernel keeps an `MR×NR` accumulator block live across the
//! whole depth loop, so each loaded A/B element is reused `NR`/`MR`
//! times from registers — versus once in the naive dot-product form.
//! Panels are packed contiguously (zero-padded to full `MR`/`NR`
//! strips), so the micro-kernel sees unit-stride streams regardless of
//! operand transposition; `A·B`, `A·Bᵀ` and `Aᵀ·B` all funnel through
//! the same inner loop and differ only in how packing walks the source.
//!
//! Parallelism: the output rows are split into contiguous bands, one
//! `std::thread::scope` worker per band. Each band re-packs B itself —
//! redundant work that buys zero synchronization (the right trade at
//! the few-hundred-row shapes this crate serves). Small problems
//! (< ~2 MFLOP) stay on the calling thread. Packing buffers are
//! thread-local, so the single-thread path (every small/medium shape)
//! re-uses warm scratch and allocates nothing per call; the parallel
//! path pays a thread spawn + cold panel allocation per worker per
//! call — acceptable against its O(m·n·k) work, and a pool would be
//! the upgrade if profiles ever say otherwise.

use crate::nn::tensor::Matrix;
use std::cell::RefCell;

/// Micro-kernel rows: C rows accumulated in registers at once.
pub const MR: usize = 8;
/// Micro-kernel columns: one SIMD-width worth of C columns.
pub const NR: usize = 8;
/// Row-block: A panel is `MC×KC` (~64 KiB — L2-resident).
const MC: usize = 64;
/// Depth-block: panels span this much of the k dimension.
const KC: usize = 256;
/// Column-block: B panel is `KC×NC` (~512 KiB — outer-cache resident).
const NC: usize = 512;

/// Threads stop paying for themselves below this many FLOPs.
const MIN_PARALLEL_FLOPS: f64 = 2.0e6;

/// Per-thread packing scratch, reused across calls on the same thread.
#[derive(Default)]
struct Scratch {
    a_panel: Vec<f32>,
    b_panel: Vec<f32>,
}

fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    thread_local! {
        static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
    }
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// A possibly-transposed view of a row-major matrix: `at(r, c)` reads
/// element `(r, c)` of `op(M)`.
#[derive(Clone, Copy)]
struct MatView<'a> {
    data: &'a [f32],
    cols: usize,
    trans: bool,
}

impl<'a> MatView<'a> {
    fn new(m: &'a Matrix, trans: bool) -> Self {
        MatView { data: &m.data, cols: m.cols, trans }
    }

    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f32 {
        if self.trans {
            self.data[c * self.cols + r]
        } else {
            self.data[r * self.cols + c]
        }
    }
}

/// One band's worth of work: rows `row0..row0+rows` of `op(A)` against
/// all of `op(B)` (`kdim×n`).
struct BandJob<'a> {
    a: MatView<'a>,
    b: MatView<'a>,
    row0: usize,
    rows: usize,
    n: usize,
    kdim: usize,
}

/// `out = op(A) · op(B)` where `op` is transpose when the flag is set.
///
/// `out` must already have shape `m×n` (`m`/`n` being the dims of the
/// *operated* matrices); its contents are overwritten. Deterministic:
/// the same shape always uses the same blocking, so results are
/// bitwise reproducible across calls and thread counts (each output
/// element is accumulated by exactly one worker in a fixed k-order).
pub fn gemm_into(out: &mut Matrix, a: &Matrix, ta: bool, b: &Matrix, tb: bool) {
    let (m, kdim) = if ta { (a.cols, a.rows) } else { (a.rows, a.cols) };
    let (kb, n) = if tb { (b.cols, b.rows) } else { (b.rows, b.cols) };
    assert_eq!(kdim, kb, "gemm inner dims: {m}x{kdim} · {kb}x{n}");
    assert_eq!(
        (out.rows, out.cols),
        (m, n),
        "gemm output shape: want {m}x{n}, got {}x{}",
        out.rows,
        out.cols
    );
    out.data.fill(0.0);
    if m == 0 || n == 0 || kdim == 0 {
        return;
    }
    let av = MatView::new(a, ta);
    let bv = MatView::new(b, tb);
    let nt = num_threads(m, n, kdim);
    if nt <= 1 {
        let job = BandJob { a: av, b: bv, row0: 0, rows: m, n, kdim };
        with_scratch(|s| gemm_band(&mut out.data, &job, s));
        return;
    }
    let band = m.div_ceil(nt);
    std::thread::scope(|scope| {
        for (t, c_band) in out.data.chunks_mut(band * n).enumerate() {
            let rows = c_band.len() / n;
            let job = BandJob { a: av, b: bv, row0: t * band, rows, n, kdim };
            scope.spawn(move || with_scratch(|s| gemm_band(c_band, &job, s)));
        }
    });
}

/// Worker-thread cap: `EDGEMLP_GEMM_THREADS` env override, else
/// available parallelism capped at 8 (row bands beyond that stop
/// scaling at MLP-sized shapes).
fn configured_threads() -> usize {
    static OVERRIDE: once_cell::sync::Lazy<Option<usize>> = once_cell::sync::Lazy::new(|| {
        std::env::var("EDGEMLP_GEMM_THREADS").ok().and_then(|s| s.parse().ok())
    });
    if let Some(t) = *OVERRIDE {
        return t.max(1);
    }
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1).min(8)
}

fn num_threads(m: usize, n: usize, kdim: usize) -> usize {
    let cap = configured_threads();
    if cap <= 1 {
        return 1;
    }
    let flops = 2.0 * m as f64 * n as f64 * kdim as f64;
    if flops < MIN_PARALLEL_FLOPS {
        return 1;
    }
    // Keep at least a couple of MR strips per band.
    cap.min(m.div_ceil(2 * MR)).max(1)
}

/// Serial blocked GEMM over one row band. `c` is the band's `rows×n`
/// slice of the output (assumed zeroed), row `i` of `c` being row
/// `job.row0 + i` of the full product.
fn gemm_band(c: &mut [f32], job: &BandJob<'_>, scratch: &mut Scratch) {
    let (n, kdim, m) = (job.n, job.kdim, job.rows);
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..kdim).step_by(KC) {
            let kc = KC.min(kdim - pc);
            pack_b(job.b, pc, jc, kc, nc, &mut scratch.b_panel);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                pack_a(job.a, job.row0 + ic, pc, mc, kc, &mut scratch.a_panel);
                for jr in (0..nc).step_by(NR) {
                    let nr = NR.min(nc - jr);
                    let bp = &scratch.b_panel[(jr / NR) * NR * kc..][..NR * kc];
                    for ir in (0..mc).step_by(MR) {
                        let mr = MR.min(mc - ir);
                        let ap = &scratch.a_panel[(ir / MR) * MR * kc..][..MR * kc];
                        let mut acc = [[0.0f32; NR]; MR];
                        micro_kernel(ap, bp, &mut acc);
                        // Write back the valid mr×nr corner (padding
                        // rows/cols accumulated zeros).
                        for (i, acc_row) in acc.iter().enumerate().take(mr) {
                            let base = (ic + ir + i) * n + jc + jr;
                            for (cv, &av) in c[base..base + nr].iter_mut().zip(acc_row) {
                                *cv += av;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// The register-tiled inner loop: `acc += Ap · Bp` over one depth
/// block. `ap` is `kc` column-slices of `MR` A values; `bp` is `kc`
/// row-slices of `NR` B values; both unit-stride by construction.
#[inline(always)]
fn micro_kernel(ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    for (ak, bk) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let ai = ak[i];
            for (av, &bv) in acc_row.iter_mut().zip(bk) {
                *av += ai * bv;
            }
        }
    }
}

/// Pack rows `r0..r0+mc`, depth `k0..k0+kc` of `op(A)` into `MR`-row
/// strips, column-major within a strip (`buf[strip][k][i]`), zero-
/// padding the final partial strip.
fn pack_a(a: MatView<'_>, r0: usize, k0: usize, mc: usize, kc: usize, buf: &mut Vec<f32>) {
    let strips = mc.div_ceil(MR);
    buf.clear();
    buf.resize(strips * MR * kc, 0.0);
    for s in 0..strips {
        let dst = &mut buf[s * MR * kc..(s + 1) * MR * kc];
        let rbase = r0 + s * MR;
        let rows = MR.min(mc - s * MR);
        for k in 0..kc {
            let col = &mut dst[k * MR..k * MR + rows];
            for (i, slot) in col.iter_mut().enumerate() {
                *slot = a.at(rbase + i, k0 + k);
            }
        }
    }
}

/// Pack depth `k0..k0+kc`, columns `j0..j0+nc` of `op(B)` into `NR`-
/// column strips, row-major within a strip (`buf[strip][k][j]`), zero-
/// padding the final partial strip.
fn pack_b(b: MatView<'_>, k0: usize, j0: usize, kc: usize, nc: usize, buf: &mut Vec<f32>) {
    let strips = nc.div_ceil(NR);
    buf.clear();
    buf.resize(strips * NR * kc, 0.0);
    for s in 0..strips {
        let dst = &mut buf[s * NR * kc..(s + 1) * NR * kc];
        let jbase = j0 + s * NR;
        let cols = NR.min(nc - s * NR);
        for k in 0..kc {
            let row = &mut dst[k * NR..k * NR + cols];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = b.at(k0 + k, jbase + j);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, property};
    use crate::util::rng::Pcg32;

    fn naive(a: &Matrix, ta: bool, b: &Matrix, tb: bool) -> Matrix {
        let av = MatView::new(a, ta);
        let bv = MatView::new(b, tb);
        let (m, kdim) = if ta { (a.cols, a.rows) } else { (a.rows, a.cols) };
        let n = if tb { b.rows } else { b.cols };
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for k in 0..kdim {
                    acc += av.at(i, k) * bv.at(k, j);
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    fn check_all_ops(m: usize, k: usize, n: usize, rng: &mut Pcg32) {
        // A is m×k, B is k×n; also build the transposed storages so all
        // three op combinations exercise the same logical product.
        let a = Matrix::random_uniform(m, k, 1.0, rng);
        let b = Matrix::random_uniform(k, n, 1.0, rng);
        let at = a.transpose(); // k×m
        let bt = b.transpose(); // n×k
        let reference = naive(&a, false, &b, false);

        let mut out = Matrix::zeros(m, n);
        gemm_into(&mut out, &a, false, &b, false);
        assert_allclose(&out.data, &reference.data, 1e-5, 1e-5);

        gemm_into(&mut out, &a, false, &bt, true);
        assert_allclose(&out.data, &reference.data, 1e-5, 1e-5);

        gemm_into(&mut out, &at, true, &b, false);
        assert_allclose(&out.data, &reference.data, 1e-5, 1e-5);

        gemm_into(&mut out, &at, true, &bt, true);
        assert_allclose(&out.data, &reference.data, 1e-5, 1e-5);
    }

    #[test]
    fn exact_tile_multiples() {
        let mut rng = Pcg32::new(1);
        check_all_ops(MR, 16, NR, &mut rng);
        check_all_ops(2 * MR, KC.min(32), 2 * NR, &mut rng);
    }

    #[test]
    fn degenerate_shapes() {
        let mut rng = Pcg32::new(2);
        check_all_ops(1, 1, 1, &mut rng);
        check_all_ops(1, 9, 1, &mut rng);
        check_all_ops(1, 3, 11, &mut rng);
        check_all_ops(13, 5, 1, &mut rng);
    }

    #[test]
    fn empty_dims_give_empty_or_zero() {
        // k = 0: the product is defined and all-zero.
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 4);
        let mut out = Matrix::from_vec(3, 4, vec![7.0; 12]);
        gemm_into(&mut out, &a, false, &b, false);
        assert!(out.data.iter().all(|&v| v == 0.0));
        // m = 0 / n = 0: empty outputs, no panics.
        let mut empty = Matrix::zeros(0, 4);
        gemm_into(&mut empty, &Matrix::zeros(0, 5), false, &Matrix::zeros(5, 4), false);
        assert!(empty.data.is_empty());
        let mut empty2 = Matrix::zeros(3, 0);
        gemm_into(&mut empty2, &Matrix::zeros(3, 5), false, &Matrix::zeros(5, 0), false);
        assert!(empty2.data.is_empty());
    }

    #[test]
    fn tail_sizes_not_divisible_by_tiles() {
        property("blocked gemm == naive on ragged shapes", 24, |rng| {
            let m = 1 + rng.index(3 * MR + 1);
            let k = 1 + rng.index(40);
            let n = 1 + rng.index(3 * NR + 1);
            check_all_ops(m, k, n, rng);
        });
    }

    #[test]
    fn multithreaded_band_split_matches_naive() {
        // Big enough to clear MIN_PARALLEL_FLOPS → exercises the
        // scoped-thread row-band path (when >1 core is available).
        let mut rng = Pcg32::new(3);
        check_all_ops(150, 300, 70, &mut rng);
    }

    #[test]
    fn depth_blocking_accumulates_across_kc() {
        // k > KC forces multiple pc iterations accumulating into C.
        let mut rng = Pcg32::new(4);
        check_all_ops(9, KC + 37, 11, &mut rng);
    }

    #[test]
    fn output_is_overwritten_not_accumulated() {
        let mut rng = Pcg32::new(5);
        let a = Matrix::random_uniform(4, 6, 1.0, &mut rng);
        let b = Matrix::random_uniform(6, 5, 1.0, &mut rng);
        let mut out = Matrix::from_vec(4, 5, vec![1e6; 20]);
        gemm_into(&mut out, &a, false, &b, false);
        assert_allclose(&out.data, &naive(&a, false, &b, false).data, 1e-5, 1e-5);
    }

    #[test]
    fn deterministic_across_calls() {
        let mut rng = Pcg32::new(6);
        let a = Matrix::random_uniform(64, 120, 1.0, &mut rng);
        let b = Matrix::random_uniform(120, 48, 1.0, &mut rng);
        let mut out1 = Matrix::zeros(64, 48);
        let mut out2 = Matrix::zeros(64, 48);
        gemm_into(&mut out1, &a, false, &b, false);
        gemm_into(&mut out2, &a, false, &b, false);
        assert_eq!(out1.data, out2.data);
    }

    #[test]
    #[should_panic]
    fn inner_dim_mismatch_panics() {
        let mut out = Matrix::zeros(2, 2);
        gemm_into(&mut out, &Matrix::zeros(2, 3), false, &Matrix::zeros(4, 2), false);
    }
}
