//! Cache-blocked, SIMD-dispatched, pool-threaded f32 GEMM
//! (EXPERIMENTS.md §Perf, §Perf gains).
//!
//! Structure follows the BLIS/GotoBLAS decomposition:
//!
//! ```text
//! for jc in 0..n  step NC        // C/B column block   (shared per band)
//!   for pc in 0..k  step KC      // depth block → pack B (KC×NC, NR strips)
//!     for ic in 0..m  step MC    // row block   → pack A (MC×KC, MR strips)
//!       for jr, ir ...           // MR×NR micro-kernel over packed panels
//! ```
//!
//! The micro-kernel keeps an `MR×NR` accumulator block live across the
//! whole depth loop, so each loaded A/B element is reused `NR`/`MR`
//! times from registers — versus once in the naive dot-product form.
//! Panels are packed contiguously (zero-padded to full `MR`/`NR`
//! strips), so the micro-kernel sees unit-stride streams regardless of
//! operand transposition; `A·B`, `A·Bᵀ` and `Aᵀ·B` all funnel through
//! the same inner loop and differ only in how packing walks the source.
//!
//! The micro-kernel itself is **runtime-dispatched**
//! ([`crate::nn::kernels::simd`]): AVX2+FMA on x86_64 (6×16 f32 FMA
//! tile), NEON on aarch64 (8×8), with the portable scalar 8×8 kernel as
//! the universal fallback (`EDGEMLP_FORCE_SCALAR=1` pins it). Packing
//! is shared — only the tile constants and the inner loop change per
//! ISA.
//!
//! Parallelism: the output is split into contiguous bands — along `m`
//! (each band re-packs B itself: redundant work that buys zero
//! synchronization), or along `n` when the product is too short to
//! split by rows (small serving batches: m=8 × wide layers; each column
//! band then re-packs A). Bands run on a lazily-created **persistent
//! worker pool** ([`super::pool`]): parked threads with per-band job
//! handoff, so the serving path stops paying a thread spawn plus a
//! cold-scratch allocation per call — worker-thread-local packing
//! buffers stay warm across calls. Small problems (< ~1 MFLOP) stay on
//! the calling thread, which also always computes band 0 itself.
//!
//! Determinism: blocking is a function of shape and dispatch path only,
//! and each output element is accumulated by exactly one band in a
//! fixed k-order (band boundaries only decide *which* thread computes
//! an element, never the order of its additions), so results are
//! bitwise reproducible across calls and thread counts. Across
//! *dispatch paths* results differ within FMA tolerance — see
//! docs/simd-dispatch.md.

use crate::nn::kernels::pool::{self, Latch, LatchGuard};
use crate::nn::kernels::simd::{self, DispatchPath, MicroOut};
use crate::nn::tensor::Matrix;
use std::cell::RefCell;

/// Scalar micro-kernel rows (the fallback tile; SIMD paths carry their
/// own tile constants — see [`DispatchPath::gemm_mr`]).
pub const MR: usize = 8;
/// Scalar micro-kernel columns.
pub const NR: usize = 8;
/// Depth-block: panels span this much of the k dimension.
const KC: usize = 256;
/// Column-block: B panel is `KC×NC` (~512 KiB — outer-cache resident).
const NC: usize = 512;

/// Threads stop paying for themselves below this many FLOPs. The
/// pre-pool kernel drew this line at 2 MFLOP to amortize a per-call
/// thread spawn; a parked-worker handoff costs microseconds, so the
/// bar drops to where the batch-8 serving layer (m=8, k=784, n=128 =
/// 1.6 MFLOP — the shape the column split exists for) clears it.
const MIN_PARALLEL_FLOPS: f64 = 1.0e6;

/// Per-thread packing scratch, reused across calls on the same thread.
/// Pool workers are persistent, so their scratch stays warm across
/// GEMM calls — the point of the pool.
#[derive(Default)]
struct Scratch {
    a_panel: Vec<f32>,
    b_panel: Vec<f32>,
}

fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    thread_local! {
        static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
    }
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// A possibly-transposed view of a row-major matrix: `at(r, c)` reads
/// element `(r, c)` of `op(M)`.
#[derive(Clone, Copy)]
struct MatView<'a> {
    data: &'a [f32],
    cols: usize,
    trans: bool,
}

impl<'a> MatView<'a> {
    fn new(m: &'a Matrix, trans: bool) -> Self {
        MatView { data: &m.data, cols: m.cols, trans }
    }

    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f32 {
        if self.trans {
            self.data[c * self.cols + r]
        } else {
            self.data[r * self.cols + c]
        }
    }
}

/// One band of the output: a row range × column range rectangle.
#[derive(Clone, Copy)]
struct Band {
    row0: usize,
    rows: usize,
    col0: usize,
    cols: usize,
}

/// One band's worth of work: rows `row0..row0+rows` × columns
/// `col0..col0+cols` of `op(A)·op(B)`, written into the full `ldc`-
/// stride output.
struct BandJob<'a> {
    a: MatView<'a>,
    b: MatView<'a>,
    path: DispatchPath,
    band: Band,
    ldc: usize,
    kdim: usize,
}

/// `out = op(A) · op(B)` where `op` is transpose when the flag is set,
/// on the active dispatch path with the configured thread cap.
///
/// `out` must already have shape `m×n` (`m`/`n` being the dims of the
/// *operated* matrices); its contents are overwritten. Deterministic:
/// bitwise reproducible across calls and thread counts (see module
/// docs).
pub fn gemm_into(out: &mut Matrix, a: &Matrix, ta: bool, b: &Matrix, tb: bool) {
    gemm_into_with(simd::active_path(), configured_threads(), out, a, ta, b, tb);
}

/// [`gemm_into`] with an explicit dispatch path and thread cap —
/// the entry point parity tests and the perf benches use to compare
/// forced-scalar vs native and single-thread vs pool without touching
/// the process-wide latches.
pub fn gemm_into_with(
    path: DispatchPath,
    max_threads: usize,
    out: &mut Matrix,
    a: &Matrix,
    ta: bool,
    b: &Matrix,
    tb: bool,
) {
    let (m, kdim) = if ta { (a.cols, a.rows) } else { (a.rows, a.cols) };
    let (kb, n) = if tb { (b.cols, b.rows) } else { (b.rows, b.cols) };
    assert_eq!(kdim, kb, "gemm inner dims: {m}x{kdim} · {kb}x{n}");
    assert_eq!(
        (out.rows, out.cols),
        (m, n),
        "gemm output shape: want {m}x{n}, got {}x{}",
        out.rows,
        out.cols
    );
    out.data.fill(0.0);
    if m == 0 || n == 0 || kdim == 0 {
        return;
    }
    let av = MatView::new(a, ta);
    let bv = MatView::new(b, tb);
    // One raw base pointer per operand, created once and shared by
    // every band (re-deriving pointers mid-flight would invalidate the
    // outstanding ones under the aliasing rules).
    let c_ptr = out.data.as_mut_ptr();
    // The single-band decision allocates nothing: sub-threshold serving
    // shapes run thousands of times a second and must stay alloc-free.
    let Some(bands) = band_plan(path, max_threads.max(1), m, n, kdim) else {
        let whole = Band { row0: 0, rows: m, col0: 0, cols: n };
        let job = BandJob { a: av, b: bv, path, band: whole, ldc: n, kdim };
        // Safety: we hold `&mut out` for the whole call; the single
        // band covers exactly the m×n buffer.
        with_scratch(|s| unsafe { gemm_band(c_ptr, &job, s) });
        return;
    };

    struct SendConst(*const f32);
    unsafe impl Send for SendConst {}
    struct SendMut(*mut f32);
    unsafe impl Send for SendMut {}

    // Size the pool for whichever is larger: the env-configured cap or
    // this call's explicit request (the E9 bench sweeps past the env
    // default). Latched by the first multi-band call — so if an earlier
    // call latched it smaller than this request, re-plan against the
    // real worker count rather than queueing surplus bands that would
    // each redundantly re-pack their panels (and misreport a thread
    // sweep). Any band plan yields bitwise-identical results, so this
    // only changes scheduling.
    let pool = pool::global(configured_threads().max(max_threads).saturating_sub(1));
    let workers_cap = pool.workers() + 1;
    let bands = if bands.len() > workers_cap {
        match band_plan(path, workers_cap, m, n, kdim) {
            Some(replanned) => replanned,
            None => bands,
        }
    } else {
        bands
    };
    let latch = Latch::new(bands.len() - 1);
    let (a_ptr, a_len, a_cols) = (a.data.as_ptr(), a.data.len(), av.cols);
    let (b_ptr, b_len, b_cols) = (b.data.as_ptr(), b.data.len(), bv.cols);
    for &band in &bands[1..] {
        let latch = latch.clone();
        let (ap, bp, cp) = (SendConst(a_ptr), SendConst(b_ptr), SendMut(c_ptr));
        pool.submit(Box::new(move || {
            let _guard = LatchGuard(latch);
            // Safety: the dispatching call blocks on the latch before
            // returning (even if a band panics), so the borrows behind
            // these raw parts outlive the job; bands write disjoint
            // rectangles of the output.
            let av = MatView {
                data: unsafe { std::slice::from_raw_parts(ap.0, a_len) },
                cols: a_cols,
                trans: ta,
            };
            let bv = MatView {
                data: unsafe { std::slice::from_raw_parts(bp.0, b_len) },
                cols: b_cols,
                trans: tb,
            };
            let job = BandJob { a: av, b: bv, path, band, ldc: n, kdim };
            with_scratch(|s| unsafe { gemm_band(cp.0, &job, s) });
        }));
    }
    // The dispatching thread computes band 0 itself; a panic there must
    // still wait out the workers before unwinding past the borrows.
    let job0 = BandJob { a: av, b: bv, path, band: bands[0], ldc: n, kdim };
    let inline = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        with_scratch(|s| unsafe { gemm_band(c_ptr, &job0, s) })
    }));
    let worker_panicked = latch.wait();
    if let Err(payload) = inline {
        std::panic::resume_unwind(payload);
    }
    if worker_panicked {
        panic!("gemm pool worker panicked while computing a band");
    }
}

/// Worker-thread cap: `EDGEMLP_GEMM_THREADS` env override, else
/// available parallelism capped at 8 (bands beyond that stop scaling
/// at MLP-sized shapes). Read once. Public so the benches can report
/// the cap [`gemm_into`] actually runs under.
pub fn configured_threads() -> usize {
    static OVERRIDE: once_cell::sync::Lazy<Option<usize>> = once_cell::sync::Lazy::new(|| {
        std::env::var("EDGEMLP_GEMM_THREADS").ok().and_then(|s| s.parse().ok())
    });
    if let Some(t) = *OVERRIDE {
        return t.max(1);
    }
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1).min(8)
}

/// Split the `m×n` output into bands for `cap` threads: by rows when
/// there are at least two `2·MR` strips of them, else by columns
/// (wide-but-short products — small serving batches against wide
/// layers — previously never parallelized). `None` means "run the
/// whole product on the calling thread" (the cap is 1, or the problem
/// is under the FLOP threshold, or it is too small to band at all) —
/// returned without allocating, since that is the per-request hot
/// path. A `Some` always holds ≥ 2 bands.
fn band_plan(path: DispatchPath, cap: usize, m: usize, n: usize, kdim: usize) -> Option<Vec<Band>> {
    if cap <= 1 {
        return None;
    }
    let flops = 2.0 * m as f64 * n as f64 * kdim as f64;
    if flops < MIN_PARALLEL_FLOPS {
        return None;
    }
    let by_rows = cap.min(m.div_ceil(2 * path.gemm_mr()));
    if by_rows > 1 {
        let band = m.div_ceil(by_rows);
        return Some(
            (0..m)
                .step_by(band)
                .map(|row0| Band { row0, rows: band.min(m - row0), col0: 0, cols: n })
                .collect(),
        );
    }
    let by_cols = cap.min(n.div_ceil(2 * path.gemm_nr()));
    if by_cols > 1 {
        let band = n.div_ceil(by_cols);
        return Some(
            (0..n)
                .step_by(band)
                .map(|col0| Band { row0: 0, rows: m, col0, cols: band.min(n - col0) })
                .collect(),
        );
    }
    None
}

/// Serial blocked GEMM over one band of the output, written through the
/// full-matrix base pointer `c` at row stride `job.ldc`.
///
/// # Safety
/// `c` must be valid for writes over the band's rectangle at stride
/// `job.ldc`, and no other thread may touch that rectangle
/// concurrently (bands are disjoint by construction).
unsafe fn gemm_band(c: *mut f32, job: &BandJob<'_>, scratch: &mut Scratch) {
    let path = job.path;
    let (mr, nr, mc) = (path.gemm_mr(), path.gemm_nr(), path.gemm_mc());
    let Band { row0, rows, col0, cols } = job.band;
    for jc in (0..cols).step_by(NC) {
        let ncb = NC.min(cols - jc);
        for pc in (0..job.kdim).step_by(KC) {
            let kc = KC.min(job.kdim - pc);
            pack_b(job.b, pc, col0 + jc, kc, ncb, nr, &mut scratch.b_panel);
            for ic in (0..rows).step_by(mc) {
                let mcb = mc.min(rows - ic);
                pack_a(job.a, row0 + ic, pc, mcb, kc, mr, &mut scratch.a_panel);
                for jr in (0..ncb).step_by(nr) {
                    let nrc = nr.min(ncb - jr);
                    let bp = &scratch.b_panel[(jr / nr) * nr * kc..][..nr * kc];
                    for ir in (0..mcb).step_by(mr) {
                        let mrc = mr.min(mcb - ir);
                        let ap = &scratch.a_panel[(ir / mr) * mr * kc..][..mr * kc];
                        let corner = c.add((row0 + ic + ir) * job.ldc + col0 + jc + jr);
                        path.micro_kernel(
                            ap,
                            bp,
                            kc,
                            MicroOut { ptr: corner, ldc: job.ldc, mr: mrc, nr: nrc },
                        );
                    }
                }
            }
        }
    }
}

/// Pack rows `r0..r0+mc`, depth `k0..k0+kc` of `op(A)` into `mr`-row
/// strips, column-major within a strip (`buf[strip][k][i]`), zero-
/// padding the final partial strip.
fn pack_a(
    a: MatView<'_>,
    r0: usize,
    k0: usize,
    mc: usize,
    kc: usize,
    mr: usize,
    buf: &mut Vec<f32>,
) {
    let strips = mc.div_ceil(mr);
    buf.clear();
    buf.resize(strips * mr * kc, 0.0);
    for s in 0..strips {
        let dst = &mut buf[s * mr * kc..(s + 1) * mr * kc];
        let rbase = r0 + s * mr;
        let rows = mr.min(mc - s * mr);
        for k in 0..kc {
            let col = &mut dst[k * mr..k * mr + rows];
            for (i, slot) in col.iter_mut().enumerate() {
                *slot = a.at(rbase + i, k0 + k);
            }
        }
    }
}

/// Pack depth `k0..k0+kc`, columns `j0..j0+nc` of `op(B)` into `nr`-
/// column strips, row-major within a strip (`buf[strip][k][j]`), zero-
/// padding the final partial strip.
fn pack_b(
    b: MatView<'_>,
    k0: usize,
    j0: usize,
    kc: usize,
    nc: usize,
    nr: usize,
    buf: &mut Vec<f32>,
) {
    let strips = nc.div_ceil(nr);
    buf.clear();
    buf.resize(strips * nr * kc, 0.0);
    for s in 0..strips {
        let dst = &mut buf[s * nr * kc..(s + 1) * nr * kc];
        let jbase = j0 + s * nr;
        let cols = nr.min(nc - s * nr);
        for k in 0..kc {
            let row = &mut dst[k * nr..k * nr + cols];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = b.at(k0 + k, jbase + j);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, property};
    use crate::util::rng::Pcg32;

    fn naive(a: &Matrix, ta: bool, b: &Matrix, tb: bool) -> Matrix {
        let av = MatView::new(a, ta);
        let bv = MatView::new(b, tb);
        let (m, kdim) = if ta { (a.cols, a.rows) } else { (a.rows, a.cols) };
        let n = if tb { b.rows } else { b.cols };
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for k in 0..kdim {
                    acc += av.at(i, k) * bv.at(k, j);
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    fn check_all_ops(m: usize, k: usize, n: usize, rng: &mut Pcg32) {
        // A is m×k, B is k×n; also build the transposed storages so all
        // three op combinations exercise the same logical product.
        let a = Matrix::random_uniform(m, k, 1.0, rng);
        let b = Matrix::random_uniform(k, n, 1.0, rng);
        let at = a.transpose(); // k×m
        let bt = b.transpose(); // n×k
        let reference = naive(&a, false, &b, false);

        let mut out = Matrix::zeros(m, n);
        gemm_into(&mut out, &a, false, &b, false);
        assert_allclose(&out.data, &reference.data, 1e-5, 1e-5);

        gemm_into(&mut out, &a, false, &bt, true);
        assert_allclose(&out.data, &reference.data, 1e-5, 1e-5);

        gemm_into(&mut out, &at, true, &b, false);
        assert_allclose(&out.data, &reference.data, 1e-5, 1e-5);

        gemm_into(&mut out, &at, true, &bt, true);
        assert_allclose(&out.data, &reference.data, 1e-5, 1e-5);
    }

    #[test]
    fn exact_tile_multiples() {
        let mut rng = Pcg32::new(1);
        check_all_ops(MR, 16, NR, &mut rng);
        check_all_ops(2 * MR, KC.min(32), 2 * NR, &mut rng);
    }

    #[test]
    fn degenerate_shapes() {
        let mut rng = Pcg32::new(2);
        check_all_ops(1, 1, 1, &mut rng);
        check_all_ops(1, 9, 1, &mut rng);
        check_all_ops(1, 3, 11, &mut rng);
        check_all_ops(13, 5, 1, &mut rng);
    }

    #[test]
    fn empty_dims_give_empty_or_zero() {
        // k = 0: the product is defined and all-zero.
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 4);
        let mut out = Matrix::from_vec(3, 4, vec![7.0; 12]);
        gemm_into(&mut out, &a, false, &b, false);
        assert!(out.data.iter().all(|&v| v == 0.0));
        // m = 0 / n = 0: empty outputs, no panics.
        let mut empty = Matrix::zeros(0, 4);
        gemm_into(&mut empty, &Matrix::zeros(0, 5), false, &Matrix::zeros(5, 4), false);
        assert!(empty.data.is_empty());
        let mut empty2 = Matrix::zeros(3, 0);
        gemm_into(&mut empty2, &Matrix::zeros(3, 5), false, &Matrix::zeros(5, 0), false);
        assert!(empty2.data.is_empty());
    }

    #[test]
    fn tail_sizes_not_divisible_by_tiles() {
        property("blocked gemm == naive on ragged shapes", 24, |rng| {
            let m = 1 + rng.index(3 * MR + 1);
            let k = 1 + rng.index(40);
            let n = 1 + rng.index(3 * NR + 1);
            check_all_ops(m, k, n, rng);
        });
    }

    #[test]
    fn multithreaded_band_split_matches_naive() {
        // Big enough to clear MIN_PARALLEL_FLOPS → exercises the pooled
        // row-band path (when >1 core is available).
        let mut rng = Pcg32::new(3);
        check_all_ops(150, 300, 70, &mut rng);
    }

    #[test]
    fn depth_blocking_accumulates_across_kc() {
        // k > KC forces multiple pc iterations accumulating into C.
        let mut rng = Pcg32::new(4);
        check_all_ops(9, KC + 37, 11, &mut rng);
    }

    #[test]
    fn output_is_overwritten_not_accumulated() {
        let mut rng = Pcg32::new(5);
        let a = Matrix::random_uniform(4, 6, 1.0, &mut rng);
        let b = Matrix::random_uniform(6, 5, 1.0, &mut rng);
        let mut out = Matrix::from_vec(4, 5, vec![1e6; 20]);
        gemm_into(&mut out, &a, false, &b, false);
        assert_allclose(&out.data, &naive(&a, false, &b, false).data, 1e-5, 1e-5);
    }

    #[test]
    fn deterministic_across_calls() {
        let mut rng = Pcg32::new(6);
        let a = Matrix::random_uniform(64, 120, 1.0, &mut rng);
        let b = Matrix::random_uniform(120, 48, 1.0, &mut rng);
        let mut out1 = Matrix::zeros(64, 48);
        let mut out2 = Matrix::zeros(64, 48);
        gemm_into(&mut out1, &a, false, &b, false);
        gemm_into(&mut out2, &a, false, &b, false);
        assert_eq!(out1.data, out2.data);
    }

    #[test]
    fn deterministic_across_thread_counts_and_paths() {
        // The pool must not cost reproducibility: for every dispatch
        // path, any thread cap must give the bitwise-identical result —
        // tall shapes (row bands), wide-short shapes (column bands),
        // and sub-threshold shapes (no bands) alike.
        let mut rng = Pcg32::new(7);
        for &(m, k, n) in &[(150usize, 300usize, 70usize), (8, 700, 400), (9, 11, 13)] {
            let a = Matrix::random_uniform(m, k, 1.0, &mut rng);
            let b = Matrix::random_uniform(k, n, 1.0, &mut rng);
            for path in simd::test_paths() {
                let mut reference = Matrix::zeros(m, n);
                gemm_into_with(path, 1, &mut reference, &a, false, &b, false);
                for threads in [2usize, 3, 5, 8] {
                    let mut out = Matrix::zeros(m, n);
                    gemm_into_with(path, threads, &mut out, &a, false, &b, false);
                    let bits_equal = out
                        .data
                        .iter()
                        .zip(&reference.data)
                        .all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(
                        bits_equal,
                        "path {} threads {threads} shape {m}x{k}x{n} diverged",
                        path.name()
                    );
                }
            }
        }
    }

    #[test]
    fn simd_paths_match_scalar_within_fma_tolerance() {
        // FMA fuses the multiply-add, so SIMD results differ from
        // scalar in the last bits but must stay within accumulation
        // tolerance for every op combination and ragged shape.
        property("gemm SIMD == scalar (fma tol)", 16, |rng| {
            let m = 1 + rng.index(40);
            let k = 1 + rng.index(80);
            let n = 1 + rng.index(40);
            let a = Matrix::random_uniform(m, k, 1.0, rng);
            let bt = Matrix::random_uniform(n, k, 1.0, rng);
            let mut want = Matrix::zeros(m, n);
            gemm_into_with(DispatchPath::Scalar, 1, &mut want, &a, false, &bt, true);
            for path in simd::test_paths() {
                let mut got = Matrix::zeros(m, n);
                gemm_into_with(path, 1, &mut got, &a, false, &bt, true);
                assert_allclose(&got.data, &want.data, 1e-4, 1e-4);
            }
        });
    }

    #[test]
    fn wide_short_products_split_into_column_bands() {
        // m = 8 is under 2·MR for every path, so the plan must fall
        // through to column bands once the FLOP threshold is met — and
        // the banded result must equal the single-thread one bitwise.
        for path in simd::test_paths() {
            let plan = band_plan(path, 4, 8, 400, 700)
                .unwrap_or_else(|| panic!("path {}: expected column bands", path.name()));
            assert!(plan.len() > 1);
            assert!(plan.iter().all(|b| b.rows == 8 && b.row0 == 0));
            let total: usize = plan.iter().map(|b| b.cols).sum();
            assert_eq!(total, 400);
            for w in plan.windows(2) {
                assert_eq!(w[0].col0 + w[0].cols, w[1].col0, "bands must tile n");
            }
        }
        // The motivating serving shape (batch 8 × the 784→128 layer,
        // 1.6 MFLOP) must clear the post-pool threshold and split.
        let serving = band_plan(DispatchPath::Scalar, 4, 8, 128, 784)
            .expect("batch-8 serving layer must column-split");
        assert!(serving.len() > 1);
        assert!(serving.iter().all(|b| b.rows == 8));
        // Genuinely tiny products still stay whole.
        assert!(band_plan(DispatchPath::Scalar, 4, 8, 10, 128).is_none());
    }

    #[test]
    fn row_band_plan_tiles_m() {
        for path in simd::test_paths() {
            let plan = band_plan(path, 4, 150, 70, 300)
                .unwrap_or_else(|| panic!("path {}: expected row bands", path.name()));
            assert!(plan.len() > 1);
            assert!(plan.iter().all(|b| b.cols == 70 && b.col0 == 0));
            let total: usize = plan.iter().map(|b| b.rows).sum();
            assert_eq!(total, 150);
            for w in plan.windows(2) {
                assert_eq!(w[0].row0 + w[0].rows, w[1].row0, "bands must tile m");
            }
        }
    }

    #[test]
    #[should_panic]
    fn inner_dim_mismatch_panics() {
        let mut out = Matrix::zeros(2, 2);
        gemm_into(&mut out, &Matrix::zeros(2, 3), false, &Matrix::zeros(4, 2), false);
    }
}
