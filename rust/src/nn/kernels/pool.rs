//! Persistent GEMM worker pool (EXPERIMENTS.md §Perf gains).
//!
//! The pre-pool kernel paid a `std::thread::scope` spawn plus a cold
//! packing-scratch allocation per worker *per call* — fine for training
//! batches, measurable on the serving path where the same shapes run
//! thousands of times a second. This pool spawns its workers once
//! (lazily, on the first multi-band GEMM), parks them in a blocking
//! `recv`, and hands each one band-sized jobs; worker-thread-local
//! packing scratch therefore stays warm across calls.
//!
//! Shape of a dispatch (`gemm::gemm_into`): the caller keeps band 0 for
//! itself, submits bands `1..nt` here, then blocks on a [`Latch`] until
//! every submitted band counted down. Band closures erase their borrow
//! lifetimes (raw parts), which is sound *because* the caller always
//! waits — including when a band panics: [`LatchGuard`] counts down
//! during unwinding, the worker survives via `catch_unwind`, and the
//! caller re-raises the failure after the barrier.

use once_cell::sync::OnceCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};

pub(crate) type Task = Box<dyn FnOnce() + Send + 'static>;

pub(crate) struct Pool {
    /// One channel per worker: per-band handoff with no shared queue
    /// contention. `Mutex` rather than relying on `Sender: Sync`
    /// (stabilized later than this crate's MSRV posture).
    senders: Vec<Mutex<Sender<Task>>>,
    cursor: AtomicUsize,
}

static POOL: OnceCell<Pool> = OnceCell::new();

/// The process-wide pool, created on first use with `workers` threads.
/// The size is latched by the first caller — consistent with the
/// `EDGEMLP_GEMM_THREADS` cap it is derived from, which is itself
/// read once.
pub(crate) fn global(workers: usize) -> &'static Pool {
    POOL.get_or_init(|| Pool::new(workers.max(1)))
}

impl Pool {
    fn new(workers: usize) -> Pool {
        let mut senders = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<Task>();
            std::thread::Builder::new()
                .name(format!("edgemlp-gemm-{w}"))
                .spawn(move || {
                    // Parked in `recv` between jobs. The loop only ends
                    // when the sender side (a process-lifetime static)
                    // is gone, i.e. at process teardown.
                    while let Ok(task) = rx.recv() {
                        // A panicking band must not take the worker
                        // down with it: the job's LatchGuard has
                        // already recorded the panic for the caller.
                        let _ = catch_unwind(AssertUnwindSafe(task));
                    }
                })
                .expect("spawn gemm pool worker");
            senders.push(Mutex::new(tx));
        }
        Pool { senders, cursor: AtomicUsize::new(0) }
    }

    /// Worker-thread count (pool sizing is latched at creation; the
    /// GEMM dispatcher re-plans band counts against it).
    pub(crate) fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Hand one job to a worker (rotating assignment; jobs queue in the
    /// worker's channel when it is busy, so more bands than workers is
    /// fine — they drain in order).
    pub(crate) fn submit(&self, task: Task) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        self.senders[i]
            .lock()
            .expect("gemm pool sender poisoned")
            .send(task)
            .expect("gemm pool worker exited");
    }
}

/// A countdown barrier: the dispatching thread waits until every
/// submitted band has finished (successfully or by panic).
pub(crate) struct Latch {
    remaining: Mutex<usize>,
    all_done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    pub(crate) fn new(jobs: usize) -> Arc<Latch> {
        Arc::new(Latch {
            remaining: Mutex::new(jobs),
            all_done: Condvar::new(),
            panicked: AtomicBool::new(false),
        })
    }

    fn count_down(&self, job_panicked: bool) {
        if job_panicked {
            self.panicked.store(true, Ordering::SeqCst);
        }
        let mut left = self.remaining.lock().expect("gemm latch poisoned");
        *left -= 1;
        if *left == 0 {
            self.all_done.notify_all();
        }
    }

    /// Block until every job counted down. Returns true if any panicked.
    pub(crate) fn wait(&self) -> bool {
        let mut left = self.remaining.lock().expect("gemm latch poisoned");
        while *left > 0 {
            left = self.all_done.wait(left).expect("gemm latch poisoned");
        }
        self.panicked.load(Ordering::SeqCst)
    }
}

/// Counts its latch down on drop — on normal completion *and* during
/// unwinding, so a panicking band can never leave the caller blocked.
pub(crate) struct LatchGuard(pub(crate) Arc<Latch>);

impl Drop for LatchGuard {
    fn drop(&mut self) {
        self.0.count_down(std::thread::panicking());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn pool_runs_jobs_and_latch_releases() {
        let pool = global(4);
        assert!(pool.workers() >= 1);
        static HITS: AtomicU32 = AtomicU32::new(0);
        let latch = Latch::new(16);
        for _ in 0..16 {
            let l = latch.clone();
            pool.submit(Box::new(move || {
                let _g = LatchGuard(l);
                HITS.fetch_add(1, Ordering::SeqCst);
            }));
        }
        assert!(!latch.wait(), "no job panicked");
        assert_eq!(HITS.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn panicking_job_is_reported_and_worker_survives() {
        let pool = global(4);
        let latch = Latch::new(1);
        let l = latch.clone();
        pool.submit(Box::new(move || {
            let _g = LatchGuard(l);
            panic!("boom");
        }));
        assert!(latch.wait(), "panic must be recorded");
        // The worker that ran the panicking job must still accept work.
        let latch2 = Latch::new(pool.workers() * 2);
        for _ in 0..pool.workers() * 2 {
            let l = latch2.clone();
            pool.submit(Box::new(move || {
                let _g = LatchGuard(l);
            }));
        }
        assert!(!latch2.wait());
    }
}
