//! Batched, weight-stationary SPx shift-add matmul (EXPERIMENTS.md
//! §Perf, §Perf gains).
//!
//! [`crate::fpga::pu::dot_shift_add`] streams a weight row's packed
//! codes once *per sample*; for a batch of `B` samples that re-reads
//! `m×n` codes `B` times. This kernel inverts the loop nest: the data
//! batch is transposed once to column-major (`d_t[j][b]` contiguous in
//! `b`), then each weight element is loaded once and applied to every
//! sample in the block — one pass over the codes per batch, the same
//! weight-stationary dataflow RedMulE/FantastIC4 use in hardware.
//!
//! The fast-row inner loop (`acc[b] += d[b] · v` with `v` the
//! precomputed shift sum) is SIMD-dispatched
//! ([`crate::nn::kernels::simd`]): a widening `i32×i32→i64`
//! multiply-accumulate, 4 lanes per step on AVX2/NEON.
//!
//! Bit-exactness: the accumulator is plain `i64` arithmetic (the fast
//! path multiplies by the precomputed shift sum, the fallback replays
//! the shifts), so each sample's dot product is the *identical integer*
//! the per-sample path computes — integer addition is associative, so
//! neither the loop interchange nor the vector width can change a
//! single bit. Property tests pin the outputs (and the event
//! accounting) to the per-sample path on every available dispatch
//! path.

use crate::fpga::pu::{from_fixed, packed_term};
use crate::fpga::stats::CycleStats;
use crate::nn::kernels::simd::{self, DispatchPath};
use crate::quant::spx::{SpxTensor, FIXED_GUARD_BITS};

/// Samples processed per weight pass: keeps the `i64` accumulator block
/// and the active `d_t` columns inside L1 while amortizing one code
/// stream over many samples.
const BB: usize = 128;

/// Transpose a row-major `batch×n` fixed-point batch into column-major
/// `n×batch` (`out[j * batch + b]`), reusing `out`'s allocation.
/// SIMD-dispatched (8×8 i32 blocks on AVX2); pure data movement, so
/// bit-identical on every path.
pub fn transpose_to_columns(d_fixed: &[i32], batch: usize, n: usize, out: &mut Vec<i32>) {
    assert_eq!(d_fixed.len(), batch * n, "batch {batch} × n {n} vs len {}", d_fixed.len());
    // Reshape only — the transpose writes every element, so the warm
    // steady state skips the zero-fill a clear()+resize would redo.
    if out.len() != batch * n {
        out.resize(batch * n, 0);
    }
    simd::active_path().transpose_to_columns(d_fixed, batch, n, out);
}

/// `out[b][r] = (w · d_b)` for every sample `b` in the batch, through
/// the fixed-point shift-add datapath, on the active dispatch path.
///
/// * `w` — SPx-quantized `m×n` weight matrix.
/// * `d_t` — column-major `n×batch` Q1.15 data (see
///   [`transpose_to_columns`]).
/// * `out` — row-major `batch×m` output.
/// * `stats` — pass `Some` to charge event accounting analytically:
///   exactly `batch` times what
///   [`crate::fpga::pu::dot_shift_add`] charges per row (the counts
///   are data-independent). Callers that report simulator stats some
///   other way (e.g.
///   [`crate::fpga::accelerator::Accelerator::infer_batch`], which
///   scales a cached per-sample trace) pass `None` and skip the work.
pub fn spx_matmul_batch(
    w: &SpxTensor,
    d_t: &[i32],
    batch: usize,
    d_scale: f32,
    out: &mut [f32],
    stats: Option<&mut CycleStats>,
) {
    spx_matmul_batch_path(simd::active_path(), w, d_t, batch, d_scale, out, stats);
}

/// [`spx_matmul_batch`] pinned to an explicit dispatch path — the
/// parity tests drive both forced-scalar and native through this.
pub(crate) fn spx_matmul_batch_path(
    path: DispatchPath,
    w: &SpxTensor,
    d_t: &[i32],
    batch: usize,
    d_scale: f32,
    out: &mut [f32],
    stats: Option<&mut CycleStats>,
) {
    assert_eq!(w.shape.len(), 2, "weights must be a matrix");
    let (m, n) = (w.shape[0], w.shape[1]);
    assert_eq!(d_t.len(), n * batch, "data {} vs {n}×{batch}", d_t.len());
    assert_eq!(out.len(), batch * m, "output {} vs {batch}×{m}", out.len());
    if batch == 0 || m == 0 {
        return;
    }
    let packed = w.packed();
    let g = FIXED_GUARD_BITS;
    let mut acc_buf = vec![0i64; BB.min(batch)];
    for b0 in (0..batch).step_by(BB) {
        let bb = BB.min(batch - b0);
        let acc = &mut acc_buf[..bb];
        for r in 0..m {
            acc.fill(0);
            if packed.row_fast[r] {
                // Every code k in this row satisfies k ≤ G, so the MAC
                // collapses to an integer multiply by the precomputed
                // signed shift sum — same as the per-sample fast path,
                // vectorized as a widening i32 MAC (exact).
                let values = packed.row_values(r);
                for (j, &v) in values.iter().enumerate() {
                    if v == 0 {
                        continue; // absent weight: contributes exactly 0
                    }
                    let col = &d_t[j * batch + b0..j * batch + b0 + bb];
                    path.mac_i32(acc, col, v);
                }
            } else {
                // Rare rows with k > G replay the literal barrel shifts.
                let words = packed.row_words(r);
                for (j, &word) in words.iter().enumerate() {
                    let col = &d_t[j * batch + b0..j * batch + b0 + bb];
                    for (a, &df) in acc.iter_mut().zip(col) {
                        *a += packed_term(word, packed.x, (df as i64) << g);
                    }
                }
            }
            for (bi, &a) in acc.iter().enumerate() {
                out[(b0 + bi) * m + r] = from_fixed(a >> g, d_scale) * w.scale;
            }
        }
    }
    // Hoisted event accounting (exact: every counter is data-
    // independent, matching dot_shift_add's per-row formulas × batch).
    if let Some(stats) = stats {
        let b = batch as u64;
        stats.macs += (m * n) as u64 * b;
        stats.shifts += (m * n * packed.x) as u64 * b;
        let active: u64 = packed.row_active_terms.iter().map(|&a| a as u64).sum();
        stats.adds += (active + (m * n) as u64) * b;
        stats.mults += m as u64 * b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::pu::{dot_shift_add, quantize_data};
    use crate::quant::spx::SpxConfig;
    use crate::quant::Calibration;
    use crate::util::check::property;

    fn run_batched_path(
        path: DispatchPath,
        w: &SpxTensor,
        d: &[Vec<f32>],
        d_scale: f32,
    ) -> (Vec<f32>, CycleStats) {
        let (m, n) = (w.shape[0], w.shape[1]);
        let batch = d.len();
        let mut flat = Vec::with_capacity(batch * n);
        for row in d {
            flat.extend(quantize_data(row, d_scale));
        }
        let mut d_t = vec![0i32; batch * n];
        path.transpose_to_columns(&flat, batch, n, &mut d_t);
        let mut out = vec![0.0f32; batch * m];
        let mut stats = CycleStats::default();
        spx_matmul_batch_path(path, w, &d_t, batch, d_scale, &mut out, Some(&mut stats));
        (out, stats)
    }

    fn run_batched(w: &SpxTensor, d: &[Vec<f32>], d_scale: f32) -> (Vec<f32>, CycleStats) {
        run_batched_path(simd::active_path(), w, d, d_scale)
    }

    fn run_per_sample(w: &SpxTensor, d: &[Vec<f32>], d_scale: f32) -> (Vec<f32>, CycleStats) {
        let m = w.shape[0];
        let mut out = Vec::with_capacity(d.len() * m);
        let mut stats = CycleStats::default();
        for row in d {
            let d_fixed = quantize_data(row, d_scale);
            for r in 0..m {
                out.push(dot_shift_add(w, r, &d_fixed, d_scale, &mut stats));
            }
        }
        (out, stats)
    }

    fn assert_bitwise_eq(batched: &[f32], reference: &[f32]) {
        assert_eq!(batched.len(), reference.len());
        for (i, (a, e)) in batched.iter().zip(reference).enumerate() {
            assert_eq!(a.to_bits(), e.to_bits(), "index {i}: {a} vs {e}");
        }
    }

    #[test]
    fn batched_matches_per_sample_bitwise_on_every_path() {
        property("batched SPx == per-sample dot", 24, |rng| {
            let m = 1 + rng.index(6);
            let n = 1 + rng.index(32);
            let batch = 1 + rng.index(9);
            let x = 1 + rng.index(3) as u32;
            let cfg = SpxConfig::spx(x + 2 + rng.index(3) as u32, x);
            let wdata: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let w = SpxTensor::encode(&cfg, &wdata, &[m, n], Calibration::MaxAbs);
            let d: Vec<Vec<f32>> = (0..batch)
                .map(|_| (0..n).map(|_| rng.range(-1.0, 1.0) as f32).collect())
                .collect();
            let (slow, s2) = run_per_sample(&w, &d, 1.0);
            for path in simd::test_paths() {
                let (fast, s1) = run_batched_path(path, &w, &d, 1.0);
                assert_bitwise_eq(&fast, &slow);
                assert_eq!(s1, s2, "event accounting diverged on {}", path.name());
            }
        });
    }

    #[test]
    fn slow_rows_with_deep_shifts_match() {
        // A single-term b=8 config reaches codes k up to 127 > G when
        // the dynamic range is extreme, forcing the non-fast fallback.
        let cfg = SpxConfig::new(vec![7]);
        let n = 8;
        let mut wdata = vec![0.5f32; n];
        wdata[1] = 0.5 * (2.0f32).powi(-20); // → k ≈ 21 > G on this row
        let w = SpxTensor::encode(&cfg, &wdata, &[1, n], Calibration::MaxAbs);
        assert!(
            !w.packed().row_fast[0],
            "test setup: expected a non-fast row, codes too shallow"
        );
        let d: Vec<Vec<f32>> = (0..5).map(|b| vec![0.1 * (b as f32 + 1.0); n]).collect();
        let (slow, _) = run_per_sample(&w, &d, 1.0);
        for path in simd::test_paths() {
            let (fast, _) = run_batched_path(path, &w, &d, 1.0);
            assert_bitwise_eq(&fast, &slow);
        }
    }

    #[test]
    fn batch_blocking_covers_batches_beyond_bb() {
        let cfg = SpxConfig::sp2(5);
        let (m, n) = (3, 7);
        let mut rng = crate::util::rng::Pcg32::new(11);
        let wdata: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32 * 0.3).collect();
        let w = SpxTensor::encode(&cfg, &wdata, &[m, n], Calibration::MaxAbs);
        let batch = BB + 17; // spans two blocks
        let d: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..n).map(|_| rng.range(-1.0, 1.0) as f32).collect())
            .collect();
        let (fast, s1) = run_batched(&w, &d, 1.0);
        let (slow, s2) = run_per_sample(&w, &d, 1.0);
        assert_bitwise_eq(&fast, &slow);
        assert_eq!(s1, s2);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let cfg = SpxConfig::sp2(5);
        let w = SpxTensor::encode(&cfg, &[0.25; 6], &[2, 3], Calibration::MaxAbs);
        let mut out = Vec::new();
        let mut stats = CycleStats::default();
        spx_matmul_batch(&w, &[], 0, 1.0, &mut out, Some(&mut stats));
        assert_eq!(stats, CycleStats::default());
    }

    #[test]
    fn transpose_round_trips() {
        let flat: Vec<i32> = (0..12).collect(); // 3 samples × 4 dims
        let mut t = Vec::new();
        transpose_to_columns(&flat, 3, 4, &mut t);
        for b in 0..3 {
            for j in 0..4 {
                assert_eq!(t[j * 3 + b], flat[b * 4 + j]);
            }
        }
    }

    #[test]
    fn transpose_round_trips_at_simd_block_sizes() {
        // Exercise the 8×8-blocked path (batch and n ≥ 8, with tails).
        let (batch, n) = (13, 19);
        let flat: Vec<i32> = (0..(batch * n) as i32).collect();
        let mut t = Vec::new();
        transpose_to_columns(&flat, batch, n, &mut t);
        for b in 0..batch {
            for j in 0..n {
                assert_eq!(t[j * batch + b], flat[b * n + j], "b {b} j {j}");
            }
        }
    }
}
