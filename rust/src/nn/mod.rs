//! Pure-Rust neural-network substrate: the paper's MLP (Eq 4.1/4.2), its
//! MSE + SGD training loop (Eq 4.4–4.6), and the dense-matrix kernels
//! they need. This is simultaneously
//!
//! * the **pre-training path** (the paper pre-trains θ on CPU/GPU before
//!   deploying to the accelerator),
//! * the **CPU baseline** of Table I, and
//! * the reference semantics that the FPGA simulator and the XLA
//!   artifacts are tested against.

pub mod activations;
pub mod kernels;
pub mod metrics;
pub mod mlp;
pub mod tensor;
pub mod train;
pub mod vsq;

pub use mlp::{Mlp, MlpConfig};
pub use tensor::Matrix;
pub use vsq::VsqMlp;
