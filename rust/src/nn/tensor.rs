//! Dense row-major f32 matrix with exactly the operations the MLP stack
//! needs. All three matmul entry points (`A·B`, `A·Bᵀ`, `Aᵀ·B`) funnel
//! through the cache-blocked, multithreaded GEMM in
//! [`crate::nn::kernels::gemm`], so the "CPU" row of Table I measures a
//! real kernel rather than allocator churn (see EXPERIMENTS.md §Perf).
//! The pre-kernel single-pass loops survive as `*_unblocked` references
//! for tests and the BENCH_gemm.json baseline.

use crate::nn::kernels::gemm::gemm_into;
use crate::util::rng::Pcg32;

/// Row-major `rows × cols` f32 matrix.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape {rows}x{cols} vs len {}", data.len());
        Matrix { rows, cols, data }
    }

    /// Uniform init in `[-scale, scale]` — the classic "small random
    /// weights" init the paper's era of MLPs used; scale defaults to
    /// `1/sqrt(fan_in)` at the call sites.
    pub fn random_uniform(rows: usize, cols: usize, scale: f32, rng: &mut Pcg32) -> Self {
        let data = (0..rows * cols)
            .map(|_| rng.range(-scale as f64, scale as f64) as f32)
            .collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Reshape to `rows × cols`, zero-filling every element (reuses the
    /// existing allocation when it is large enough). The resize target
    /// for scratch buffers fed to [`Matrix::matmul_bt_into`] &c.
    pub fn resize_zeroed(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Become a copy of `other`, reusing this matrix's allocation.
    pub fn copy_from(&mut self, other: &Matrix) {
        self.rows = other.rows;
        self.cols = other.cols;
        self.data.clear();
        self.data.extend_from_slice(&other.data);
    }

    /// `C = A · B` (blocked GEMM; see [`crate::nn::kernels::gemm`]).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Matrix::zeros(self.rows, other.cols);
        gemm_into(&mut out, self, false, other, false);
        out
    }

    /// `C = A · Bᵀ` (the batched-forward layout, where B is an `out×in`
    /// weight matrix whose rows are contiguous).
    pub fn matmul_bt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_bt inner dims");
        let mut out = Matrix::zeros(self.rows, other.rows);
        gemm_into(&mut out, self, false, other, true);
        out
    }

    /// `C = A · Bᵀ` into a reusable output buffer (resized in place) —
    /// the allocation-free hot path used by
    /// [`crate::nn::mlp::Mlp::forward_with`]. Only the shape is fixed
    /// up here; `gemm_into` owns the (single) zeroing pass.
    pub fn matmul_bt_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols, other.cols, "matmul_bt inner dims");
        out.rows = self.rows;
        out.cols = other.rows;
        out.data.resize(self.rows * other.rows, 0.0);
        gemm_into(out, self, false, other, true);
    }

    /// `C = Aᵀ · B` (used by the gradient `∂L/∂W = δᵀ · X`).
    pub fn matmul_at(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_at inner dims");
        let mut out = Matrix::zeros(self.cols, other.cols);
        gemm_into(&mut out, self, true, other, false);
        out
    }

    /// The seed's single-pass `A · Bᵀ` (one dot product per output, 8
    /// unrolled accumulators). Kept as the measured baseline the
    /// BENCH_gemm.json speedup column is computed against, and as an
    /// independent reference for the blocked kernel's tests.
    pub fn matmul_bt_unblocked(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_bt inner dims");
        let mut out = Matrix::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a_row = self.row(i);
            for j in 0..other.rows {
                let b_row = other.row(j);
                // Eight independent accumulators so the compiler can
                // vectorize the reduction (a single serial accumulator
                // forces scalar FP adds); see EXPERIMENTS.md §Perf.
                let mut acc = [0.0f32; 8];
                let a_chunks = a_row.chunks_exact(8);
                let b_chunks = b_row.chunks_exact(8);
                let mut tail = 0.0f32;
                for (ar, br) in a_chunks.remainder().iter().zip(b_chunks.remainder()) {
                    tail += ar * br;
                }
                for (ac, bc) in a_chunks.zip(b_chunks) {
                    for l in 0..8 {
                        acc[l] += ac[l] * bc[l];
                    }
                }
                let total = (acc[0] + acc[1]) + (acc[2] + acc[3])
                    + (acc[4] + acc[5]) + (acc[6] + acc[7]) + tail;
                out.data[i * other.rows + j] = total;
            }
        }
        out
    }

    /// Add a row vector to every row (bias broadcast).
    pub fn add_row_inplace(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (x, &b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    /// Elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// `self -= scale * other` (SGD step).
    pub fn axpy_inplace(&mut self, scale: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &g) in self.data.iter_mut().zip(&other.data) {
            *a -= scale * g;
        }
    }

    /// Elementwise product (Hadamard), consuming neither operand.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| a * b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Column sums (bias gradient).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(r)) {
                *o += x;
            }
        }
        out
    }

    /// Transpose (copying).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.at(r, c);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, property};

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                out.data[i * b.cols + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive() {
        property("ikj matmul == naive", 32, |rng| {
            let (m, k, n) = (1 + rng.index(8), 1 + rng.index(8), 1 + rng.index(8));
            let a = Matrix::random_uniform(m, k, 2.0, rng);
            let b = Matrix::random_uniform(k, n, 2.0, rng);
            assert_allclose(&a.matmul(&b).data, &naive_matmul(&a, &b).data, 1e-5, 1e-5);
        });
    }

    #[test]
    fn matmul_bt_into_reuses_buffer() {
        property("matmul_bt_into == matmul_bt across resizes", 16, |rng| {
            let mut out = Matrix::zeros(0, 0);
            for _ in 0..3 {
                let (m, k, n) = (1 + rng.index(9), 1 + rng.index(20), 1 + rng.index(9));
                let a = Matrix::random_uniform(m, k, 1.0, rng);
                let b = Matrix::random_uniform(n, k, 1.0, rng);
                a.matmul_bt_into(&b, &mut out);
                assert_eq!((out.rows, out.cols), (m, n));
                assert_eq!(out.data, a.matmul_bt(&b).data);
            }
        });
    }

    #[test]
    fn blocked_bt_matches_unblocked_baseline() {
        property("blocked A·Bᵀ == seed unblocked A·Bᵀ", 16, |rng| {
            let (m, k, n) = (1 + rng.index(24), 1 + rng.index(48), 1 + rng.index(24));
            let a = Matrix::random_uniform(m, k, 1.0, rng);
            let b = Matrix::random_uniform(n, k, 1.0, rng);
            assert_allclose(&a.matmul_bt(&b).data, &a.matmul_bt_unblocked(&b).data, 1e-5, 1e-5);
        });
    }

    #[test]
    fn matmul_bt_matches_matmul_of_transpose() {
        property("A·Bᵀ == A·(Bᵀ)", 32, |rng| {
            let (m, k, n) = (1 + rng.index(6), 1 + rng.index(6), 1 + rng.index(6));
            let a = Matrix::random_uniform(m, k, 1.0, rng);
            let b = Matrix::random_uniform(n, k, 1.0, rng);
            assert_allclose(&a.matmul_bt(&b).data, &a.matmul(&b.transpose()).data, 1e-5, 1e-5);
        });
    }

    #[test]
    fn matmul_at_matches_transpose_matmul() {
        property("Aᵀ·B == (Aᵀ)·B", 32, |rng| {
            let (m, k, n) = (1 + rng.index(6), 1 + rng.index(6), 1 + rng.index(6));
            let a = Matrix::random_uniform(k, m, 1.0, rng);
            let b = Matrix::random_uniform(k, n, 1.0, rng);
            assert_allclose(&a.matmul_at(&b).data, &a.transpose().matmul(&b).data, 1e-5, 1e-5);
        });
    }

    #[test]
    fn transpose_involution() {
        property("(Aᵀ)ᵀ == A", 16, |rng| {
            let a = Matrix::random_uniform(1 + rng.index(7), 1 + rng.index(7), 1.0, rng);
            assert_eq!(a.transpose().transpose(), a);
        });
    }

    #[test]
    fn bias_broadcast() {
        let mut m = Matrix::zeros(2, 3);
        m.add_row_inplace(&[1.0, 2.0, 3.0]);
        assert_eq!(m.data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn col_sums_basic() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.col_sums(), vec![4.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
