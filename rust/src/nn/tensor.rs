//! Dense row-major f32 matrix with exactly the operations the MLP stack
//! needs. The matmul kernels use the cache-friendly i-k-j loop order with
//! an unrolled inner accumulation — good enough that the "CPU" row of
//! Table I is a fair software baseline (see EXPERIMENTS.md §Perf).

use crate::util::rng::Pcg32;

/// Row-major `rows × cols` f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape {rows}x{cols} vs len {}", data.len());
        Matrix { rows, cols, data }
    }

    /// Uniform init in `[-scale, scale]` — the classic "small random
    /// weights" init the paper's era of MLPs used; scale defaults to
    /// `1/sqrt(fan_in)` at the call sites.
    pub fn random_uniform(rows: usize, cols: usize, scale: f32, rng: &mut Pcg32) -> Self {
        let data = (0..rows * cols)
            .map(|_| rng.range(-scale as f64, scale as f64) as f32)
            .collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `C = A · B` (i-k-j order: streams B rows, accumulates into C rows).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let c_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                for (c, &b) in c_row.iter_mut().zip(b_row) {
                    *c += a * b;
                }
            }
        }
        out
    }

    /// `C = A · Bᵀ` (both operands streamed row-major — the layout used
    /// by the batched forward pass, where B is a `out×in` weight matrix).
    pub fn matmul_bt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_bt inner dims");
        let mut out = Matrix::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a_row = self.row(i);
            for j in 0..other.rows {
                let b_row = other.row(j);
                // Eight independent accumulators so the compiler can
                // vectorize the reduction (a single serial accumulator
                // forces scalar FP adds); see EXPERIMENTS.md §Perf.
                let mut acc = [0.0f32; 8];
                let a_chunks = a_row.chunks_exact(8);
                let b_chunks = b_row.chunks_exact(8);
                let mut tail = 0.0f32;
                for (ar, br) in a_chunks.remainder().iter().zip(b_chunks.remainder()) {
                    tail += ar * br;
                }
                for (ac, bc) in a_chunks.zip(b_chunks) {
                    for l in 0..8 {
                        acc[l] += ac[l] * bc[l];
                    }
                }
                let total = (acc[0] + acc[1]) + (acc[2] + acc[3])
                    + (acc[4] + acc[5]) + (acc[6] + acc[7]) + tail;
                out.data[i * other.rows + j] = total;
            }
        }
        out
    }

    /// `C = Aᵀ · B` (used by the gradient `∂L/∂W = δᵀ · X`).
    pub fn matmul_at(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_at inner dims");
        let mut out = Matrix::zeros(self.cols, other.cols);
        for k in 0..self.rows {
            let a_row = self.row(k);
            let b_row = other.row(k);
            for (i, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let c_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (c, &b) in c_row.iter_mut().zip(b_row) {
                    *c += a * b;
                }
            }
        }
        out
    }

    /// Add a row vector to every row (bias broadcast).
    pub fn add_row_inplace(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (x, &b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    /// Elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// `self -= scale * other` (SGD step).
    pub fn axpy_inplace(&mut self, scale: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &g) in self.data.iter_mut().zip(&other.data) {
            *a -= scale * g;
        }
    }

    /// Elementwise product (Hadamard), consuming neither operand.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| a * b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Column sums (bias gradient).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(r)) {
                *o += x;
            }
        }
        out
    }

    /// Transpose (copying).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.at(r, c);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, property};

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                out.data[i * b.cols + j] = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive() {
        property("ikj matmul == naive", 32, |rng| {
            let (m, k, n) = (1 + rng.index(8), 1 + rng.index(8), 1 + rng.index(8));
            let a = Matrix::random_uniform(m, k, 2.0, rng);
            let b = Matrix::random_uniform(k, n, 2.0, rng);
            assert_allclose(&a.matmul(&b).data, &naive_matmul(&a, &b).data, 1e-5, 1e-5);
        });
    }

    #[test]
    fn matmul_bt_matches_matmul_of_transpose() {
        property("A·Bᵀ == A·(Bᵀ)", 32, |rng| {
            let (m, k, n) = (1 + rng.index(6), 1 + rng.index(6), 1 + rng.index(6));
            let a = Matrix::random_uniform(m, k, 1.0, rng);
            let b = Matrix::random_uniform(n, k, 1.0, rng);
            assert_allclose(&a.matmul_bt(&b).data, &a.matmul(&b.transpose()).data, 1e-5, 1e-5);
        });
    }

    #[test]
    fn matmul_at_matches_transpose_matmul() {
        property("Aᵀ·B == (Aᵀ)·B", 32, |rng| {
            let (m, k, n) = (1 + rng.index(6), 1 + rng.index(6), 1 + rng.index(6));
            let a = Matrix::random_uniform(k, m, 1.0, rng);
            let b = Matrix::random_uniform(k, n, 1.0, rng);
            assert_allclose(&a.matmul_at(&b).data, &a.transpose().matmul(&b).data, 1e-5, 1e-5);
        });
    }

    #[test]
    fn transpose_involution() {
        property("(Aᵀ)ᵀ == A", 16, |rng| {
            let a = Matrix::random_uniform(1 + rng.index(7), 1 + rng.index(7), 1.0, rng);
            assert_eq!(a.transpose().transpose(), a);
        });
    }

    #[test]
    fn bias_broadcast() {
        let mut m = Matrix::zeros(2, 3);
        m.add_row_inplace(&[1.0, 2.0, 3.0]);
        assert_eq!(m.data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn col_sums_basic() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.col_sums(), vec![4.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
