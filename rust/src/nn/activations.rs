//! Activation functions. The paper uses sigmoid on both the hidden and
//! output layers (Eq 4.2); ReLU and identity are provided for the RL
//! Q-network and for ablations.
//!
//! [`sigmoid_lut`] is the 256-entry lookup table the FPGA design would
//! burn into block RAM — the simulator uses it so the hardware path's
//! activation error is modeled, and a unit test bounds that error.

/// Activation function selector (serialized into checkpoints by name).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Sigmoid,
    Relu,
    Identity,
}

impl Activation {
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::Sigmoid => sigmoid(x),
            Activation::Relu => x.max(0.0),
            Activation::Identity => x,
        }
    }

    /// Derivative expressed in terms of the *activation output* `a`
    /// (cheap for sigmoid: `a(1-a)`), as used by backprop.
    pub fn derivative_from_output(&self, a: f32) -> f32 {
        match self {
            Activation::Sigmoid => a * (1.0 - a),
            Activation::Relu => {
                if a > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Identity => 1.0,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Activation::Sigmoid => "sigmoid",
            Activation::Relu => "relu",
            Activation::Identity => "identity",
        }
    }

    pub fn from_name(name: &str) -> Option<Activation> {
        match name {
            "sigmoid" => Some(Activation::Sigmoid),
            "relu" => Some(Activation::Relu),
            "identity" => Some(Activation::Identity),
            _ => None,
        }
    }
}

/// `σ(x) = 1 / (1 + e^{-x})`.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Hardware sigmoid: piecewise-linear interpolation over a 256-entry
/// table spanning `[-8, 8]`, saturating outside — the standard BRAM
/// implementation on FPGA. Max absolute error vs [`sigmoid`] is < 1e-3
/// (pinned by a test).
pub struct SigmoidLut {
    table: [f32; 257],
}

impl SigmoidLut {
    pub const LO: f32 = -8.0;
    pub const HI: f32 = 8.0;

    pub fn new() -> Self {
        let mut table = [0.0f32; 257];
        for (i, t) in table.iter_mut().enumerate() {
            let x = Self::LO + (Self::HI - Self::LO) * i as f32 / 256.0;
            *t = sigmoid(x);
        }
        SigmoidLut { table }
    }

    #[inline]
    pub fn eval(&self, x: f32) -> f32 {
        if x <= Self::LO {
            return self.table[0];
        }
        if x >= Self::HI {
            return self.table[256];
        }
        let pos = (x - Self::LO) / (Self::HI - Self::LO) * 256.0;
        // Clamp the cell index: for x just below HI (e.g. the largest
        // f32 < 8.0), `x - LO` rounds up to the full span and `pos`
        // lands exactly on 256.0 — the unclamped index would read one
        // past the table. With i = 255 the lerp degenerates to
        // `table[256]`, continuous with the saturated branch. The SIMD
        // LUT (`nn::kernels::simd`) clamps identically, which keeps the
        // two paths bit-equal.
        let i = (pos as usize).min(255);
        let frac = pos - i as f32;
        self.table[i] * (1.0 - frac) + self.table[i + 1] * frac
    }

    /// The raw 257-entry table (index 256 closes the last lerp cell) —
    /// read by the SIMD gather LUT in [`crate::nn::kernels::simd`].
    pub fn table(&self) -> &[f32; 257] {
        &self.table
    }
}

impl Default for SigmoidLut {
    fn default() -> Self {
        Self::new()
    }
}

/// Shared LUT instance (the table is immutable after construction).
pub fn sigmoid_lut() -> &'static SigmoidLut {
    use once_cell::sync::Lazy;
    static LUT: Lazy<SigmoidLut> = Lazy::new(SigmoidLut::new);
    &LUT
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::property;

    #[test]
    fn sigmoid_known_points() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(10.0) > 0.9999);
        assert!(sigmoid(-10.0) < 0.0001);
    }

    #[test]
    fn sigmoid_symmetry() {
        property("σ(-x) == 1 - σ(x)", 64, |rng| {
            let x = rng.range(-20.0, 20.0) as f32;
            assert!((sigmoid(-x) - (1.0 - sigmoid(x))).abs() < 1e-6);
        });
    }

    #[test]
    fn sigmoid_monotone() {
        property("σ monotone", 64, |rng| {
            let a = rng.range(-10.0, 10.0) as f32;
            let b = a + rng.range(0.001, 5.0) as f32;
            assert!(sigmoid(b) > sigmoid(a));
        });
    }

    #[test]
    fn derivative_from_output_matches_finite_difference() {
        property("σ' matches FD", 64, |rng| {
            let x = rng.range(-5.0, 5.0) as f32;
            let h = 1e-3f32;
            let fd = (sigmoid(x + h) - sigmoid(x - h)) / (2.0 * h);
            let a = sigmoid(x);
            let an = Activation::Sigmoid.derivative_from_output(a);
            assert!((fd - an).abs() < 1e-3, "x={x} fd={fd} an={an}");
        });
    }

    #[test]
    fn lut_error_bound() {
        let lut = SigmoidLut::new();
        let mut max_err = 0.0f32;
        for i in 0..=4000 {
            let x = -10.0 + 20.0 * i as f32 / 4000.0;
            max_err = max_err.max((lut.eval(x) - sigmoid(x)).abs());
        }
        assert!(max_err < 1e-3, "LUT max error {max_err}");
    }

    #[test]
    fn lut_eval_just_below_hi_does_not_overrun() {
        // Largest f32 < 8.0: (x - LO) rounds up to the full 16.0 span,
        // so pos == 256.0 exactly — the pre-clamp code indexed past the
        // table here. Must evaluate (to the saturated value, since the
        // lerp cell collapses) rather than panic.
        let lut = SigmoidLut::new();
        let x = f32::from_bits(0x40FF_FFFF);
        assert!(x < SigmoidLut::HI);
        assert_eq!(lut.eval(x), lut.eval(SigmoidLut::HI));
        // And the mirrored point just above LO stays in the first cell.
        let y = f32::from_bits(0xC0FF_FFFF);
        assert!(y > SigmoidLut::LO);
        assert!((lut.eval(y) - sigmoid(y)).abs() < 1e-3);
    }

    #[test]
    fn lut_saturates() {
        let lut = SigmoidLut::new();
        assert_eq!(lut.eval(-100.0), lut.eval(-8.0));
        assert_eq!(lut.eval(100.0), lut.eval(8.0));
    }

    #[test]
    fn activation_name_roundtrip() {
        for a in [Activation::Sigmoid, Activation::Relu, Activation::Identity] {
            assert_eq!(Activation::from_name(a.name()), Some(a));
        }
        assert_eq!(Activation::from_name("tanh"), None);
    }
}
