//! The paper's MLP (Eq 4.1/4.2): an alternating stack of affine maps and
//! activations. `F₁(x) = σ¹(x + b¹)` (the paper's layer 1 is the input
//! layer; in practice b¹ = 0 and σ¹ = identity, matching Eq 4.2 which
//! only shows W²/W³), `Fᵢ(x) = σⁱ(Wⁱ Fᵢ₋₁ + bⁱ)`.
//!
//! Weights are stored `out×in` so the batched forward is `X · Wᵀ + b`
//! with both operands streamed row-major, and a weight *row* `wᵢ` is
//! contiguous — exactly the unit the paper's input buffer streams
//! (`wᵢ ‖ d` reorganized rows, §3.1).

use super::activations::Activation;
use super::tensor::Matrix;
use crate::util::rng::Pcg32;
use crate::util::serde::{load_tensors, save_tensors, NamedTensor};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One affine + activation layer.
#[derive(Debug, Clone)]
pub struct Layer {
    /// `out × in` weight matrix (`W⁽ⁱ⁾ ∈ R^{Nᵢ×Nᵢ₋₁}`).
    pub w: Matrix,
    /// Bias `b⁽ⁱ⁾ ∈ R^{Nᵢ}`.
    pub b: Vec<f32>,
    pub activation: Activation,
}

impl Layer {
    /// One layer of the batched forward: `dst = σ(src · Wᵀ + b)`, with
    /// `dst` resized in place. This is the *single* per-layer code path
    /// — [`Mlp::forward_with`], [`Mlp::forward_trace_into`] and the
    /// stage-pipelined backend
    /// ([`crate::serve::pipeline_backend::PipelineCpuBackend`]) all
    /// funnel through it, so a stage thread that owns a `Layer` clone
    /// computes bit-for-bit what the monolithic forward computes.
    pub fn forward_into(&self, src: &Matrix, dst: &mut Matrix) {
        src.matmul_bt_into(&self.w, dst);
        apply_bias_activation(dst, self);
    }
}

/// Architecture description: layer sizes plus activations.
#[derive(Debug, Clone, PartialEq)]
pub struct MlpConfig {
    /// `[N₁, N₂, …, N_N]` — e.g. the paper's `[784, 128, 10]`.
    pub sizes: Vec<usize>,
    /// One activation per affine layer (`sizes.len() - 1` entries).
    pub activations: Vec<Activation>,
}

impl MlpConfig {
    /// The paper's §4.1 network: 784-128-10, sigmoid on hidden and output.
    pub fn paper_mnist() -> Self {
        MlpConfig {
            sizes: vec![784, 128, 10],
            activations: vec![Activation::Sigmoid, Activation::Sigmoid],
        }
    }

    /// Q-network for Acrobot-v1 (§4.2): 6 state dims → 3 actions,
    /// ReLU hidden layers, identity output (Q-values are unbounded).
    pub fn paper_qnet() -> Self {
        MlpConfig {
            sizes: vec![6, 64, 64, 3],
            activations: vec![Activation::Relu, Activation::Relu, Activation::Identity],
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.sizes.len() < 2 {
            bail!("MLP needs at least 2 layers, got {:?}", self.sizes);
        }
        if self.activations.len() != self.sizes.len() - 1 {
            bail!(
                "need {} activations, got {}",
                self.sizes.len() - 1,
                self.activations.len()
            );
        }
        if self.sizes.iter().any(|&s| s == 0) {
            bail!("zero-width layer in {:?}", self.sizes);
        }
        Ok(())
    }
}

/// A multi-layer perceptron with row-major `out×in` weights.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub config: MlpConfig,
    pub layers: Vec<Layer>,
}

/// Reusable ping-pong activation buffers for [`Mlp::forward_with`]:
/// layer `i` writes one buffer while reading the other, so a steady-
/// state serving loop performs zero allocations per batch.
#[derive(Debug, Clone, Default)]
pub struct ForwardScratch {
    ping: Matrix,
    pong: Matrix,
}

impl ForwardScratch {
    pub fn new() -> Self {
        ForwardScratch::default()
    }

    /// Move the finished output of an `n_layers` forward pass out of
    /// the scratch (leaving an empty buffer behind) — lets
    /// [`Mlp::forward`] return by move instead of cloning.
    fn take_output(&mut self, n_layers: usize) -> Matrix {
        if n_layers % 2 == 1 {
            std::mem::take(&mut self.ping)
        } else {
            std::mem::take(&mut self.pong)
        }
    }
}

/// Output-stage tail shared by every forward variant: bias broadcast
/// then elementwise activation.
fn apply_bias_activation(z: &mut Matrix, layer: &Layer) {
    z.add_row_inplace(&layer.b);
    let act = layer.activation;
    z.map_inplace(|v| act.apply(v));
}

impl Mlp {
    /// Random init: uniform `±1/√fan_in` weights, zero biases.
    pub fn new(config: MlpConfig, rng: &mut Pcg32) -> Self {
        config.validate().expect("invalid MLP config");
        let layers = config
            .sizes
            .windows(2)
            .zip(&config.activations)
            .map(|(io, &activation)| {
                let (fan_in, fan_out) = (io[0], io[1]);
                let scale = 1.0 / (fan_in as f32).sqrt();
                Layer {
                    w: Matrix::random_uniform(fan_out, fan_in, scale, rng),
                    b: vec![0.0; fan_out],
                    activation,
                }
            })
            .collect();
        Mlp { config, layers }
    }

    pub fn input_dim(&self) -> usize {
        self.config.sizes[0]
    }

    pub fn output_dim(&self) -> usize {
        *self.config.sizes.last().unwrap()
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.data.len() + l.b.len()).sum()
    }

    /// Batched forward: `X` is `B × input_dim`; returns `B × output_dim`.
    ///
    /// Convenience wrapper that allocates fresh scratch; hot paths
    /// (backends, benches) hold a [`ForwardScratch`] and call
    /// [`Mlp::forward_with`] to reuse layer buffers across batches.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut scratch = ForwardScratch::new();
        self.forward_with(x, &mut scratch);
        scratch.take_output(self.layers.len())
    }

    /// Batched forward through caller-owned scratch: no allocation once
    /// the two ping-pong layer buffers are warm. Returns a view of the
    /// final activation living inside `scratch`.
    pub fn forward_with<'s>(&self, x: &Matrix, scratch: &'s mut ForwardScratch) -> &'s Matrix {
        assert_eq!(x.cols, self.input_dim(), "input dim");
        let ForwardScratch { ping, pong } = scratch;
        for (li, layer) in self.layers.iter().enumerate() {
            if li == 0 {
                layer.forward_into(x, ping);
            } else if li % 2 == 1 {
                layer.forward_into(ping, pong);
            } else {
                layer.forward_into(pong, ping);
            }
        }
        // Layer i writes ping when i is even, so an odd layer count
        // finishes in ping.
        if self.layers.len() % 2 == 1 {
            ping
        } else {
            pong
        }
    }

    /// Forward keeping every layer's activation (for backprop):
    /// `activations[0] = x`, `activations[i]` = output of layer i.
    pub fn forward_trace(&self, x: &Matrix) -> Vec<Matrix> {
        let mut acts = Vec::new();
        self.forward_trace_into(x, &mut acts);
        acts
    }

    /// [`Mlp::forward_trace`] into a reusable activation stack: the
    /// training loop calls this once per mini-batch, so after the first
    /// batch every per-layer buffer is reused instead of reallocated.
    pub fn forward_trace_into(&self, x: &Matrix, acts: &mut Vec<Matrix>) {
        assert_eq!(x.cols, self.input_dim(), "input dim");
        let needed = self.layers.len() + 1;
        if acts.len() != needed {
            acts.clear();
            acts.resize(needed, Matrix::zeros(0, 0));
        }
        acts[0].copy_from(x);
        for (i, layer) in self.layers.iter().enumerate() {
            let (before, after) = acts.split_at_mut(i + 1);
            layer.forward_into(&before[i], &mut after[0]);
        }
    }

    /// Single-sample forward (convenience; allocates a 1-row matrix).
    pub fn forward_one(&self, x: &[f32]) -> Vec<f32> {
        let m = Matrix::from_vec(1, x.len(), x.to_vec());
        self.forward(&m).data
    }

    /// Eq 4.3: classification by argmax over the output vector.
    pub fn classify_one(&self, x: &[f32]) -> usize {
        argmax(&self.forward_one(x))
    }

    /// Flatten all parameters as named tensors (w0, b0, w1, b1, …).
    pub fn to_tensors(&self) -> Vec<NamedTensor> {
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            out.push(NamedTensor::new(
                format!("w{i}"),
                vec![layer.w.rows, layer.w.cols],
                layer.w.data.clone(),
            ));
            out.push(NamedTensor::new(format!("b{i}"), vec![layer.b.len()], layer.b.clone()));
            out.push(NamedTensor::new(
                format!("act{i}"),
                vec![1],
                vec![match layer.activation {
                    Activation::Sigmoid => 0.0,
                    Activation::Relu => 1.0,
                    Activation::Identity => 2.0,
                }],
            ));
        }
        out
    }

    /// Rebuild from [`Mlp::to_tensors`] output.
    pub fn from_tensors(tensors: &[NamedTensor]) -> Result<Self> {
        let find = |name: &str| -> Result<&NamedTensor> {
            tensors
                .iter()
                .find(|t| t.name == name)
                .with_context(|| format!("missing tensor '{name}'"))
        };
        let n_layers = tensors.iter().filter(|t| t.name.starts_with('w')).count();
        if n_layers == 0 {
            bail!("no weight tensors found");
        }
        let mut layers = Vec::with_capacity(n_layers);
        let mut sizes = Vec::new();
        let mut activations = Vec::new();
        for i in 0..n_layers {
            let w = find(&format!("w{i}"))?;
            let b = find(&format!("b{i}"))?;
            let act = find(&format!("act{i}"))?;
            if w.shape.len() != 2 {
                bail!("w{i} is not a matrix");
            }
            if b.shape != vec![w.shape[0]] {
                bail!("b{i} shape {:?} vs w{i} rows {}", b.shape, w.shape[0]);
            }
            let activation = match act.data[0] as i32 {
                0 => Activation::Sigmoid,
                1 => Activation::Relu,
                2 => Activation::Identity,
                other => bail!("unknown activation code {other}"),
            };
            if i == 0 {
                sizes.push(w.shape[1]);
            } else if sizes.last() != Some(&w.shape[1]) {
                bail!("layer {i} fan_in {} mismatches previous fan_out", w.shape[1]);
            }
            sizes.push(w.shape[0]);
            activations.push(activation);
            layers.push(Layer {
                w: Matrix::from_vec(w.shape[0], w.shape[1], w.data.clone()),
                b: b.data.clone(),
                activation,
            });
        }
        let config = MlpConfig { sizes, activations };
        config.validate()?;
        Ok(Mlp { config, layers })
    }

    /// Save to an EMLP blob.
    pub fn save(&self, path: &Path) -> Result<()> {
        save_tensors(path, &self.to_tensors())
    }

    /// Load from an EMLP blob.
    pub fn load(path: &Path) -> Result<Self> {
        Mlp::from_tensors(&load_tensors(path)?)
    }
}

/// Index of the maximum element (first on ties) — Eq 4.3.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{assert_allclose, property};

    fn tiny(rng: &mut Pcg32) -> Mlp {
        Mlp::new(
            MlpConfig {
                sizes: vec![4, 5, 3],
                activations: vec![Activation::Sigmoid, Activation::Sigmoid],
            },
            rng,
        )
    }

    #[test]
    fn paper_config_shapes() {
        let mut rng = Pcg32::new(0);
        let mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
        assert_eq!(mlp.input_dim(), 784);
        assert_eq!(mlp.output_dim(), 10);
        assert_eq!(mlp.layers[0].w.rows, 128);
        assert_eq!(mlp.layers[0].w.cols, 784);
        // 784·128 + 128 + 128·10 + 10 = 101_770 params.
        assert_eq!(mlp.num_params(), 101_770);
    }

    #[test]
    fn forward_output_in_sigmoid_range() {
        property("sigmoid MLP output in (0,1)", 16, |rng| {
            let mlp = tiny(rng);
            let x = Matrix::random_uniform(3, 4, 5.0, rng);
            let y = mlp.forward(&x);
            assert_eq!((y.rows, y.cols), (3, 3));
            assert!(y.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        });
    }

    #[test]
    fn forward_batch_equals_per_sample() {
        property("batched == per-sample forward", 16, |rng| {
            let mlp = tiny(rng);
            let x = Matrix::random_uniform(4, 4, 2.0, rng);
            let batched = mlp.forward(&x);
            for r in 0..4 {
                let single = mlp.forward_one(x.row(r));
                assert_allclose(batched.row(r), &single, 1e-6, 1e-6);
            }
        });
    }

    #[test]
    fn forward_with_matches_forward_across_batch_sizes() {
        // The same scratch must serve changing batch sizes (the
        // coordinator's dynamic batching produces ragged batches).
        let mut rng = Pcg32::new(21);
        let mlp = tiny(&mut rng);
        let mut scratch = ForwardScratch::new();
        for &batch in &[1usize, 4, 3, 7, 1] {
            let x = Matrix::random_uniform(batch, 4, 2.0, &mut rng);
            let expect = mlp.forward(&x);
            let got = mlp.forward_with(&x, &mut scratch);
            assert_eq!(got, &expect);
        }
    }

    #[test]
    fn forward_rows_bitwise_stable_under_chunking() {
        // The contract the stage-pipelined backend's micro-batching
        // rests on: a row of the batched forward is bit-identical
        // whether the row rides in the full batch or in any contiguous
        // row chunk. The blocked GEMM guarantees it by construction —
        // each output element's additions happen in a fixed k-order
        // that neither `m` nor the band plan can change.
        let mut rng = Pcg32::new(31);
        for sizes in [vec![11usize, 7, 3], vec![784, 128, 10], vec![6, 64, 64, 3]] {
            let n_layers = sizes.len() - 1;
            let mlp = Mlp::new(
                MlpConfig { sizes, activations: vec![Activation::Sigmoid; n_layers] },
                &mut rng,
            );
            let batch = 9usize;
            let x = Matrix::random_uniform(batch, mlp.input_dim(), 1.0, &mut rng);
            let full = mlp.forward(&x);
            for chunk in [1usize, 2, 4, 9] {
                let mut r0 = 0;
                while r0 < batch {
                    let rows = chunk.min(batch - r0);
                    let mut sub = Matrix::zeros(rows, x.cols);
                    sub.data.copy_from_slice(&x.data[r0 * x.cols..(r0 + rows) * x.cols]);
                    let sub_out = mlp.forward(&sub);
                    for r in 0..rows {
                        for (a, b) in sub_out.row(r).iter().zip(full.row(r0 + r)) {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "row {} chunk {chunk}",
                                r0 + r
                            );
                        }
                    }
                    r0 += rows;
                }
            }
        }
    }

    #[test]
    fn layer_forward_into_is_the_forward_with_code_path() {
        // `Layer::forward_into` chained manually must reproduce
        // `forward_with` bit for bit — it IS the code path, and the
        // stage-pipelined backend holds per-stage `Layer` clones that
        // call exactly this entry point.
        let mut rng = Pcg32::new(32);
        let mlp = tiny(&mut rng);
        let x = Matrix::random_uniform(5, 4, 2.0, &mut rng);
        let want = mlp.forward(&x);
        let mut cur = x;
        let mut next = Matrix::zeros(0, 0);
        for layer in &mlp.layers {
            layer.forward_into(&cur, &mut next);
            std::mem::swap(&mut cur, &mut next);
        }
        assert_eq!(cur, want);
    }

    #[test]
    fn forward_with_odd_layer_count() {
        let mut rng = Pcg32::new(22);
        let mlp = Mlp::new(MlpConfig::paper_qnet(), &mut rng); // 3 layers
        let x = Matrix::random_uniform(2, 6, 1.0, &mut rng);
        let mut scratch = ForwardScratch::new();
        assert_eq!(mlp.forward_with(&x, &mut scratch), &mlp.forward(&x));
    }

    #[test]
    fn forward_trace_into_reuses_buffers() {
        let mut rng = Pcg32::new(23);
        let mlp = tiny(&mut rng);
        let mut acts = Vec::new();
        for _ in 0..3 {
            let x = Matrix::random_uniform(5, 4, 1.0, &mut rng);
            mlp.forward_trace_into(&x, &mut acts);
            assert_eq!(acts.len(), 3);
            assert_eq!(acts[0], x);
            assert_eq!(acts.last().unwrap(), &mlp.forward(&x));
        }
    }

    #[test]
    fn forward_trace_last_equals_forward() {
        let mut rng = Pcg32::new(3);
        let mlp = tiny(&mut rng);
        let x = Matrix::random_uniform(2, 4, 1.0, &mut rng);
        let trace = mlp.forward_trace(&x);
        assert_eq!(trace.len(), 3);
        assert_eq!(trace.last().unwrap(), &mlp.forward(&x));
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Pcg32::new(5);
        let mlp = Mlp::new(MlpConfig::paper_qnet(), &mut rng);
        let dir = std::env::temp_dir().join("edgemlp_mlp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("qnet.emlp");
        mlp.save(&path).unwrap();
        let back = Mlp::load(&path).unwrap();
        assert_eq!(back.config, mlp.config);
        let x = vec![0.1f32, -0.2, 0.3, 0.0, 0.5, -0.9];
        assert_eq!(back.forward_one(&x), mlp.forward_one(&x));
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn config_validation() {
        assert!(MlpConfig { sizes: vec![4], activations: vec![] }.validate().is_err());
        assert!(MlpConfig {
            sizes: vec![4, 0, 2],
            activations: vec![Activation::Relu, Activation::Relu]
        }
        .validate()
        .is_err());
        assert!(MlpConfig {
            sizes: vec![4, 3],
            activations: vec![Activation::Relu, Activation::Relu]
        }
        .validate()
        .is_err());
        assert!(MlpConfig::paper_mnist().validate().is_ok());
    }
}
