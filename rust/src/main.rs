//! `edgemlp` CLI — the leader entrypoint.
//!
//! ```text
//! edgemlp train            --epochs 5 --out /tmp/mlp.emlp
//! edgemlp infer            --model /tmp/mlp.emlp --backend fpga
//! edgemlp serve            --addr 127.0.0.1:7878 --model /tmp/mlp.emlp \
//!                          --replicas 4 --models qnet=/tmp/qnet.emlp \
//!                          --backends cpu,fpga,pipeline,int8 --pipeline-depth 4 \
//!                          --precision int8 \
//!                          --autoscale 1:4 --power-budget-w 3.0 \
//!                          --metrics-addr 127.0.0.1:9184 --trace-capacity 8192
//! edgemlp loadgen          --addr 127.0.0.1:7878 --requests 10000 \
//!                          --model qnet --warmup 500 \
//!                          --idle-conns 10000   # c10k background population
//! edgemlp loadgen          --addr 127.0.0.1:7878 --storm --requests 5000 \
//!                          --connections 16     # burst-reconnect churn
//! edgemlp ctl              --addr 127.0.0.1:7878 \
//!                          --op stats|ping|health|autoscale|swap|models|metrics|trace
//! edgemlp throughput       --requests 500       # in-process E6 sweep
//! edgemlp table1           [--no-xla]         # paper Table I
//! edgemlp fig5                                 # paper Figure 5
//! edgemlp quant-ablation   --bits 3,4,5,6,7,8  # §3.2 schemes
//! edgemlp pipeline-ablation                    # §3.1 claims
//! edgemlp rl               --episodes 80       # §4.2 Acrobot
//! edgemlp verilog          --out design.v      # emit the RTL
//! edgemlp info                                 # artifact registry
//! ```

use anyhow::{bail, Context, Result};
use edgemlp::data::load_digits;
use edgemlp::experiments::common::ExperimentScale;
use edgemlp::experiments::{fig5, pipeline_ablation, quant_ablation, table1, throughput};
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::fpga::verilog::{emit_design, VerilogConfig};
use edgemlp::nn::metrics::{accuracy, confusion_matrix, format_confusion};
use edgemlp::nn::mlp::{argmax, Mlp, MlpConfig};
use edgemlp::nn::train::{train, TrainConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::Calibration;
use edgemlp::rl::qlearn::{evaluate_policy, QLearnConfig, QLearner};
use edgemlp::rl::Acrobot;
use edgemlp::runtime::Runtime;
use edgemlp::util::cli::Args;
use edgemlp::util::rng::Pcg32;
use std::path::{Path, PathBuf};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            usage();
            std::process::exit(2);
        }
    };
    let command = args.command.clone().unwrap_or_else(|| "help".into());
    let result = match command.as_str() {
        "train" => cmd_train(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "ctl" => cmd_ctl(&args),
        "throughput" => cmd_throughput(&args),
        "table1" => cmd_table1(&args),
        "fig5" => cmd_fig5(&args),
        "quant-ablation" => cmd_quant_ablation(&args),
        "pipeline-ablation" => cmd_pipeline_ablation(&args),
        "rl" => cmd_rl(&args),
        "verilog" => cmd_verilog(&args),
        "info" => cmd_info(&args),
        "help" => {
            usage();
            Ok(())
        }
        other => {
            usage();
            Err(anyhow::anyhow!("unknown command '{other}'"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "edgemlp — pipelined matmul + SPx quantization MLP accelerator (paper reproduction)\n\
         commands: train infer serve loadgen ctl throughput table1 fig5 quant-ablation \
         pipeline-ablation rl verilog info"
    );
}

fn scale_from(args: &Args) -> Result<ExperimentScale> {
    let base = ExperimentScale::from_env();
    Ok(ExperimentScale {
        n_train: args.get_parse("train-samples", base.n_train).map_err(anyhow::Error::msg)?,
        n_test: args.get_parse("test-samples", base.n_test).map_err(anyhow::Error::msg)?,
        epochs: args.get_parse("epochs", base.epochs).map_err(anyhow::Error::msg)?,
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let epochs: usize = args.get_parse("epochs", 5).map_err(anyhow::Error::msg)?;
    let n_train: usize = args.get_parse("train-samples", 4000).map_err(anyhow::Error::msg)?;
    let out = PathBuf::from(args.get("out", "/tmp/edgemlp_mlp.emlp"));
    args.finish().map_err(anyhow::Error::msg)?;

    let (train_set, test_set) = load_digits(n_train, n_train / 4, 2021);
    println!(
        "dataset: {} train / {} test ({})",
        train_set.len(),
        test_set.len(),
        train_set.source
    );
    let mut rng = Pcg32::new(42);
    let mut mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let log = train(
        &mut mlp,
        &train_set.inputs,
        &train_set.labels,
        &TrainConfig { epochs, ..Default::default() },
    );
    for s in &log {
        println!("epoch {:>2}  loss {:.4}  train acc {:.3}", s.epoch, s.loss, s.train_accuracy);
    }
    let acc = accuracy(&mlp, &test_set.inputs, &test_set.labels);
    println!("test accuracy: {acc:.3}");
    mlp.save(&out).with_context(|| format!("save {}", out.display()))?;
    println!("saved checkpoint to {}", out.display());
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let model_path = PathBuf::from(args.get("model", "/tmp/edgemlp_mlp.emlp"));
    let backend = args.get("backend", "fpga");
    let n: usize = args.get_parse("samples", 32).map_err(anyhow::Error::msg)?;
    args.finish().map_err(anyhow::Error::msg)?;

    let mlp = Mlp::load(&model_path)
        .with_context(|| format!("load {} (run `edgemlp train` first)", model_path.display()))?;
    let (_, test_set) = load_digits(64, n.max(16), 2021);
    let labels = &test_set.labels[..n.min(test_set.len())];

    let preds: Vec<usize> = match backend.as_str() {
        "cpu" => (0..labels.len()).map(|i| mlp.classify_one(test_set.inputs.row(i))).collect(),
        "fpga" => {
            let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
            let accel = Accelerator::new(q, AccelConfig::default_fpga());
            let mut total = edgemlp::fpga::CycleStats::default();
            let preds = (0..labels.len())
                .map(|i| {
                    let (p, s) = accel.classify_one(test_set.inputs.row(i));
                    total.merge(&s);
                    p
                })
                .collect();
            let t = accel.seconds_per_inference(&total) / labels.len() as f64;
            println!(
                "fpga sim: {:.2} µs/sample, {:.1} W, {:.1}% stalls",
                t * 1e6,
                accel.power_w(&total),
                100.0 * total.stall_fraction()
            );
            preds
        }
        "xla" => {
            let rt = Runtime::new_default()?;
            let model = rt.load("mlp_fp32_b1")?;
            (0..labels.len())
                .map(|i| {
                    let out = model
                        .run(&edgemlp::runtime::executable::mlp_fp32_inputs(
                            &mlp,
                            test_set.inputs.row(i),
                        ))
                        .expect("xla run");
                    argmax(&out)
                })
                .collect()
        }
        other => bail!("unknown backend '{other}' (cpu|fpga|xla)"),
    };
    let acc = edgemlp::nn::metrics::accuracy_from_preds(&preds, labels);
    println!("backend {backend}: accuracy {acc:.3} on {} samples", labels.len());
    println!("{}", format_confusion(&confusion_matrix(&preds, labels, 10)));
    Ok(())
}

/// Start the real TCP server: the replicated multi-model engine behind
/// the wire protocol. Blocks until killed.
fn cmd_serve(args: &Args) -> Result<()> {
    use edgemlp::coordinator::{AutoscalePolicy, BatchPolicy, CoordinatorConfig, DegradePolicy};
    use edgemlp::serve::{
        BackendKind, EngineConfig, ModelRegistry, Precision, ServeConfig, Server,
    };
    use std::time::Duration;

    let addr = args.get("addr", "127.0.0.1:7878");
    let model_path = PathBuf::from(args.get("model", "/tmp/edgemlp_mlp.emlp"));
    let random = args.get_bool("random").map_err(anyhow::Error::msg)?;
    let models = args.get("models", "");
    // `--backend pipeline` is accepted as an alias for `--backends`
    // (the singular reads naturally when serving one kind).
    let backend_alias = args.get("backend", "cpu,fpga");
    let backends = args.get("backends", &backend_alias);
    let pipeline_depth: usize = args.get_parse("pipeline-depth", 2).map_err(anyhow::Error::msg)?;
    let replicas: usize = args.get_parse("replicas", 1).map_err(anyhow::Error::msg)?;
    let queue_capacity: usize =
        args.get_parse("queue-capacity", 1024).map_err(anyhow::Error::msg)?;
    let max_batch: usize = args.get_parse("max-batch", 64).map_err(anyhow::Error::msg)?;
    let window_ms: f64 = args.get_parse("window-ms", 2.0).map_err(anyhow::Error::msg)?;
    let max_conns: usize = args.get_parse("max-conns", 64).map_err(anyhow::Error::msg)?;
    let spx_bits: u32 = args.get_parse("spx-bits", 5).map_err(anyhow::Error::msg)?;
    // `--precision f32|spx|int8|int4` pins every slot's preferred
    // serving precision; BACKEND_ANY then routes to matching pools.
    let precision_arg = args.get("precision", "");
    let read_timeout_s: f64 =
        args.get_parse("read-timeout-s", 30.0).map_err(anyhow::Error::msg)?;
    // Observability knobs: `--metrics-addr host:port` starts the
    // Prometheus sidecar; `--trace-capacity 0` disables request
    // tracing.
    let metrics_addr = args.get("metrics-addr", "");
    let trace_capacity: usize =
        args.get_parse("trace-capacity", 8192).map_err(anyhow::Error::msg)?;
    let mut degrade = DegradePolicy::default();
    degrade.enter_occupancy =
        args.get_parse("degrade-enter", degrade.enter_occupancy).map_err(anyhow::Error::msg)?;
    degrade.exit_occupancy =
        args.get_parse("degrade-exit", degrade.exit_occupancy).map_err(anyhow::Error::msg)?;
    // `--autoscale min:max` runs the replica feedback controller over
    // every pool; `--power-budget-w W` adds the accuracy-for-power
    // loop (usable on its own too — the replica band then stays fixed).
    let autoscale_arg = args.get("autoscale", "");
    let power_budget_arg: f64 =
        args.get_parse("power-budget-w", 0.0).map_err(anyhow::Error::msg)?;
    args.finish().map_err(anyhow::Error::msg)?;
    if !(read_timeout_s > 0.0) {
        bail!("--read-timeout-s must be positive, got {read_timeout_s}");
    }
    degrade.validate().map_err(anyhow::Error::msg)?;
    // One readiness loop serves every connection, so the fd limit is
    // the real connection ceiling — raise it to cover --max-conns
    // (best effort; the hard limit caps what we can get).
    let nofile = edgemlp::serve::raise_nofile_limit(max_conns as u64 + 128);
    if nofile < max_conns as u64 + 16 {
        eprintln!(
            "warning: fd limit {nofile} is below --max-conns {max_conns} + headroom; \
             the server will Busy-reject or fail accepts at the fd ceiling"
        );
    }
    // SpxConfig::sp2 asserts on its range; turn bad flags into a CLI
    // error instead of a panic.
    if !(3..=15).contains(&spx_bits) {
        bail!("--spx-bits must be in 3..=15, got {spx_bits}");
    }
    if replicas == 0 {
        bail!("--replicas must be at least 1");
    }
    if !(1..=64).contains(&pipeline_depth) {
        bail!("--pipeline-depth must be in 1..=64, got {pipeline_depth}");
    }
    let precision: Option<Precision> = if precision_arg.is_empty() {
        None
    } else {
        Some(
            Precision::parse(&precision_arg)
                .ok_or_else(|| anyhow::anyhow!("--precision '{precision_arg}' (f32|spx|int8|int4)"))?,
        )
    };
    let autoscale: Option<AutoscalePolicy> = if autoscale_arg.is_empty() {
        None
    } else {
        let (lo, hi) = autoscale_arg
            .split_once(':')
            .with_context(|| format!("--autoscale '{autoscale_arg}' is not min:max"))?;
        let min: usize =
            lo.trim().parse().map_err(|e| anyhow::anyhow!("--autoscale min: {e}"))?;
        let max: usize =
            hi.trim().parse().map_err(|e| anyhow::anyhow!("--autoscale max: {e}"))?;
        let policy = AutoscalePolicy::band(min, max);
        policy.validate().map_err(anyhow::Error::msg)?;
        Some(policy)
    };
    if power_budget_arg < 0.0 || !power_budget_arg.is_finite() {
        bail!("--power-budget-w must be a positive number of watts, got {power_budget_arg}");
    }
    let power_budget_w = (power_budget_arg > 0.0).then_some(power_budget_arg);

    let mlp = if random {
        let mut rng = Pcg32::new(2021);
        Mlp::new(MlpConfig::paper_mnist(), &mut rng)
    } else {
        Mlp::load(&model_path).with_context(|| {
            format!(
                "load {} (run `edgemlp train` first, or pass --random)",
                model_path.display()
            )
        })?
    };
    let registry = ModelRegistry::new("default", mlp, SpxConfig::sp2(spx_bits));
    // Every --models entry is registered in the catalog AND served in
    // its own slot, routable by name on the wire. When the name
    // collides with an existing slot (e.g. "default"), add_slot is an
    // idempotent no-op, so the freshly loaded version must be activated
    // explicitly — otherwise the slot would keep serving the old
    // weights while the CLI claims the new version is live.
    for entry in models.split(',').filter(|s| !s.is_empty()) {
        let (name, path) = entry
            .split_once('=')
            .with_context(|| format!("--models entry '{entry}' is not name=path"))?;
        let model = registry.load_blob(name, Path::new(path))?;
        let slot = registry.add_slot(name)?;
        if slot.active().version != model.version {
            registry.activate_into(name, name)?;
        }
        println!("serving model '{}' v{} from {path}", model.name, model.version);
    }

    let mut kinds = Vec::new();
    for b in backends.split(',').filter(|s| !s.is_empty()) {
        match b.trim() {
            "cpu" => kinds.push(BackendKind::Cpu),
            "fpga" => kinds.push(BackendKind::FpgaSim(AccelConfig::default_fpga())),
            "pipeline" => kinds.push(BackendKind::PipelineCpu { depth: pipeline_depth }),
            "pipeline-fpga" => kinds.push(BackendKind::PipelineFpga {
                config: AccelConfig::default_fpga(),
                depth: pipeline_depth,
            }),
            "int8" => kinds.push(BackendKind::Int8),
            "int4" => kinds.push(BackendKind::Int4),
            other => {
                bail!("unknown backend '{other}' (cpu|fpga|pipeline|pipeline-fpga|int8|int4)")
            }
        }
    }
    if let Some(p) = precision {
        for slot in registry.slots() {
            slot.set_preferred_precision(Some(p));
        }
        println!("preferred precision: {p}");
    }
    let server = Server::serve(
        registry.clone(),
        &addr,
        EngineConfig {
            replicas,
            backends: kinds,
            coordinator: CoordinatorConfig {
                queue_capacity,
                policy: BatchPolicy::windowed(
                    max_batch,
                    Duration::from_secs_f64(window_ms / 1e3),
                ),
            },
            serve: ServeConfig {
                max_conns,
                read_timeout: Duration::from_secs_f64(read_timeout_s),
                degrade,
                metrics_addr: (!metrics_addr.is_empty()).then(|| metrics_addr.clone()),
                trace_capacity,
                ..ServeConfig::default()
            },
            autoscale,
            power_budget_w,
        },
    )?;
    println!(
        "serving on {} — backends [{backends}] × {replicas} replica(s), queue \
         {queue_capacity}, batch {max_batch}@{window_ms}ms",
        server.local_addr(),
    );
    if let Some(m) = server.metrics_local_addr() {
        println!("  metrics: http://{m}/metrics");
    }
    if let Some(p) = &autoscale {
        println!("  autoscale: [{}, {}] replicas per pool", p.min, p.max);
    }
    if let Some(w) = power_budget_w {
        println!("  power budget: {w} W (accuracy-for-power degrade before shedding)");
    }
    for slot in registry.slots() {
        let active = slot.active();
        println!(
            "  model {}: {} v{} ({}→{})",
            slot.name(),
            active.name,
            active.version,
            active.input_dim(),
            active.output_dim(),
        );
    }
    println!("stop with ctrl-c; `edgemlp ctl --op stats` for live metrics");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// Drive a running server with synthetic load and report latency.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use edgemlp::serve::{run_loadgen, run_slo_sweep, LoadGenConfig, Priority, BACKEND_ANY};

    let addr = args.get("addr", "127.0.0.1:7878");
    let backend_arg = args.get("backend", "any");
    // Comma-separated model names; connections are spread across them.
    let models: Vec<String> = args
        .get("model", "")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    let config = LoadGenConfig {
        requests: args.get_parse("requests", 10_000).map_err(anyhow::Error::msg)?,
        connections: args.get_parse("connections", 8).map_err(anyhow::Error::msg)?,
        backend: if backend_arg == "any" {
            BACKEND_ANY
        } else {
            backend_arg.parse().map_err(|e| anyhow::anyhow!("--backend: {e}"))?
        },
        models,
        dim: args.get_parse("dim", 784).map_err(anyhow::Error::msg)?,
        rate_rps: args.get_parse("rate", 0.0).map_err(anyhow::Error::msg)?,
        batch: args.get_parse("batch", 1).map_err(anyhow::Error::msg)?,
        pipeline: args.get_parse("pipeline", 8).map_err(anyhow::Error::msg)?,
        warmup: args.get_parse("warmup", 0).map_err(anyhow::Error::msg)?,
        seed: args.get_parse("seed", 7).map_err(anyhow::Error::msg)?,
        deadline_us: {
            let ms: f64 = args.get_parse("deadline-ms", 0.0).map_err(anyhow::Error::msg)?;
            (ms * 1e3) as u64
        },
        priority: match args.get("priority", "normal").as_str() {
            "normal" => Priority::Normal,
            "high" => Priority::High,
            "low" => Priority::Low,
            other => bail!("unknown --priority '{other}' (normal|high|low)"),
        },
        // `--idle-conns N` holds N extra idle connections open for the
        // whole run (the c10k background population). The client host
        // needs fd headroom for them too.
        idle_conns: args.get_parse("idle-conns", 0).map_err(anyhow::Error::msg)?,
    };
    // `--sweep 0.5,1,2,4` replays the same scenario at multiples of
    // `--rate` and prints the SLO attainment / shed-rate curve.
    let sweep = args.get("sweep", "");
    // `--storm` switches to the burst-reconnect scenario: --requests
    // connect→ping→disconnect cycles across --connections threads.
    let storm = args.get_bool("storm").map_err(anyhow::Error::msg)?;
    args.finish().map_err(anyhow::Error::msg)?;
    if config.idle_conns > 0 {
        edgemlp::serve::raise_nofile_limit(config.idle_conns as u64 + 256);
    }

    // Resolve hostnames too, so `--addr localhost:7878` works like it
    // does for `serve` and `ctl` — and probe each resolved address,
    // because `localhost` is often [::1, 127.0.0.1] and the server may
    // listen on only one of them.
    use std::net::ToSocketAddrs;
    let candidates: Vec<std::net::SocketAddr> = addr
        .to_socket_addrs()
        .map_err(|e| anyhow::anyhow!("--addr '{addr}': {e}"))?
        .collect();
    let addr = candidates
        .iter()
        .find(|a| {
            std::net::TcpStream::connect_timeout(a, std::time::Duration::from_secs(2)).is_ok()
        })
        .copied()
        .with_context(|| format!("--addr '{addr}': no resolved address accepts connections"))?;
    if storm {
        let report =
            edgemlp::serve::run_reconnect_storm(addr, config.connections, config.requests)?;
        println!("{}", report.render());
        return Ok(());
    }
    if !sweep.is_empty() {
        use edgemlp::bench_harness::Table;
        let factors: Vec<f64> = sweep
            .split(',')
            .map(|s| s.trim().parse().map_err(|e| anyhow::anyhow!("--sweep: {e}")))
            .collect::<Result<_>>()?;
        let points = run_slo_sweep(addr, &config, &factors)
            .context("--sweep needs --rate > 0 and --deadline-ms > 0")?;
        let mut table = Table::new(&[
            "rate (rps)",
            "sent",
            "ok",
            "shed",
            "expired",
            "errors",
            "attainment",
            "shed rate",
            "p99",
        ]);
        for p in &points {
            table.row(&[
                format!("{:.0}", p.rate_rps),
                p.sent.to_string(),
                p.ok.to_string(),
                p.shed.to_string(),
                p.expired.to_string(),
                p.errors.to_string(),
                format!("{:.1}%", p.attainment * 100.0),
                format!("{:.1}%", p.shed_rate * 100.0),
                format!("{:.2} ms", p.p99_s * 1e3),
            ]);
        }
        table.print();
        return Ok(());
    }
    let report = run_loadgen(addr, config)?;
    println!("{}", report.render());
    // Surface the server's modeled energy accounting (Stats appends
    // `energy ...` lines computed from aggregate CycleStats). Best
    // effort: an old server without the lines just prints nothing.
    if let Ok(mut client) = edgemlp::serve::Client::connect(addr) {
        if let Ok(stats) = client.stats() {
            for line in stats.lines().filter(|l| l.starts_with("energy ")) {
                println!("{line}");
            }
        }
    }
    Ok(())
}

/// One-shot control operations against a running server.
fn cmd_ctl(args: &Args) -> Result<()> {
    use edgemlp::serve::Client;

    let addr = args.get("addr", "127.0.0.1:7878");
    let op = args.get("op", "stats");
    let model = args.get("model", "");
    let into = args.get("into", "");
    let out = args.get("out", "");
    let precision_arg = args.get("precision", "");
    args.finish().map_err(anyhow::Error::msg)?;

    let mut client = Client::connect(&addr)?;
    match op.as_str() {
        "ping" => {
            let rtt = client.ping()?;
            println!("pong from {addr} in {:.1} µs", rtt.as_secs_f64() * 1e6);
        }
        "stats" => print!("{}", client.stats()?),
        "health" => {
            use edgemlp::bench_harness::Table;
            let h = client.health()?;
            println!(
                "degraded: {} | transitions: {} | read timeouts: {} | busy rejected: {}",
                if h.degraded { "YES" } else { "no" },
                h.degraded_transitions,
                h.read_timeouts,
                h.busy_rejected,
            );
            if !h.bad_requests.is_empty() {
                let causes: Vec<String> =
                    h.bad_requests.iter().map(|(c, n)| format!("{c}={n}")).collect();
                println!("bad requests: {}", causes.join(" "));
            }
            let mut table =
                Table::new(&["pool", "depth", "capacity", "replicas", "shed", "expired"]);
            for p in &h.pools {
                table.row(&[
                    p.name.clone(),
                    p.queue_depth.to_string(),
                    p.queue_capacity.to_string(),
                    p.replicas.to_string(),
                    p.shed.to_string(),
                    p.expired.to_string(),
                ]);
            }
            table.print();
        }
        "swap" => {
            if model.is_empty() {
                bail!("--op swap needs --model <name> (and optionally --into <slot>, \
                       --precision f32|spx|int8|int4)");
            }
            let precision = if precision_arg.is_empty() {
                None
            } else {
                Some(edgemlp::serve::Precision::parse(&precision_arg).ok_or_else(|| {
                    anyhow::anyhow!("--precision '{precision_arg}' (f32|spx|int8|int4)")
                })?)
            };
            println!("{}", client.swap_model_with_precision(&into, &model, precision)?);
        }
        "models" => {
            use edgemlp::bench_harness::Table;
            let models = client.list_models()?;
            let mut table =
                Table::new(&["slot", "active model", "version", "dims", "generation", "precision"]);
            for m in &models {
                table.row(&[
                    m.slot.clone(),
                    m.model.clone(),
                    m.version.to_string(),
                    format!("{}→{}", m.input_dim, m.output_dim),
                    m.generation.to_string(),
                    m.precision.label().to_string(),
                ]);
            }
            table.print();
        }
        "autoscale" => {
            let (h, _, autoscale) = client.health_full()?;
            match autoscale {
                None => println!("server sent no autoscale block (pre-autoscaler build)"),
                Some(a) if !a.enabled => println!("autoscaler: off (fixed replica counts)"),
                Some(a) => {
                    let budget = if a.budget_mw == 0 {
                        "none".to_string()
                    } else {
                        format!("{:.2} W", a.budget_mw as f64 / 1e3)
                    };
                    println!(
                        "autoscaler: band [{}, {}] | {} scale-ups / {} scale-downs | \
                         power {:.3} W (budget {budget}) | power-degraded: {}",
                        a.min_replicas,
                        a.max_replicas,
                        a.scale_ups,
                        a.scale_downs,
                        a.power_mw as f64 / 1e3,
                        if a.power_degraded { "YES" } else { "no" },
                    );
                    use edgemlp::bench_harness::Table;
                    let mut table = Table::new(&["pool", "replicas", "depth", "capacity"]);
                    for p in &h.pools {
                        table.row(&[
                            p.name.clone(),
                            p.replicas.to_string(),
                            p.queue_depth.to_string(),
                            p.queue_capacity.to_string(),
                        ]);
                    }
                    table.print();
                }
            }
        }
        "metrics" => print!("{}", client.metrics_text()?),
        "trace" => {
            let json = client.dump_trace()?;
            if out.is_empty() {
                println!("{json}");
            } else {
                std::fs::write(&out, &json)
                    .with_context(|| format!("write trace to {out}"))?;
                println!("wrote {} bytes to {out} (load in Perfetto / chrome://tracing)", json.len());
            }
        }
        other => {
            bail!("unknown op '{other}' (ping|stats|health|autoscale|swap|models|metrics|trace)")
        }
    }
    Ok(())
}

/// The in-process E6 throughput sweep (pre-PR-2 `serve` behavior).
fn cmd_throughput(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let rows = throughput::run(scale)?;
    println!("{}", throughput::render(&rows));
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let no_xla = args.get_bool("no-xla").map_err(anyhow::Error::msg)?;
    let scale = scale_from(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let t = table1::run(scale, !no_xla)?;
    println!("Table I — time per sample and power (paper values alongside)\n");
    println!("{}", table1::render(&t));
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let points = fig5::run(scale);
    println!("Figure 5 — inference time per sample across training epochs\n");
    println!("{}", fig5::render(&points));
    println!("flatness (CV of time series): {:.3}", fig5::flatness(&points));
    Ok(())
}

fn cmd_quant_ablation(args: &Args) -> Result<()> {
    let bits_str = args.get("bits", "3,4,5,6,8");
    let scale = scale_from(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let bits: Vec<u32> = bits_str
        .split(',')
        .map(|s| s.trim().parse().map_err(|e| anyhow::anyhow!("--bits: {e}")))
        .collect::<Result<_>>()?;
    let fp32 = quant_ablation::fp32_accuracy(scale);
    let rows = quant_ablation::run(scale, &bits);
    println!("Quantization ablation (§3.2) — uniform vs PoT vs SP2 vs SPx\n");
    println!("{}", quant_ablation::render(&rows, fp32));
    let (fp32_e2e, precision_rows) = quant_ablation::run_precision_modes(scale);
    println!("\nServing-precision ablation — f32 vs SPx vs VSQ int8/int4 end to end\n");
    println!("{}", quant_ablation::render_precision_modes(fp32_e2e, &precision_rows));
    Ok(())
}

fn cmd_pipeline_ablation(args: &Args) -> Result<()> {
    args.finish().map_err(anyhow::Error::msg)?;
    let a = pipeline_ablation::run();
    println!("Pipeline ablation (§3.1)\n");
    println!("{}", pipeline_ablation::render(&a));
    Ok(())
}

fn cmd_rl(args: &Args) -> Result<()> {
    let episodes: usize = args.get_parse("episodes", 80).map_err(anyhow::Error::msg)?;
    let eval_episodes: usize =
        args.get_parse("eval-episodes", 10).map_err(anyhow::Error::msg)?;
    args.finish().map_err(anyhow::Error::msg)?;

    let mut env = Acrobot::new();
    let config = QLearnConfig { episodes, ..Default::default() };
    let mut learner = QLearner::new(&env, config);
    println!("training Q-learning on Acrobot-v1 for {episodes} episodes...");
    let stats = learner.train(&mut env);
    for chunk in stats.chunks(10) {
        let mean_ret: f64 =
            chunk.iter().map(|s| s.return_sum as f64).sum::<f64>() / chunk.len() as f64;
        println!(
            "episodes {:>3}-{:>3}  mean return {:>7.1}  ε {:.2}",
            chunk[0].episode,
            chunk.last().unwrap().episode,
            mean_ret,
            chunk.last().unwrap().epsilon
        );
    }

    // E5: fp32 policy vs SPx-quantized policy.
    let qnet = learner.qnet.clone();
    let mut fp32_q = |obs: &[f32]| qnet.forward_one(obs);
    let fp32_returns = evaluate_policy(&mut env, &mut fp32_q, eval_episodes, 123);

    let quant =
        QuantizedMlp::from_mlp(&learner.qnet, &SpxConfig::spx(8, 2), Calibration::MaxAbs, None);
    let accel = Accelerator::new(quant, AccelConfig::default_fpga());
    let mut spx_q = |obs: &[f32]| accel.forward_decoded(obs);
    let spx_returns = evaluate_policy(&mut env, &mut spx_q, eval_episodes, 123);

    let mean = |v: &[f32]| v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
    println!("\nE5 — greedy-policy returns over {eval_episodes} episodes:");
    println!("  fp32 Q-network:       {:>7.1}", mean(&fp32_returns));
    println!("  SPx(b=8,x=2) on sim:  {:>7.1}", mean(&spx_returns));
    Ok(())
}

fn cmd_verilog(args: &Args) -> Result<()> {
    let out = args.get("out", "-");
    let bits: u32 = args.get_parse("bits", 5).map_err(anyhow::Error::msg)?;
    let terms: u32 = args.get_parse("terms", 2).map_err(anyhow::Error::msg)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let cfg = VerilogConfig {
        spx: SpxConfig::spx(bits, terms),
        ..VerilogConfig::default_design()
    };
    let design = emit_design(&cfg);
    if out == "-" {
        println!("{design}");
    } else {
        std::fs::write(&out, &design)?;
        println!("wrote {} ({} lines)", out, design.lines().count());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish().map_err(anyhow::Error::msg)?;
    let rt = Runtime::new_default()?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts ({}):", rt.registry.len());
    for name in rt.registry.names() {
        let spec = rt.registry.get(name)?;
        println!(
            "  {name}: model={} batch={} inputs={} ({})",
            spec.model,
            spec.batch,
            spec.inputs.len(),
            spec.path.display()
        );
    }
    Ok(())
}
