//! `edgemlp` CLI — the leader entrypoint.
//!
//! ```text
//! edgemlp train            --epochs 5 --out /tmp/mlp.emlp
//! edgemlp infer            --model /tmp/mlp.emlp --backend fpga
//! edgemlp serve            --requests 500 --rate 800
//! edgemlp table1           [--no-xla]         # paper Table I
//! edgemlp fig5                                 # paper Figure 5
//! edgemlp quant-ablation   --bits 3,4,5,6,7,8  # §3.2 schemes
//! edgemlp pipeline-ablation                    # §3.1 claims
//! edgemlp rl               --episodes 80       # §4.2 Acrobot
//! edgemlp verilog          --out design.v      # emit the RTL
//! edgemlp info                                 # artifact registry
//! ```

use anyhow::{bail, Context, Result};
use edgemlp::data::load_digits;
use edgemlp::experiments::common::ExperimentScale;
use edgemlp::experiments::{fig5, pipeline_ablation, quant_ablation, table1, throughput};
use edgemlp::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use edgemlp::fpga::verilog::{emit_design, VerilogConfig};
use edgemlp::nn::metrics::{accuracy, confusion_matrix, format_confusion};
use edgemlp::nn::mlp::{argmax, Mlp, MlpConfig};
use edgemlp::nn::train::{train, TrainConfig};
use edgemlp::quant::spx::SpxConfig;
use edgemlp::quant::Calibration;
use edgemlp::rl::qlearn::{evaluate_policy, QLearnConfig, QLearner};
use edgemlp::rl::Acrobot;
use edgemlp::runtime::Runtime;
use edgemlp::util::cli::Args;
use edgemlp::util::rng::Pcg32;
use std::path::PathBuf;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            usage();
            std::process::exit(2);
        }
    };
    let command = args.command.clone().unwrap_or_else(|| "help".into());
    let result = match command.as_str() {
        "train" => cmd_train(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "table1" => cmd_table1(&args),
        "fig5" => cmd_fig5(&args),
        "quant-ablation" => cmd_quant_ablation(&args),
        "pipeline-ablation" => cmd_pipeline_ablation(&args),
        "rl" => cmd_rl(&args),
        "verilog" => cmd_verilog(&args),
        "info" => cmd_info(&args),
        "help" => {
            usage();
            Ok(())
        }
        other => {
            usage();
            Err(anyhow::anyhow!("unknown command '{other}'"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "edgemlp — pipelined matmul + SPx quantization MLP accelerator (paper reproduction)\n\
         commands: train infer serve table1 fig5 quant-ablation pipeline-ablation rl verilog info"
    );
}

fn scale_from(args: &Args) -> Result<ExperimentScale> {
    let base = ExperimentScale::from_env();
    Ok(ExperimentScale {
        n_train: args.get_parse("train-samples", base.n_train).map_err(anyhow::Error::msg)?,
        n_test: args.get_parse("test-samples", base.n_test).map_err(anyhow::Error::msg)?,
        epochs: args.get_parse("epochs", base.epochs).map_err(anyhow::Error::msg)?,
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let epochs: usize = args.get_parse("epochs", 5).map_err(anyhow::Error::msg)?;
    let n_train: usize = args.get_parse("train-samples", 4000).map_err(anyhow::Error::msg)?;
    let out = PathBuf::from(args.get("out", "/tmp/edgemlp_mlp.emlp"));
    args.finish().map_err(anyhow::Error::msg)?;

    let (train_set, test_set) = load_digits(n_train, n_train / 4, 2021);
    println!(
        "dataset: {} train / {} test ({})",
        train_set.len(),
        test_set.len(),
        train_set.source
    );
    let mut rng = Pcg32::new(42);
    let mut mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let log = train(
        &mut mlp,
        &train_set.inputs,
        &train_set.labels,
        &TrainConfig { epochs, ..Default::default() },
    );
    for s in &log {
        println!("epoch {:>2}  loss {:.4}  train acc {:.3}", s.epoch, s.loss, s.train_accuracy);
    }
    let acc = accuracy(&mlp, &test_set.inputs, &test_set.labels);
    println!("test accuracy: {acc:.3}");
    mlp.save(&out).with_context(|| format!("save {}", out.display()))?;
    println!("saved checkpoint to {}", out.display());
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let model_path = PathBuf::from(args.get("model", "/tmp/edgemlp_mlp.emlp"));
    let backend = args.get("backend", "fpga");
    let n: usize = args.get_parse("samples", 32).map_err(anyhow::Error::msg)?;
    args.finish().map_err(anyhow::Error::msg)?;

    let mlp = Mlp::load(&model_path)
        .with_context(|| format!("load {} (run `edgemlp train` first)", model_path.display()))?;
    let (_, test_set) = load_digits(64, n.max(16), 2021);
    let labels = &test_set.labels[..n.min(test_set.len())];

    let preds: Vec<usize> = match backend.as_str() {
        "cpu" => (0..labels.len()).map(|i| mlp.classify_one(test_set.inputs.row(i))).collect(),
        "fpga" => {
            let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(5), Calibration::MaxAbs, None);
            let accel = Accelerator::new(q, AccelConfig::default_fpga());
            let mut total = edgemlp::fpga::CycleStats::default();
            let preds = (0..labels.len())
                .map(|i| {
                    let (p, s) = accel.classify_one(test_set.inputs.row(i));
                    total.merge(&s);
                    p
                })
                .collect();
            let t = accel.seconds_per_inference(&total) / labels.len() as f64;
            println!(
                "fpga sim: {:.2} µs/sample, {:.1} W, {:.1}% stalls",
                t * 1e6,
                accel.power_w(&total),
                100.0 * total.stall_fraction()
            );
            preds
        }
        "xla" => {
            let rt = Runtime::new_default()?;
            let model = rt.load("mlp_fp32_b1")?;
            (0..labels.len())
                .map(|i| {
                    let out = model
                        .run(&edgemlp::runtime::executable::mlp_fp32_inputs(
                            &mlp,
                            test_set.inputs.row(i),
                        ))
                        .expect("xla run");
                    argmax(&out)
                })
                .collect()
        }
        other => bail!("unknown backend '{other}' (cpu|fpga|xla)"),
    };
    let acc = edgemlp::nn::metrics::accuracy_from_preds(&preds, labels);
    println!("backend {backend}: accuracy {acc:.3} on {} samples", labels.len());
    println!("{}", format_confusion(&confusion_matrix(&preds, labels, 10)));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let rows = throughput::run(scale)?;
    println!("{}", throughput::render(&rows));
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let no_xla = args.get_bool("no-xla").map_err(anyhow::Error::msg)?;
    let scale = scale_from(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let t = table1::run(scale, !no_xla)?;
    println!("Table I — time per sample and power (paper values alongside)\n");
    println!("{}", table1::render(&t));
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let scale = scale_from(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let points = fig5::run(scale);
    println!("Figure 5 — inference time per sample across training epochs\n");
    println!("{}", fig5::render(&points));
    println!("flatness (CV of time series): {:.3}", fig5::flatness(&points));
    Ok(())
}

fn cmd_quant_ablation(args: &Args) -> Result<()> {
    let bits_str = args.get("bits", "3,4,5,6,8");
    let scale = scale_from(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let bits: Vec<u32> = bits_str
        .split(',')
        .map(|s| s.trim().parse().map_err(|e| anyhow::anyhow!("--bits: {e}")))
        .collect::<Result<_>>()?;
    let fp32 = quant_ablation::fp32_accuracy(scale);
    let rows = quant_ablation::run(scale, &bits);
    println!("Quantization ablation (§3.2) — uniform vs PoT vs SP2 vs SPx\n");
    println!("{}", quant_ablation::render(&rows, fp32));
    Ok(())
}

fn cmd_pipeline_ablation(args: &Args) -> Result<()> {
    args.finish().map_err(anyhow::Error::msg)?;
    let a = pipeline_ablation::run();
    println!("Pipeline ablation (§3.1)\n");
    println!("{}", pipeline_ablation::render(&a));
    Ok(())
}

fn cmd_rl(args: &Args) -> Result<()> {
    let episodes: usize = args.get_parse("episodes", 80).map_err(anyhow::Error::msg)?;
    let eval_episodes: usize =
        args.get_parse("eval-episodes", 10).map_err(anyhow::Error::msg)?;
    args.finish().map_err(anyhow::Error::msg)?;

    let mut env = Acrobot::new();
    let config = QLearnConfig { episodes, ..Default::default() };
    let mut learner = QLearner::new(&env, config);
    println!("training Q-learning on Acrobot-v1 for {episodes} episodes...");
    let stats = learner.train(&mut env);
    for chunk in stats.chunks(10) {
        let mean_ret: f64 =
            chunk.iter().map(|s| s.return_sum as f64).sum::<f64>() / chunk.len() as f64;
        println!(
            "episodes {:>3}-{:>3}  mean return {:>7.1}  ε {:.2}",
            chunk[0].episode,
            chunk.last().unwrap().episode,
            mean_ret,
            chunk.last().unwrap().epsilon
        );
    }

    // E5: fp32 policy vs SPx-quantized policy.
    let qnet = learner.qnet.clone();
    let mut fp32_q = |obs: &[f32]| qnet.forward_one(obs);
    let fp32_returns = evaluate_policy(&mut env, &mut fp32_q, eval_episodes, 123);

    let quant =
        QuantizedMlp::from_mlp(&learner.qnet, &SpxConfig::spx(8, 2), Calibration::MaxAbs, None);
    let accel = Accelerator::new(quant, AccelConfig::default_fpga());
    let mut spx_q = |obs: &[f32]| accel.forward_decoded(obs);
    let spx_returns = evaluate_policy(&mut env, &mut spx_q, eval_episodes, 123);

    let mean = |v: &[f32]| v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
    println!("\nE5 — greedy-policy returns over {eval_episodes} episodes:");
    println!("  fp32 Q-network:       {:>7.1}", mean(&fp32_returns));
    println!("  SPx(b=8,x=2) on sim:  {:>7.1}", mean(&spx_returns));
    Ok(())
}

fn cmd_verilog(args: &Args) -> Result<()> {
    let out = args.get("out", "-");
    let bits: u32 = args.get_parse("bits", 5).map_err(anyhow::Error::msg)?;
    let terms: u32 = args.get_parse("terms", 2).map_err(anyhow::Error::msg)?;
    args.finish().map_err(anyhow::Error::msg)?;
    let cfg = VerilogConfig {
        spx: SpxConfig::spx(bits, terms),
        ..VerilogConfig::default_design()
    };
    let design = emit_design(&cfg);
    if out == "-" {
        println!("{design}");
    } else {
        std::fs::write(&out, &design)?;
        println!("wrote {} ({} lines)", out, design.lines().count());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish().map_err(anyhow::Error::msg)?;
    let rt = Runtime::new_default()?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts ({}):", rt.registry.len());
    for name in rt.registry.names() {
        let spec = rt.registry.get(name)?;
        println!(
            "  {name}: model={} batch={} inputs={} ({})",
            spec.model,
            spec.batch,
            spec.inputs.len(),
            spec.path.display()
        );
    }
    Ok(())
}
