//! Bounded MPMC queue with blocking push (backpressure), non-blocking
//! try-push (load shedding), and a batch-draining pop designed for the
//! dynamic batcher: wait for the first item, then keep collecting until
//! either `max` items are in hand or `window` has elapsed.
//!
//! Multiple consumers may call [`BoundedQueue::pop_batch`] concurrently
//! — that is how a replicated worker pool shares one submission queue.
//! Each item is delivered to exactly one consumer (the drain happens
//! under the state mutex), and a consumer that drains a batch while
//! items remain passes the baton by re-notifying another waiter, so a
//! burst larger than one consumer's `max` cannot strand work behind a
//! straggler window.
//!
//! [`BoundedQueue::with_key`] turns the FIFO into a priority queue:
//! items are held in ascending key order (stable — equal keys keep
//! arrival order), which is how the coordinator gets earliest-deadline-
//! first batch formation without a separate scheduler thread.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Ordering key for [`BoundedQueue::with_key`].
pub type KeyFn<T> = Box<dyn Fn(&T) -> u64 + Send + Sync>;

/// Why a push or pop did not complete.
#[derive(Debug, PartialEq, Eq)]
pub enum QueueError {
    /// Queue is full (try_push only).
    Full,
    /// Queue was closed.
    Closed,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// The queue. Clone-free: share via `Arc`.
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    /// When set, items are kept sorted ascending by this key.
    key_fn: Option<KeyFn<T>>,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
            key_fn: None,
        }
    }

    /// A priority variant: items are held in ascending `key` order, so
    /// `pop_batch` always drains the smallest keys first. The insert is
    /// stable (an item lands *after* existing items with an equal key),
    /// preserving FIFO order within a key — deadline-free requests all
    /// share one key and behave exactly like the plain FIFO.
    pub fn with_key(capacity: usize, key: impl Fn(&T) -> u64 + Send + Sync + 'static) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
            key_fn: Some(Box::new(key)),
        }
    }

    /// Ordered (or plain FIFO) insert into the locked state.
    fn insert(&self, items: &mut VecDeque<T>, item: T) {
        match &self.key_fn {
            None => items.push_back(item),
            Some(f) => {
                let k = f(&item);
                let idx = items.partition_point(|it| f(it) <= k);
                items.insert(idx, item);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking push; waits while full. Errors only if closed.
    pub fn push(&self, item: T) -> Result<(), QueueError> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(QueueError::Closed);
            }
            if st.items.len() < self.capacity {
                self.insert(&mut st.items, item);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking push — `Err(Full)` signals backpressure to the
    /// caller (load shedding at the edge).
    pub fn try_push(&self, item: T) -> Result<(), QueueError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(QueueError::Closed);
        }
        if st.items.len() >= self.capacity {
            return Err(QueueError::Full);
        }
        self.insert(&mut st.items, item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dynamic-batch pop: block until at least one item (or close),
    /// then drain up to `max` items, waiting at most `window` after the
    /// first item for stragglers. Returns an empty vec only when the
    /// queue is closed and drained.
    pub fn pop_batch(&self, max: usize, window: Duration) -> Vec<T> {
        static NEVER: AtomicBool = AtomicBool::new(false);
        self.pop_batch_cancel(max, window, &NEVER)
    }

    /// [`BoundedQueue::pop_batch`] with a per-consumer cancel flag: a
    /// consumer whose flag is raised stops waiting for work and returns
    /// empty as soon as it holds no items, without closing the queue
    /// for its siblings. A batch already claimed is still returned in
    /// full — cancellation is checked only while empty-handed, so a
    /// retiring pool worker can never drop a request. Pair a raised
    /// flag with [`BoundedQueue::nudge`] so a parked consumer actually
    /// wakes to observe it.
    pub fn pop_batch_cancel(
        &self,
        max: usize,
        window: Duration,
        cancel: &AtomicBool,
    ) -> Vec<T> {
        assert!(max > 0);
        let mut st = self.state.lock().unwrap();
        loop {
            // Phase 1: wait for the first item.
            while st.items.is_empty() {
                if st.closed || cancel.load(Ordering::Acquire) {
                    return Vec::new();
                }
                st = self.not_empty.wait(st).unwrap();
            }
            if cancel.load(Ordering::Acquire) {
                // Items exist but this consumer is retiring: leave them
                // for a sibling and make sure one is awake to take them.
                self.not_empty.notify_one();
                return Vec::new();
            }
            let deadline = Instant::now() + window;
            // Phase 2: batch window.
            loop {
                if st.items.len() >= max || st.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) =
                    self.not_empty.wait_timeout(st, deadline - now).unwrap();
                st = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = st.items.len().min(max);
            if take == 0 {
                // A sibling consumer drained the items this consumer
                // saw in phase 1 while it waited out its straggler
                // window. An empty return must mean closed+drained —
                // consumers exit on it — so go back to waiting.
                if st.closed {
                    return Vec::new();
                }
                continue;
            }
            let batch: Vec<T> = st.items.drain(..take).collect();
            for _ in 0..take {
                self.not_full.notify_one();
            }
            if !st.items.is_empty() {
                // Baton pass: leftover items mean another consumer (if
                // any is parked) has work right now — a push's
                // notify_one may have been absorbed by this consumer's
                // straggler window.
                self.not_empty.notify_one();
            }
            return batch;
        }
    }

    /// Wake every parked consumer without changing queue state, so
    /// consumers whose cancel flag was just raised re-check it. Spurious
    /// wakeups are harmless — non-cancelled consumers go straight back
    /// to waiting.
    pub fn nudge(&self) {
        let _st = self.state.lock().unwrap();
        self.not_empty.notify_all();
    }

    /// Close: unblock all waiters; further pushes fail.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        let batch = q.pop_batch(5, Duration::ZERO);
        assert_eq!(batch, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn try_push_full_signals_backpressure() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(QueueError::Full));
    }

    #[test]
    fn pop_respects_max() {
        let q = BoundedQueue::new(16);
        for i in 0..10 {
            q.push(i).unwrap();
        }
        assert_eq!(q.pop_batch(4, Duration::ZERO).len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn pop_waits_for_first_item() {
        let q = Arc::new(BoundedQueue::new(4));
        let q2 = q.clone();
        let t = thread::spawn(move || q2.pop_batch(4, Duration::from_millis(1)));
        thread::sleep(Duration::from_millis(20));
        q.push(42).unwrap();
        let batch = t.join().unwrap();
        assert_eq!(batch, vec![42]);
    }

    #[test]
    fn batch_window_collects_stragglers() {
        let q = Arc::new(BoundedQueue::new(16));
        let q2 = q.clone();
        q.push(1).unwrap();
        let t = thread::spawn(move || q2.pop_batch(4, Duration::from_millis(80)));
        thread::sleep(Duration::from_millis(10));
        q.push(2).unwrap();
        q.push(3).unwrap();
        let batch = t.join().unwrap();
        assert!(batch.len() >= 3, "batch {batch:?}");
    }

    #[test]
    fn close_unblocks_poppers() {
        let q = Arc::new(BoundedQueue::<u32>::new(4));
        let q2 = q.clone();
        let t = thread::spawn(move || q2.pop_batch(4, Duration::from_millis(50)));
        thread::sleep(Duration::from_millis(10));
        q.close();
        assert!(t.join().unwrap().is_empty());
        assert_eq!(q.push(1), Err(QueueError::Closed));
    }

    #[test]
    fn multi_consumer_every_item_delivered_exactly_once() {
        // Four consumers drain one queue concurrently; every pushed item
        // must come back exactly once across all of them.
        let q = Arc::new(BoundedQueue::<u32>::new(256));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        let batch = q.pop_batch(8, Duration::from_millis(1));
                        if batch.is_empty() {
                            return got; // closed + drained
                        }
                        got.extend(batch);
                    }
                })
            })
            .collect();
        for i in 0..200u32 {
            q.push(i).unwrap();
        }
        q.close();
        let mut all: Vec<u32> =
            consumers.into_iter().flat_map(|t| t.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..200).collect::<Vec<_>>());
    }

    #[test]
    fn burst_larger_than_one_batch_reaches_second_consumer() {
        // One consumer takes at most 4 items; a burst of 12 must not
        // strand the remaining 8 behind its batch window — the baton
        // pass wakes the second consumer.
        let q = Arc::new(BoundedQueue::<u32>::new(64));
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = 0usize;
                    loop {
                        let batch = q.pop_batch(4, Duration::from_millis(200));
                        if batch.is_empty() {
                            return got;
                        }
                        got += batch.len();
                    }
                })
            })
            .collect();
        thread::sleep(Duration::from_millis(20)); // both parked in phase 1
        for i in 0..12u32 {
            q.push(i).unwrap();
        }
        thread::sleep(Duration::from_millis(100));
        q.close();
        let total: usize = consumers.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 12);
    }

    #[test]
    fn robbed_consumer_keeps_waiting_instead_of_returning_empty() {
        // A consumer that saw items in phase 1 can have them all
        // drained by a sibling during its straggler window. It must go
        // back to waiting — an empty return means closed+drained, and
        // pool workers exit on it.
        let q = Arc::new(BoundedQueue::new(8));
        q.push(1).unwrap();
        let q2 = q.clone();
        let victim = thread::spawn(move || q2.pop_batch(4, Duration::from_millis(300)));
        // Let the victim enter its batch window, then steal the item.
        thread::sleep(Duration::from_millis(30));
        let stolen = q.pop_batch(4, Duration::ZERO);
        assert_eq!(stolen, vec![1]);
        // Past the victim's window: were it buggy it would now have
        // returned an empty batch. Feed it a new item instead.
        thread::sleep(Duration::from_millis(400));
        q.push(2).unwrap();
        assert_eq!(victim.join().unwrap(), vec![2]);
    }

    #[test]
    fn keyed_queue_drains_smallest_keys_first() {
        // Key = the item itself: pop order is ascending regardless of
        // push order — the EDF property batch formation relies on.
        let q = BoundedQueue::with_key(16, |&x: &u64| x);
        for v in [50u64, 10, 40, 20, 30] {
            q.push(v).unwrap();
        }
        assert_eq!(q.pop_batch(3, Duration::ZERO), vec![10, 20, 30]);
        // A later push with a smaller key jumps ahead of what remains.
        q.try_push(5).unwrap();
        assert_eq!(q.pop_batch(8, Duration::ZERO), vec![5, 40, 50]);
    }

    #[test]
    fn keyed_queue_is_fifo_within_equal_keys() {
        // (key, arrival) pairs: equal keys must keep arrival order, so
        // deadline-free traffic (one shared key) stays strictly FIFO.
        let q = BoundedQueue::with_key(16, |&(k, _): &(u64, u32)| k);
        for (i, k) in [7u64, 7, 3, 7, 3].into_iter().enumerate() {
            q.push((k, i as u32)).unwrap();
        }
        let batch = q.pop_batch(8, Duration::ZERO);
        assert_eq!(batch, vec![(3, 2), (3, 4), (7, 0), (7, 1), (7, 3)]);
    }

    #[test]
    fn cancelled_consumer_returns_empty_without_closing_queue() {
        // Raise one consumer's cancel flag and nudge: it returns empty
        // while the queue stays open and a sibling still gets the work.
        let q = Arc::new(BoundedQueue::<u32>::new(8));
        let cancel = Arc::new(AtomicBool::new(false));
        let (q2, c2) = (q.clone(), cancel.clone());
        let retiring =
            thread::spawn(move || q2.pop_batch_cancel(4, Duration::from_millis(50), &c2));
        thread::sleep(Duration::from_millis(20)); // parked in phase 1
        cancel.store(true, Ordering::Release);
        q.nudge();
        assert!(retiring.join().unwrap().is_empty());
        assert!(!q.is_closed());
        q.push(7).unwrap();
        assert_eq!(q.pop_batch(4, Duration::ZERO), vec![7]);
    }

    #[test]
    fn cancelled_consumer_leaves_queued_items_to_siblings() {
        // Items are already waiting when the cancelled consumer arrives:
        // it must not claim them, and must wake a sibling to take them.
        let q = Arc::new(BoundedQueue::<u32>::new(8));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let cancel = AtomicBool::new(true);
        assert!(q.pop_batch_cancel(4, Duration::from_millis(50), &cancel).is_empty());
        assert_eq!(q.len(), 2, "cancelled consumer consumed items");
        assert_eq!(q.pop_batch(4, Duration::ZERO), vec![1, 2]);
    }

    #[test]
    fn blocking_push_resumes_after_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(1).unwrap();
        let q2 = q.clone();
        let t = thread::spawn(move || q2.push(2));
        thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop_batch(1, Duration::ZERO), vec![1]);
        t.join().unwrap().unwrap();
        assert_eq!(q.pop_batch(1, Duration::ZERO), vec![2]);
    }
}
