//! Bounded MPSC queue with blocking push (backpressure), non-blocking
//! try-push (load shedding), and a batch-draining pop designed for the
//! dynamic batcher: wait for the first item, then keep collecting until
//! either `max` items are in hand or `window` has elapsed.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push or pop did not complete.
#[derive(Debug, PartialEq, Eq)]
pub enum QueueError {
    /// Queue is full (try_push only).
    Full,
    /// Queue was closed.
    Closed,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// The queue. Clone-free: share via `Arc`.
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking push; waits while full. Errors only if closed.
    pub fn push(&self, item: T) -> Result<(), QueueError> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(QueueError::Closed);
            }
            if st.items.len() < self.capacity {
                st.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking push — `Err(Full)` signals backpressure to the
    /// caller (load shedding at the edge).
    pub fn try_push(&self, item: T) -> Result<(), QueueError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(QueueError::Closed);
        }
        if st.items.len() >= self.capacity {
            return Err(QueueError::Full);
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dynamic-batch pop: block until at least one item (or close),
    /// then drain up to `max` items, waiting at most `window` after the
    /// first item for stragglers. Returns an empty vec only when the
    /// queue is closed and drained.
    pub fn pop_batch(&self, max: usize, window: Duration) -> Vec<T> {
        assert!(max > 0);
        let mut st = self.state.lock().unwrap();
        // Phase 1: wait for the first item.
        while st.items.is_empty() {
            if st.closed {
                return Vec::new();
            }
            st = self.not_empty.wait(st).unwrap();
        }
        let deadline = Instant::now() + window;
        // Phase 2: batch window.
        loop {
            if st.items.len() >= max || st.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let take = st.items.len().min(max);
        let batch: Vec<T> = st.items.drain(..take).collect();
        for _ in 0..take {
            self.not_full.notify_one();
        }
        batch
    }

    /// Close: unblock all waiters; further pushes fail.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        let batch = q.pop_batch(5, Duration::ZERO);
        assert_eq!(batch, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn try_push_full_signals_backpressure() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(QueueError::Full));
    }

    #[test]
    fn pop_respects_max() {
        let q = BoundedQueue::new(16);
        for i in 0..10 {
            q.push(i).unwrap();
        }
        assert_eq!(q.pop_batch(4, Duration::ZERO).len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn pop_waits_for_first_item() {
        let q = Arc::new(BoundedQueue::new(4));
        let q2 = q.clone();
        let t = thread::spawn(move || q2.pop_batch(4, Duration::from_millis(1)));
        thread::sleep(Duration::from_millis(20));
        q.push(42).unwrap();
        let batch = t.join().unwrap();
        assert_eq!(batch, vec![42]);
    }

    #[test]
    fn batch_window_collects_stragglers() {
        let q = Arc::new(BoundedQueue::new(16));
        let q2 = q.clone();
        q.push(1).unwrap();
        let t = thread::spawn(move || q2.pop_batch(4, Duration::from_millis(80)));
        thread::sleep(Duration::from_millis(10));
        q.push(2).unwrap();
        q.push(3).unwrap();
        let batch = t.join().unwrap();
        assert!(batch.len() >= 3, "batch {batch:?}");
    }

    #[test]
    fn close_unblocks_poppers() {
        let q = Arc::new(BoundedQueue::<u32>::new(4));
        let q2 = q.clone();
        let t = thread::spawn(move || q2.pop_batch(4, Duration::from_millis(50)));
        thread::sleep(Duration::from_millis(10));
        q.close();
        assert!(t.join().unwrap().is_empty());
        assert_eq!(q.push(1), Err(QueueError::Closed));
    }

    #[test]
    fn blocking_push_resumes_after_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(1).unwrap();
        let q2 = q.clone();
        let t = thread::spawn(move || q2.push(2));
        thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop_batch(1, Duration::ZERO), vec![1]);
        t.join().unwrap().unwrap();
        assert_eq!(q.pop_batch(1, Duration::ZERO), vec![2]);
    }
}
