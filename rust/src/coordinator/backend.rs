//! Inference backends the router can dispatch to.
//!
//! A [`Backend`] consumes a batch of flattened inputs and returns one
//! output vector per input. Three implementations mirror Table I's
//! device rows:
//!
//! * [`CpuBackend`] — the rust [`crate::nn::Mlp`] forward (Table I "CPU");
//! * [`FpgaBackend`] — the cycle-accurate simulator (Table I "FPGA"),
//!   which also reports [`CycleStats`] for the power model;
//! * the XLA backend — built *inside* its worker thread via a factory
//!   because PJRT handles are not `Send` (see [`super::server`]); the
//!   generic [`FnBackend`] adapter wraps it and any test double.

use crate::fpga::accelerator::Accelerator;
use crate::fpga::stats::CycleStats;
use crate::nn::kernels::pipeline::StageSnapshot;
use crate::nn::mlp::ForwardScratch;
use crate::nn::tensor::Matrix;
use crate::nn::vsq::VsqMlp;
use crate::nn::Mlp;
use anyhow::Result;

/// Stage a batch of flattened samples into a reusable `B × d` matrix.
/// Shared with the stage-pipelined backends
/// ([`crate::serve::pipeline_backend`]), so every batch-oriented
/// backend validates per-sample dimensions identically.
pub(crate) fn stage_inputs(staging: &mut Matrix, inputs: &[Vec<f32>], d: usize) -> Result<()> {
    staging.resize_zeroed(inputs.len(), d);
    for (i, sample) in inputs.iter().enumerate() {
        anyhow::ensure!(sample.len() == d, "sample {i}: {} != input dim {d}", sample.len());
        staging.data[i * d..(i + 1) * d].copy_from_slice(sample);
    }
    Ok(())
}

/// A batch-oriented inference engine.
pub trait Backend {
    fn name(&self) -> &str;
    /// Largest batch `infer` accepts (the batcher caps at this).
    fn max_batch(&self) -> usize;
    /// Run a batch; `inputs[i]` is one flattened sample. Returns one
    /// output per input plus simulator stats if this backend has them.
    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)>;
    /// Per-stage occupancy/stall counters, for stage-pipelined backends
    /// only (`None` for monolithic ones). The worker loop forwards the
    /// latest snapshot into the metrics sink after each batch, which is
    /// how they reach `MetricsSnapshot::render` and the `Stats` opcode.
    fn stage_stats(&self) -> Option<Vec<StageSnapshot>> {
        None
    }
    /// One representative sample for warm-up timing. A worker runs it
    /// once (off-queue, unmetered) right after construction and seeds
    /// the pool's admission-control service EMA from the measured
    /// latency, so a tight-deadline burst against a fresh pool is shed
    /// on arrival instead of fully admitted and expired at dequeue.
    /// `None` (the default, and what test doubles keep) skips
    /// calibration: the estimator starts cold and admits optimistically.
    fn calibration_input(&self) -> Option<Vec<f32>> {
        None
    }
}

/// Table I "CPU": the pure-rust MLP forward at f32, batched through the
/// blocked GEMM with worker-owned scratch — the steady-state serving
/// loop allocates only the response vectors.
pub struct CpuBackend {
    pub mlp: Mlp,
    name: String,
    staging: Matrix,
    scratch: ForwardScratch,
}

impl CpuBackend {
    pub fn new(mlp: Mlp) -> Self {
        CpuBackend {
            mlp,
            name: "cpu".into(),
            staging: Matrix::zeros(0, 0),
            scratch: ForwardScratch::new(),
        }
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        256
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        stage_inputs(&mut self.staging, inputs, self.mlp.input_dim())?;
        let y = self.mlp.forward_with(&self.staging, &mut self.scratch);
        let out = (0..inputs.len()).map(|r| y.row(r).to_vec()).collect();
        Ok((out, None))
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        Some(vec![0.0; self.mlp.input_dim()])
    }
}

/// Table I "FPGA": the cycle-accurate accelerator simulator. Dispatches
/// whole batches through the weight-stationary SPx kernel
/// ([`Accelerator::infer_batch`]): outputs are bit-identical to the
/// per-sample stream engine, and the reported event trace is exactly
/// what per-sample simulation would merge (the counters are
/// data-independent), so the power model sees the same numbers at a
/// fraction of the host cost.
pub struct FpgaBackend {
    pub accel: Accelerator,
    name: String,
    staging: Matrix,
}

impl FpgaBackend {
    pub fn new(accel: Accelerator) -> Self {
        FpgaBackend { accel, name: "fpga".into(), staging: Matrix::zeros(0, 0) }
    }
}

impl Backend for FpgaBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        // The simulated engine streams samples; host-side batching
        // amortizes the code stream, so accept moderate batches.
        64
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        let d = self.accel.model.layers[0].w.shape[1];
        stage_inputs(&mut self.staging, inputs, d)?;
        let (y, stats) = self.accel.infer_batch(&self.staging);
        let out = (0..inputs.len()).map(|r| y.row(r).to_vec()).collect();
        Ok((out, Some(stats)))
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        Some(vec![0.0; self.accel.model.layers[0].w.shape[1]])
    }
}

/// Low-bit integer backend: the VSQ int8/int4 forward
/// ([`crate::nn::vsq::VsqMlp`]) through the SIMD integer dot kernel.
/// Moves 4–8× fewer weight bytes per sample than [`CpuBackend`], which
/// is the point — see docs/quantization-modes.md.
pub struct VsqBackend {
    pub model: VsqMlp,
    name: String,
    staging: Matrix,
}

impl VsqBackend {
    pub fn new(model: VsqMlp) -> Self {
        let name = format!("int{}", model.bits());
        VsqBackend { model, name, staging: Matrix::zeros(0, 0) }
    }
}

impl Backend for VsqBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        256
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        stage_inputs(&mut self.staging, inputs, self.model.input_dim())?;
        let y = self.model.forward_batch(&self.staging);
        let out = (0..inputs.len()).map(|r| y.row(r).to_vec()).collect();
        Ok((out, None))
    }

    fn calibration_input(&self) -> Option<Vec<f32>> {
        Some(vec![0.0; self.model.input_dim()])
    }
}

/// Adapter turning a closure into a [`Backend`] — used for the XLA
/// backend (closure captures the non-`Send` runtime inside its worker
/// thread) and for test doubles.
pub struct FnBackend<F> {
    name: String,
    max_batch: usize,
    f: F,
}

impl<F> FnBackend<F>
where
    F: FnMut(&[Vec<f32>]) -> Result<Vec<Vec<f32>>>,
{
    pub fn new(name: impl Into<String>, max_batch: usize, f: F) -> Self {
        FnBackend { name: name.into(), max_batch, f }
    }
}

impl<F> Backend for FnBackend<F>
where
    F: FnMut(&[Vec<f32>]) -> Result<Vec<Vec<f32>>>,
{
    fn name(&self) -> &str {
        &self.name
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn infer(&mut self, inputs: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, Option<CycleStats>)> {
        Ok(((self.f)(inputs)?, None))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::accelerator::{AccelConfig, QuantizedMlp};
    use crate::nn::mlp::MlpConfig;
    use crate::quant::spx::SpxConfig;
    use crate::quant::Calibration;
    use crate::util::check::assert_allclose;
    use crate::util::rng::Pcg32;

    fn mnist_mlp() -> Mlp {
        let mut rng = Pcg32::new(1);
        let activations = MlpConfig::paper_mnist().activations;
        Mlp::new(MlpConfig { sizes: vec![8, 6, 3], activations }, &mut rng)
    }

    #[test]
    fn cpu_backend_matches_direct_forward() {
        let mlp = mnist_mlp();
        let mut be = CpuBackend::new(mlp.clone());
        let inputs = vec![vec![0.3f32; 8], vec![0.7f32; 8]];
        let (out, stats) = be.infer(&inputs).unwrap();
        assert!(stats.is_none());
        assert_allclose(&out[0], &mlp.forward_one(&inputs[0]), 1e-6, 1e-6);
        assert_allclose(&out[1], &mlp.forward_one(&inputs[1]), 1e-6, 1e-6);
    }

    #[test]
    fn cpu_backend_rejects_bad_dims() {
        let mut be = CpuBackend::new(mnist_mlp());
        assert!(be.infer(&[vec![0.0; 5]]).is_err());
    }

    #[test]
    fn fpga_backend_returns_stats() {
        let mlp = mnist_mlp();
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(6), Calibration::MaxAbs, None);
        let mut be = FpgaBackend::new(Accelerator::new(q, AccelConfig::default_fpga()));
        let (out, stats) = be.infer(&[vec![0.5f32; 8], vec![0.1f32; 8]]).unwrap();
        assert_eq!(out.len(), 2);
        let stats = stats.unwrap();
        // 2 samples × (8·6 + 6·3) MACs.
        assert_eq!(stats.macs, 2 * (48 + 18));
    }

    #[test]
    fn fpga_backend_batch_matches_per_sample_stream() {
        let mlp = mnist_mlp();
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(6), Calibration::MaxAbs, None);
        let mut be = FpgaBackend::new(Accelerator::new(q, AccelConfig::default_fpga()));
        let inputs: Vec<Vec<f32>> =
            (0..5).map(|i| vec![0.1 * (i as f32 + 1.0); 8]).collect();
        let (out, _) = be.infer(&inputs).unwrap();
        for (i, sample) in inputs.iter().enumerate() {
            let (want, _) = be.accel.infer_one(sample);
            assert_eq!(out[i], want, "sample {i}");
        }
    }

    #[test]
    fn fpga_backend_rejects_bad_dims() {
        let mlp = mnist_mlp();
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(6), Calibration::MaxAbs, None);
        let mut be = FpgaBackend::new(Accelerator::new(q, AccelConfig::default_fpga()));
        assert!(be.infer(&[vec![0.0; 3]]).is_err());
    }

    #[test]
    fn vsq_backend_matches_model_forward() {
        let mlp = mnist_mlp();
        for bits in [8u8, 4] {
            let v = VsqMlp::from_mlp(&mlp, bits, 4, Calibration::MaxAbs, None);
            let mut be = VsqBackend::new(v.clone());
            assert_eq!(be.name(), format!("int{bits}"));
            let inputs = vec![vec![0.3f32; 8], vec![0.7f32; 8]];
            let (out, stats) = be.infer(&inputs).unwrap();
            assert!(stats.is_none());
            for (i, sample) in inputs.iter().enumerate() {
                assert_eq!(out[i], v.forward_one(sample), "bits {bits} sample {i}");
            }
            assert!(be.infer(&[vec![0.0; 5]]).is_err(), "bad dims accepted");
        }
    }

    #[test]
    fn calibration_inputs_match_model_dims() {
        // Real backends offer a correctly sized warm-up sample, so the
        // startup calibration forward cannot fail on a dim mismatch;
        // the closure adapter (test doubles, XLA) stays calibration-free
        // so cold-estimator tests keep their semantics.
        let mlp = mnist_mlp();
        let cpu = CpuBackend::new(mlp.clone());
        assert_eq!(cpu.calibration_input().unwrap().len(), 8);
        let q = QuantizedMlp::from_mlp(&mlp, &SpxConfig::sp2(6), Calibration::MaxAbs, None);
        let fpga = FpgaBackend::new(Accelerator::new(q, AccelConfig::default_fpga()));
        assert_eq!(fpga.calibration_input().unwrap().len(), 8);
        let vsq = VsqBackend::new(VsqMlp::from_mlp(&mlp, 8, 4, Calibration::MaxAbs, None));
        assert_eq!(vsq.calibration_input().unwrap().len(), 8);
        let fnb = FnBackend::new("echo", 4, |inputs: &[Vec<f32>]| Ok(inputs.to_vec()));
        assert!(fnb.calibration_input().is_none());
    }

    #[test]
    fn fn_backend_wraps_closure() {
        let mut be = FnBackend::new("echo", 4, |inputs: &[Vec<f32>]| {
            Ok(inputs.iter().map(|v| v.clone()).collect())
        });
        assert_eq!(be.name(), "echo");
        let (out, _) = be.infer(&[vec![1.0, 2.0]]).unwrap();
        assert_eq!(out[0], vec![1.0, 2.0]);
    }
}
