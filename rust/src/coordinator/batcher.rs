//! Dynamic batching policy: how many requests to coalesce and how long
//! to wait for stragglers. The throughput bench (E6) sweeps these.

use std::time::Duration;

/// Batching knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Upper bound on batch size (further capped by the backend's
    /// `max_batch`).
    pub max_batch: usize,
    /// How long to hold the first request while waiting for more.
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// Latency-first: serve immediately, batch only what is already
    /// queued.
    pub fn immediate(max_batch: usize) -> Self {
        BatchPolicy { max_batch, max_wait: Duration::ZERO }
    }

    /// Throughput-first: the paper's B = 64 with a small window.
    pub fn windowed(max_batch: usize, max_wait: Duration) -> Self {
        BatchPolicy { max_batch, max_wait }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err("max_batch must be positive".into());
        }
        if self.max_wait > Duration::from_secs(10) {
            return Err("max_wait over 10s is surely a bug".into());
        }
        Ok(())
    }
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_papers_batch() {
        assert_eq!(BatchPolicy::default().max_batch, 64);
    }

    #[test]
    fn immediate_has_zero_wait() {
        let p = BatchPolicy::immediate(8);
        assert_eq!(p.max_wait, Duration::ZERO);
        p.validate().unwrap();
    }

    #[test]
    fn validation_rejects_zero_batch() {
        assert!(BatchPolicy::immediate(0).validate().is_err());
    }

    #[test]
    fn validation_rejects_absurd_wait() {
        let p = BatchPolicy::windowed(8, Duration::from_secs(60));
        assert!(p.validate().is_err());
    }
}
