//! The coordinator proper: per-backend queues + worker threads, request
//! routing, graceful shutdown.
//!
//! Backends are supplied as *factories* executed inside each worker
//! thread — the XLA backend's PJRT handles are not `Send`, so the
//! runtime must be constructed where it is used. Worker startup is
//! confirmed through a handshake channel so `Coordinator::start`
//! surfaces backend construction errors synchronously.

use super::backend::Backend;
use super::batcher::BatchPolicy;
use super::metrics::Metrics;
use super::queue::{BoundedQueue, QueueError};
use super::request::{InferRequest, InferResult, InferResponse};
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Factory run on the worker thread to build its backend.
pub type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn Backend>> + Send>;

/// Coordinator-wide knobs.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    /// Per-backend queue capacity (requests beyond this are shed).
    pub queue_capacity: usize,
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { queue_capacity: 1024, policy: BatchPolicy::default() }
    }
}

/// Submission failure modes surfaced to clients.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — backpressure; retry later or shed.
    Backpressure,
    /// Coordinator is shutting down.
    Closed,
    /// No backend with that name.
    UnknownBackend,
}

/// Running coordinator. Drop or call [`Coordinator::shutdown`] to stop.
pub struct Coordinator {
    queues: Vec<Arc<BoundedQueue<InferRequest>>>,
    names: Vec<String>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    round_robin: AtomicUsize,
}

impl Coordinator {
    /// Spawn one worker per `(name, factory)` pair; blocks until every
    /// backend reports ready (or fails).
    pub fn start(
        backends: Vec<(String, BackendFactory)>,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        config.policy.validate().map_err(|e| anyhow::anyhow!(e))?;
        if backends.is_empty() {
            bail!("need at least one backend");
        }
        let metrics = Arc::new(Metrics::new());
        let mut queues = Vec::new();
        let mut names = Vec::new();
        let mut workers = Vec::new();
        for (name, factory) in backends {
            let queue = Arc::new(BoundedQueue::<InferRequest>::new(config.queue_capacity));
            let (ready_tx, ready_rx) = channel::<Result<()>>();
            let worker = {
                let queue = queue.clone();
                let metrics = metrics.clone();
                let name = name.clone();
                let policy = config.policy;
                std::thread::Builder::new()
                    .name(format!("edgemlp-{name}"))
                    .spawn(move || {
                        let mut backend = match factory() {
                            Ok(b) => {
                                let _ = ready_tx.send(Ok(()));
                                b
                            }
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        };
                        worker_loop(&name, backend.as_mut(), &queue, &metrics, policy);
                    })
                    .context("spawn worker")?
            };
            ready_rx
                .recv()
                .context("worker handshake lost")?
                .with_context(|| format!("backend '{name}' failed to start"))?;
            queues.push(queue);
            names.push(name);
            workers.push(worker);
        }
        Ok(Coordinator {
            queues,
            names,
            workers,
            metrics,
            next_id: AtomicU64::new(0),
            round_robin: AtomicUsize::new(0),
        })
    }

    pub fn backend_names(&self) -> &[String] {
        &self.names
    }

    pub fn backend_index(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    fn make_request(&self, payload: Vec<f32>) -> (InferRequest, Receiver<InferResult>) {
        let (tx, rx) = channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            payload,
            enqueued_at: Instant::now(),
            respond_to: tx,
        };
        (req, rx)
    }

    /// Blocking submit to a specific backend.
    pub fn submit_to(
        &self,
        backend: usize,
        payload: Vec<f32>,
    ) -> Result<Receiver<InferResult>, SubmitError> {
        let queue = self.queues.get(backend).ok_or(SubmitError::UnknownBackend)?;
        let (req, rx) = self.make_request(payload);
        match queue.push(req) {
            Ok(()) => Ok(rx),
            Err(QueueError::Closed) => Err(SubmitError::Closed),
            Err(QueueError::Full) => unreachable!("push blocks on full"),
        }
    }

    /// Non-blocking submit — `Backpressure` tells the edge client to
    /// shed or retry.
    pub fn try_submit_to(
        &self,
        backend: usize,
        payload: Vec<f32>,
    ) -> Result<Receiver<InferResult>, SubmitError> {
        let queue = self.queues.get(backend).ok_or(SubmitError::UnknownBackend)?;
        let (req, rx) = self.make_request(payload);
        match queue.try_push(req) {
            Ok(()) => Ok(rx),
            Err(QueueError::Closed) => Err(SubmitError::Closed),
            Err(QueueError::Full) => {
                self.metrics.record_rejected();
                Err(SubmitError::Backpressure)
            }
        }
    }

    /// Round-robin submit across backends.
    pub fn submit(&self, payload: Vec<f32>) -> Result<Receiver<InferResult>, SubmitError> {
        let idx = self.round_robin.fetch_add(1, Ordering::Relaxed) % self.queues.len();
        self.submit_to(idx, payload)
    }

    /// Close the submission queues without consuming the coordinator:
    /// later submits fail with [`SubmitError::Closed`] while workers
    /// drain everything already queued and then exit. Needed by owners
    /// that hold the coordinator behind an `Arc` (the TCP server) and
    /// therefore cannot call [`Coordinator::shutdown`]; joining happens
    /// in `Drop`.
    pub fn stop(&self) {
        for q in &self.queues {
            q.close();
        }
    }

    /// Close queues and join workers (drains in-flight requests).
    pub fn shutdown(mut self) {
        for q in &self.queues {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for q in &self.queues {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Body of a backend worker thread.
fn worker_loop(
    name: &str,
    backend: &mut dyn Backend,
    queue: &BoundedQueue<InferRequest>,
    metrics: &Metrics,
    policy: BatchPolicy,
) {
    let max_batch = policy.max_batch.min(backend.max_batch()).max(1);
    loop {
        let batch = queue.pop_batch(max_batch, policy.max_wait);
        if batch.is_empty() {
            return; // closed + drained
        }
        let inputs: Vec<Vec<f32>> = batch.iter().map(|r| r.payload.clone()).collect();
        match backend.infer(&inputs) {
            Ok((outputs, cycle_stats)) => {
                debug_assert_eq!(outputs.len(), batch.len());
                let now = Instant::now();
                let latencies: Vec<f64> = batch
                    .iter()
                    .map(|r| now.duration_since(r.enqueued_at).as_secs_f64())
                    .collect();
                metrics.record_batch(name, batch.len(), &latencies, cycle_stats.as_ref());
                for ((req, output), &latency_s) in
                    batch.into_iter().zip(outputs).zip(&latencies)
                {
                    let _ = req.respond_to.send(Ok(InferResponse {
                        id: req.id,
                        output,
                        latency_s,
                        backend: name.to_string(),
                        batch_size: inputs.len(),
                    }));
                }
            }
            Err(e) => {
                metrics.record_error(name);
                let msg = format!("backend '{name}': {e:#}");
                for req in batch {
                    let _ = req.respond_to.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::FnBackend;
    use std::time::Duration;

    fn echo_factory(name: &str) -> (String, BackendFactory) {
        let n = name.to_string();
        (
            n.clone(),
            Box::new(move || {
                Ok(Box::new(FnBackend::new(n, 16, |inputs: &[Vec<f32>]| {
                    Ok(inputs.iter().map(|v| v.iter().map(|x| x * 2.0).collect()).collect())
                })) as Box<dyn Backend>)
            }),
        )
    }

    #[test]
    fn serves_requests_end_to_end() {
        let coord =
            Coordinator::start(vec![echo_factory("echo")], CoordinatorConfig::default())
                .unwrap();
        let rx = coord.submit(vec![1.0, 2.0]).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(resp.output, vec![2.0, 4.0]);
        assert_eq!(resp.backend, "echo");
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let coord = Coordinator::start(
            vec![echo_factory("echo")],
            CoordinatorConfig {
                queue_capacity: 512,
                policy: BatchPolicy::windowed(8, Duration::from_millis(1)),
            },
        )
        .unwrap();
        let receivers: Vec<_> =
            (0..200).map(|i| coord.submit(vec![i as f32]).unwrap()).collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.output, vec![2.0 * i as f32]);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.backends["echo"].requests, 200);
        coord.shutdown();
    }

    #[test]
    fn failing_backend_start_is_synchronous_error() {
        let failing: (String, BackendFactory) = (
            "bad".into(),
            Box::new(|| anyhow::bail!("no device")),
        );
        match Coordinator::start(vec![failing], CoordinatorConfig::default()) {
            Ok(_) => panic!("expected startup failure"),
            Err(e) => assert!(format!("{e:#}").contains("no device")),
        }
    }

    #[test]
    fn backend_error_propagates_to_clients() {
        let flaky: (String, BackendFactory) = (
            "flaky".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("flaky", 8, |_inputs: &[Vec<f32>]| {
                    anyhow::bail!("kaboom")
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(vec![flaky], CoordinatorConfig::default()).unwrap();
        let rx = coord.submit(vec![1.0]).unwrap();
        let result = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(result.unwrap_err().contains("kaboom"));
        assert_eq!(coord.metrics().snapshot().backends["flaky"].errors, 1);
        coord.shutdown();
    }

    #[test]
    fn backpressure_on_tiny_queue() {
        // A backend that blocks forever would hang shutdown; instead use
        // a slow backend and a capacity-1 queue.
        let slow: (String, BackendFactory) = (
            "slow".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("slow", 1, |inputs: &[Vec<f32>]| {
                    std::thread::sleep(Duration::from_millis(50));
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(
            vec![slow],
            CoordinatorConfig { queue_capacity: 1, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        // Fill: one in flight + one queued; the third must shed.
        let _a = coord.try_submit_to(0, vec![1.0]).unwrap();
        let mut shed = false;
        for _ in 0..50 {
            match coord.try_submit_to(0, vec![2.0]) {
                Err(SubmitError::Backpressure) => {
                    shed = true;
                    break;
                }
                Ok(_) => continue,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(shed, "expected backpressure on capacity-1 queue");
        assert!(coord.metrics().snapshot().rejected >= 1);
        coord.shutdown();
    }

    #[test]
    fn try_submit_full_on_saturated_queue() {
        // A capacity-1 queue behind a backend that never finishes its
        // first batch within the test window: once one request is in
        // flight and one is parked in the queue, try_submit must shed.
        let slow: (String, BackendFactory) = (
            "slow".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("slow", 1, |inputs: &[Vec<f32>]| {
                    std::thread::sleep(Duration::from_millis(200));
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(
            vec![slow],
            CoordinatorConfig { queue_capacity: 1, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        let _a = coord.try_submit_to(0, vec![1.0]).unwrap();
        let mut saw_full = false;
        let mut held = Vec::new();
        for _ in 0..50 {
            match coord.try_submit_to(0, vec![2.0]) {
                Ok(rx) => held.push(rx),
                Err(SubmitError::Backpressure) => {
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(saw_full, "saturated queue never reported Full/Backpressure");
        assert!(coord.metrics().snapshot().rejected >= 1);
        coord.shutdown();
    }

    #[test]
    fn submit_after_stop_returns_closed() {
        let coord =
            Coordinator::start(vec![echo_factory("echo")], CoordinatorConfig::default())
                .unwrap();
        coord.stop();
        assert!(matches!(coord.submit(vec![1.0]), Err(SubmitError::Closed)));
        assert!(matches!(coord.try_submit_to(0, vec![1.0]), Err(SubmitError::Closed)));
        coord.shutdown();
    }

    #[test]
    fn stop_drains_in_flight_work() {
        // Queue a pile of requests against a deliberately slow backend,
        // close the queues immediately, and verify every queued request
        // still gets an answer (graceful drain, not drop).
        let slow: (String, BackendFactory) = (
            "slow".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("slow", 4, |inputs: &[Vec<f32>]| {
                    std::thread::sleep(Duration::from_millis(2));
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(
            vec![slow],
            CoordinatorConfig {
                queue_capacity: 64,
                policy: BatchPolicy::windowed(4, Duration::from_millis(1)),
            },
        )
        .unwrap();
        let receivers: Vec<_> =
            (0..20).map(|i| coord.submit(vec![i as f32]).unwrap()).collect();
        coord.stop();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.output, vec![i as f32], "request {i} lost in drain");
        }
        coord.shutdown();
    }

    #[test]
    fn routes_by_backend_index() {
        let coord = Coordinator::start(
            vec![echo_factory("a"), echo_factory("b")],
            CoordinatorConfig::default(),
        )
        .unwrap();
        assert_eq!(coord.backend_index("b"), Some(1));
        let rx = coord.submit_to(1, vec![3.0]).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().backend, "b");
        coord.shutdown();
    }
}
