//! The coordinator proper: replicated worker pools over shared MPMC
//! queues, queue-depth-aware request routing, graceful shutdown.
//!
//! Each *pool* is one submission queue drained by `replicas` worker
//! threads, every worker owning its own backend instance — the software
//! mirror of the paper's array of parallel processing units. Backends
//! are supplied as *factories* executed inside each worker thread — the
//! XLA backend's PJRT handles are not `Send`, so the runtime must be
//! constructed where it is used. Worker startup is confirmed through a
//! handshake channel so [`Coordinator::start`] surfaces backend
//! construction errors synchronously.

use super::backend::Backend;
use super::batcher::BatchPolicy;
use super::metrics::Metrics;
use super::queue::{BoundedQueue, QueueError};
use super::request::{
    CompletionNotify, InferError, InferRequest, InferResponse, InferResult, Responder,
    PRIORITY_NORMAL,
};
use crate::nn::kernels::pipeline::panic_message;
use crate::obs::trace::TraceRecorder;
use anyhow::{bail, Context, Result};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Factory run once on a worker thread to build its backend.
pub type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn Backend>> + Send>;

/// Re-usable factory for replicated pools: called once per replica,
/// each call on that replica's worker thread.
pub type SharedBackendFactory = Arc<dyn Fn() -> Result<Box<dyn Backend>> + Send + Sync>;

/// One worker pool: a name (the metrics / routing label), plus one
/// backend factory per replica sharing a single submission queue.
/// Replicated pools keep their [`SharedBackendFactory`] so the
/// coordinator can spawn additional replicas after startup
/// ([`Coordinator::scale_to`]); single-factory pools cannot grow.
pub struct PoolSpec {
    pub name: String,
    factories: Vec<BackendFactory>,
    shared: Option<SharedBackendFactory>,
}

impl PoolSpec {
    /// A single-replica pool (the pre-replication coordinator shape).
    /// Not scalable — there is no factory left to build a second
    /// replica from.
    pub fn single(name: impl Into<String>, factory: BackendFactory) -> PoolSpec {
        PoolSpec { name: name.into(), factories: vec![factory], shared: None }
    }

    /// A pool of `replicas` workers, each building its own backend from
    /// the shared factory. The factory is retained, so the pool can be
    /// rescaled at runtime.
    pub fn replicated(
        name: impl Into<String>,
        replicas: usize,
        factory: SharedBackendFactory,
    ) -> PoolSpec {
        let factories = (0..replicas.max(1))
            .map(|_| {
                let f = factory.clone();
                Box::new(move || f()) as BackendFactory
            })
            .collect();
        PoolSpec { name: name.into(), factories, shared: Some(factory) }
    }

    pub fn replicas(&self) -> usize {
        self.factories.len()
    }
}

impl From<(String, BackendFactory)> for PoolSpec {
    fn from((name, factory): (String, BackendFactory)) -> PoolSpec {
        PoolSpec::single(name, factory)
    }
}

impl From<(String, SharedBackendFactory)> for PoolSpec {
    fn from((name, factory): (String, SharedBackendFactory)) -> PoolSpec {
        PoolSpec::replicated(name, 1, factory)
    }
}

/// Coordinator-wide knobs.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorConfig {
    /// Per-pool queue capacity (requests beyond this are shed).
    pub queue_capacity: usize,
    pub policy: BatchPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { queue_capacity: 1024, policy: BatchPolicy::default() }
    }
}

/// Submission failure modes surfaced to clients.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — backpressure; retry later or shed.
    Backpressure,
    /// Coordinator is shutting down.
    Closed,
    /// No backend with that name.
    UnknownBackend,
    /// Admission control: the estimated queue wait alone already
    /// overshoots the request's deadline, so computing the answer would
    /// only waste a worker on a result nobody can use. Rejected on
    /// arrival, nothing enqueued.
    Expired {
        /// The wait estimate that sank the request (for diagnostics).
        estimated_wait: Duration,
    },
}

/// Per-request scheduling inputs carried into the coordinator. The wire
/// layer maps its `Qos` onto this (deadline budget → absolute
/// [`Instant`], `Priority` → rank) so the coordinator stays independent
/// of wire-protocol types.
#[derive(Debug, Clone, Copy)]
pub struct RequestQos {
    /// Absolute completion deadline; `None` = pre-v3 behavior.
    pub deadline: Option<Instant>,
    /// Scheduling rank, lower first (see
    /// [`PRIORITY_NORMAL`](super::request::PRIORITY_NORMAL)).
    pub priority: u8,
}

impl RequestQos {
    /// No deadline, normal priority.
    pub fn none() -> RequestQos {
        RequestQos { deadline: None, priority: PRIORITY_NORMAL }
    }

    pub fn with_deadline(deadline: Instant) -> RequestQos {
        RequestQos { deadline: Some(deadline), priority: PRIORITY_NORMAL }
    }
}

impl Default for RequestQos {
    fn default() -> Self {
        RequestQos::none()
    }
}

/// EDF ordering key: priority rank in the top 8 bits, deadline (µs
/// since the coordinator's epoch) below. Within a priority, earlier
/// deadlines drain first and deadline-free requests sort after every
/// deadline (all sharing one key, so they stay FIFO among themselves).
fn edf_key(req: &InferRequest, epoch: Instant) -> u64 {
    const NO_DEADLINE: u64 = (1 << 56) - 1;
    let d = match req.deadline {
        Some(d) => {
            (d.saturating_duration_since(epoch).as_micros() as u64).min(NO_DEADLINE - 1)
        }
        None => NO_DEADLINE,
    };
    ((req.priority as u64) << 56) | d
}

/// One worker behind a pool: its join handle plus the retire flag its
/// loop polls between batches. Raising the flag (and nudging the
/// queue) makes the worker finish whatever batch it already claimed
/// and then exit without taking more work.
struct WorkerHandle {
    retire: Arc<AtomicBool>,
    handle: JoinHandle<()>,
}

/// Replica membership of one pool. `active` workers drain the queue;
/// `retiring` workers have their flag raised and are joined
/// opportunistically on the next resize (or at shutdown).
#[derive(Default)]
struct PoolWorkers {
    active: Vec<WorkerHandle>,
    retiring: Vec<WorkerHandle>,
}

/// One running pool: the submission queue, the admission-control
/// signals, and the (dynamically sized) worker set draining it. The
/// hot-path signals stay lock-free atomics; only replica membership —
/// touched by [`Coordinator::scale_to`] and shutdown — sits behind a
/// mutex.
struct Pool {
    name: String,
    queue: Arc<BoundedQueue<InferRequest>>,
    /// EWMA of per-request service time in nanoseconds (0 = no
    /// observation yet). Seeded by the calibration forward at replica
    /// startup, then written by workers after every successful batch;
    /// read by admission control. Racy load/store is fine — it is a
    /// smoothed estimate, not an invariant.
    service_ema_ns: Arc<AtomicU64>,
    /// Admissions granted but not yet pushed into the queue. Counted
    /// into the wait estimate so a burst of concurrent submits cannot
    /// all reason against the same (stale) queue depth and over-admit.
    in_flight_admits: AtomicU64,
    /// Active replica count, mirrored from `workers.active.len()` so
    /// the estimator and health snapshots read it without the lock.
    replicas: AtomicUsize,
    /// Retained factory for replicated pools; `None` marks the pool
    /// unscalable (its one-shot factory was consumed at startup).
    shared_factory: Option<SharedBackendFactory>,
    workers: Mutex<PoolWorkers>,
    /// Monotonic replica sequence, so rescales never reuse a thread
    /// name.
    spawn_seq: AtomicUsize,
    /// Pre-built trace track label (`Arc<str>` so the hot path clones
    /// a pointer, not a string).
    track: Arc<str>,
}

/// Spawn one replica worker thread for a pool and block until its
/// backend reports ready (or fails — then the thread is already gone
/// and the error is returned synchronously). Before the ready
/// handshake the worker runs one unmetered calibration forward (if the
/// backend offers a [`Backend::calibration_input`]) and seeds the
/// pool's admission EMA from the measured latency — only from cold
/// (`compare_exchange` from 0), so a mid-traffic rescale never
/// clobbers live observations with a one-shot sample.
#[allow(clippy::too_many_arguments)]
fn spawn_replica(
    name: &str,
    seq: usize,
    factory: BackendFactory,
    queue: &Arc<BoundedQueue<InferRequest>>,
    metrics: &Arc<Metrics>,
    policy: BatchPolicy,
    ema: &Arc<AtomicU64>,
    trace: Option<(Arc<TraceRecorder>, Arc<str>)>,
) -> Result<WorkerHandle> {
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    let retire = Arc::new(AtomicBool::new(false));
    let handle = {
        let queue = queue.clone();
        let metrics = metrics.clone();
        let name = name.to_string();
        let ema = ema.clone();
        let retire = retire.clone();
        std::thread::Builder::new()
            .name(format!("edgemlp-{name}-r{seq}"))
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => b,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                if let Some(sample) = backend.calibration_input() {
                    let t0 = Instant::now();
                    if backend.infer(std::slice::from_ref(&sample)).is_ok() {
                        let ns = (t0.elapsed().as_nanos() as u64).max(1);
                        let _ = ema.compare_exchange(0, ns, Ordering::Relaxed, Ordering::Relaxed);
                    }
                }
                let _ = ready_tx.send(Ok(()));
                worker_loop(
                    &name,
                    backend.as_mut(),
                    &queue,
                    &metrics,
                    policy,
                    &ema,
                    &retire,
                    trace.as_ref(),
                );
            })
            .context("spawn worker")?
    };
    let ready = ready_rx.recv().context("worker handshake lost").and_then(|r| {
        r.with_context(|| format!("backend '{name}' replica {seq} failed to start"))
    });
    match ready {
        Ok(()) => Ok(WorkerHandle { retire, handle }),
        Err(e) => {
            // A failed handshake means the thread already returned (it
            // only errors before entering the worker loop) — reap it
            // before surfacing the error.
            let _ = handle.join();
            Err(e)
        }
    }
}

/// Close every built pool's queue, then join all their workers —
/// the startup-failure cleanup path.
fn teardown(pools: Vec<Pool>) {
    for p in &pools {
        p.queue.close();
    }
    for p in pools {
        let w = p.workers.into_inner().unwrap();
        for h in w.active.into_iter().chain(w.retiring) {
            let _ = h.handle.join();
        }
    }
}

/// RAII token for one granted admission that has not reached its queue
/// yet. While held, the request stays counted in the pool's
/// `in_flight_admits`, so concurrent admissions see each other either
/// there or (after the push completes and the guard drops) in the
/// queue depth — never in neither.
struct AdmitGuard<'a>(&'a AtomicU64);

impl Drop for AdmitGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Running coordinator. Drop or call [`Coordinator::shutdown`] to stop.
pub struct Coordinator {
    pools: Vec<Pool>,
    /// Pool names in submission-index order, duplicated out of `pools`
    /// so [`Coordinator::pool_names`] can hand out a plain slice.
    names: Vec<String>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    /// Rotates the scan start of least-loaded selection so queue-depth
    /// ties do not all land on pool 0.
    tie_break: AtomicUsize,
    queue_capacity: usize,
    /// Batching knobs, retained so replicas spawned by a later
    /// [`Coordinator::scale_to`] run the same policy as startup ones.
    policy: BatchPolicy,
    /// Time origin of the EDF queue keys.
    epoch: Instant,
    /// Request-lifecycle trace sink. `None` = tracing disabled, zero
    /// cost.
    trace: Option<Arc<TraceRecorder>>,
}

impl Coordinator {
    /// Spawn every pool's workers; blocks until each replica's backend
    /// reports ready (or fails). Accepts `(String, BackendFactory)`
    /// pairs (single-replica pools) or explicit [`PoolSpec`]s.
    pub fn start<P: Into<PoolSpec>>(
        pools: Vec<P>,
        config: CoordinatorConfig,
    ) -> Result<Coordinator> {
        Coordinator::start_traced(pools, config, None)
    }

    /// [`Coordinator::start`] with an optional request-lifecycle trace
    /// recorder. When set, the coordinator emits `queue` events
    /// (enqueue / shed / admit-expired instants, a "queued" span per
    /// dequeue) and `worker` events (an "infer" span per batch,
    /// writeback / expired instants per request), all on the pool's
    /// track. Kept out of [`CoordinatorConfig`] so that `Copy` config
    /// struct — and every literal constructing it — stays unchanged.
    pub fn start_traced<P: Into<PoolSpec>>(
        pools: Vec<P>,
        config: CoordinatorConfig,
        tracer: Option<Arc<TraceRecorder>>,
    ) -> Result<Coordinator> {
        config.policy.validate().map_err(|e| anyhow::anyhow!(e))?;
        if pools.is_empty() {
            bail!("need at least one backend pool");
        }
        let metrics = Arc::new(Metrics::new());
        let epoch = Instant::now();
        let mut built: Vec<Pool> = Vec::new();
        let mut names: Vec<String> = Vec::new();
        for spec in pools {
            let spec: PoolSpec = spec.into();
            let name = spec.name;
            if spec.factories.is_empty() {
                teardown(built);
                bail!("pool '{name}' has zero replicas");
            }
            // EDF queue: drains by (priority, deadline); deadline-free
            // traffic shares one key and stays FIFO.
            let queue = Arc::new(BoundedQueue::<InferRequest>::with_key(
                config.queue_capacity,
                move |r| edf_key(r, epoch),
            ));
            let ema = Arc::new(AtomicU64::new(0));
            let track: Arc<str> = Arc::from(name.as_str());
            let mut active: Vec<WorkerHandle> = Vec::new();
            let mut spawn_err = None;
            for (r, factory) in spec.factories.into_iter().enumerate() {
                let trace = tracer.as_ref().map(|t| (t.clone(), track.clone()));
                match spawn_replica(
                    &name,
                    r,
                    factory,
                    &queue,
                    &metrics,
                    config.policy,
                    &ema,
                    trace,
                ) {
                    Ok(h) => active.push(h),
                    Err(e) => {
                        spawn_err = Some(e);
                        break;
                    }
                }
            }
            // Register the (possibly partially spawned) pool before
            // checking for errors: teardown then closes this pool's
            // queue too, so its earlier replicas exit instead of
            // leaking blocked on an open queue.
            let n = active.len();
            built.push(Pool {
                name: name.clone(),
                queue,
                service_ema_ns: ema,
                in_flight_admits: AtomicU64::new(0),
                replicas: AtomicUsize::new(n),
                shared_factory: spec.shared,
                workers: Mutex::new(PoolWorkers { active, retiring: Vec::new() }),
                spawn_seq: AtomicUsize::new(n),
                track,
            });
            names.push(name);
            if let Some(e) = spawn_err {
                teardown(built);
                return Err(e);
            }
        }
        Ok(Coordinator {
            pools: built,
            names,
            metrics,
            next_id: AtomicU64::new(0),
            tie_break: AtomicUsize::new(0),
            queue_capacity: config.queue_capacity,
            policy: config.policy,
            epoch,
            trace: tracer,
        })
    }

    /// Emit a queue-lifecycle instant on pool `pool`'s track, if a
    /// trace recorder is attached and enabled.
    fn trace_instant(&self, pool: usize, name: &'static str, request_id: u64) {
        if let Some(rec) = &self.trace {
            if rec.enabled() {
                if let Some(p) = self.pools.get(pool) {
                    rec.instant("queue", name, Some(p.track.clone()), request_id);
                }
            }
        }
    }

    /// Emit an autoscale lifecycle instant (`scale_up` / `scale_down`)
    /// on pool `pool`'s track.
    pub(crate) fn trace_scale_event(&self, pool: usize, name: &'static str) {
        if let Some(rec) = &self.trace {
            if rec.enabled() {
                if let Some(p) = self.pools.get(pool) {
                    rec.instant("autoscale", name, Some(p.track.clone()), 0);
                }
            }
        }
    }

    /// Whether [`Coordinator::scale_to`] can resize pool `idx` — true
    /// for pools built from a retained [`SharedBackendFactory`].
    pub fn scalable(&self, idx: usize) -> bool {
        self.pools.get(idx).is_some_and(|p| p.shared_factory.is_some())
    }

    /// Resize pool `pool` to `target` active replicas (clamped to at
    /// least 1). Growing spawns workers from the pool's retained
    /// shared factory — pools built from one-shot factories refuse.
    /// Shrinking retires the most recently spawned workers first: each
    /// finishes whatever batch it already claimed and then exits
    /// without taking more work, so scale-down mid-traffic never loses
    /// a response. Retired threads are reaped opportunistically on the
    /// next resize and joined at shutdown. Returns the active replica
    /// count after the change.
    pub fn scale_to(&self, pool: usize, target: usize) -> Result<usize> {
        let p = self
            .pools
            .get(pool)
            .ok_or_else(|| anyhow::anyhow!("no such pool index: {pool}"))?;
        let target = target.max(1);
        let mut w = p.workers.lock().unwrap();
        let mut i = 0;
        while i < w.retiring.len() {
            if w.retiring[i].handle.is_finished() {
                let h = w.retiring.swap_remove(i);
                let _ = h.handle.join();
            } else {
                i += 1;
            }
        }
        while w.active.len() > target {
            let h = w.active.pop().expect("active.len() > target >= 1");
            h.retire.store(true, Ordering::Release);
            w.retiring.push(h);
            p.replicas.store(w.active.len(), Ordering::Relaxed);
            // Wake parked consumers so an idle retired worker observes
            // its flag now instead of at the next enqueue.
            p.queue.nudge();
        }
        while w.active.len() < target {
            let Some(shared) = &p.shared_factory else {
                bail!("pool '{}' is not scalable (built from a one-shot factory)", p.name);
            };
            let f = shared.clone();
            let factory: BackendFactory = Box::new(move || f());
            let seq = p.spawn_seq.fetch_add(1, Ordering::Relaxed);
            let trace = self.trace.as_ref().map(|t| (t.clone(), p.track.clone()));
            let h = spawn_replica(
                &p.name,
                seq,
                factory,
                &p.queue,
                &self.metrics,
                self.policy,
                &p.service_ema_ns,
                trace,
            )?;
            w.active.push(h);
            p.replicas.store(w.active.len(), Ordering::Relaxed);
        }
        Ok(w.active.len())
    }

    /// Pool names, in submission-index order.
    pub fn pool_names(&self) -> &[String] {
        &self.names
    }

    /// Back-compat alias for [`Coordinator::pool_names`].
    pub fn backend_names(&self) -> &[String] {
        &self.names
    }

    pub fn backend_index(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn num_pools(&self) -> usize {
        self.pools.len()
    }

    /// Active worker replicas behind pool `idx` (excludes retiring
    /// workers still finishing their last batch).
    pub fn pool_replicas(&self, idx: usize) -> Option<usize> {
        self.pools.get(idx).map(|p| p.replicas.load(Ordering::Relaxed))
    }

    /// Requests currently parked in pool `idx`'s queue.
    pub fn queue_depth(&self, idx: usize) -> Option<usize> {
        self.pools.get(idx).map(|p| p.queue.len())
    }

    /// The least-loaded pool among `candidates` (queue depth; ties
    /// broken by a rotating scan start so equally idle pools share
    /// traffic). `None` if no candidate is a valid pool index.
    pub fn least_loaded_of(&self, candidates: &[usize]) -> Option<usize> {
        self.least_loaded_scan(candidates.len(), |k| candidates[k])
    }

    /// Shared scan: `index` maps a rotated scan position to a pool
    /// index. Allocation-free, so the per-request [`Coordinator::submit`]
    /// path can scan all pools without building an index `Vec`.
    fn least_loaded_scan(
        &self,
        n: usize,
        index: impl Fn(usize) -> usize,
    ) -> Option<usize> {
        if n == 0 {
            return None;
        }
        let start = self.tie_break.fetch_add(1, Ordering::Relaxed);
        let mut best: Option<(usize, usize)> = None; // (idx, depth)
        for k in 0..n {
            let idx = index((start + k) % n);
            let Some(depth) = self.queue_depth(idx) else { continue };
            if best.map(|(_, d)| depth < d).unwrap_or(true) {
                best = Some((idx, depth));
            }
        }
        best.map(|(idx, _)| idx)
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Per-pool queue capacity (every pool shares one configured value).
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    /// Admission-control wait estimate for pool `pool`: (queued
    /// requests + admissions still in flight toward the queue) ×
    /// smoothed per-request service time ÷ replicas. Zero until the
    /// pool has an estimate — real backends seed it from a calibration
    /// forward at startup; estimator-less pools (test doubles) admit
    /// optimistically rather than shedding blind.
    pub fn estimated_wait(&self, pool: usize) -> Duration {
        let Some(p) = self.pools.get(pool) else { return Duration::ZERO };
        let depth = p.queue.len() as u64 + p.in_flight_admits.load(Ordering::Relaxed);
        let ema = p.service_ema_ns.load(Ordering::Relaxed);
        let replicas = p.replicas.load(Ordering::Relaxed).max(1) as u64;
        Duration::from_nanos(depth.saturating_mul(ema) / replicas)
    }

    fn make_request(
        &self,
        payload: Vec<f32>,
        qos: RequestQos,
        notify: Option<CompletionNotify>,
    ) -> (InferRequest, Receiver<InferResult>) {
        let (tx, rx) = channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            payload,
            enqueued_at: Instant::now(),
            deadline: qos.deadline,
            priority: qos.priority,
            respond_to: Responder::with_notify(tx, notify),
        };
        (req, rx)
    }

    /// Reject-on-arrival check: with a deadline set, a completion
    /// estimate (queue wait + own service) that overshoots it means the
    /// answer would be computed for nobody. Err = shed now, nothing
    /// enqueued. On success returns an [`AdmitGuard`] the caller must
    /// hold across the queue push: it keeps this admission counted in
    /// the estimate's `pending` term so a concurrent burst cannot all
    /// admit against the same stale queue depth. (The estimate can
    /// over-count — a guard whose push ultimately sheds still inflated
    /// concurrent estimates — which errs toward shedding, never toward
    /// admitting work that cannot finish.)
    fn admit(&self, pool: usize, qos: &RequestQos) -> Result<Option<AdmitGuard<'_>>, SubmitError> {
        let p = self.pools.get(pool).ok_or(SubmitError::UnknownBackend)?;
        let Some(deadline) = qos.deadline else { return Ok(None) };
        // Pre-increment value: earlier concurrent admissions are in
        // `pending` (guard still held) or already in the queue depth —
        // our own slot is not double-counted.
        let pending = p.in_flight_admits.fetch_add(1, Ordering::AcqRel);
        let guard = AdmitGuard(&p.in_flight_admits);
        let ema = p.service_ema_ns.load(Ordering::Relaxed);
        let replicas = p.replicas.load(Ordering::Relaxed).max(1) as u64;
        let depth = p.queue.len() as u64 + pending;
        // Queue wait plus the request's own service time: under
        // sustained overload the queue pins at the admission boundary,
        // and without the service term every admitted request would
        // finish exactly AT its deadline — a coin flip instead of an
        // SLO.
        let estimated_wait =
            Duration::from_nanos((depth.saturating_mul(ema) / replicas).saturating_add(ema));
        if Instant::now() + estimated_wait > deadline {
            drop(guard);
            self.metrics.record_expired(&p.name);
            // Rejected before an id is allocated — req 0 on the trace.
            self.trace_instant(pool, "admit_expired", 0);
            return Err(SubmitError::Expired { estimated_wait });
        }
        Ok(Some(guard))
    }

    /// Blocking submit to a specific pool.
    pub fn submit_to(
        &self,
        pool: usize,
        payload: Vec<f32>,
    ) -> Result<Receiver<InferResult>, SubmitError> {
        self.submit_to_qos(pool, payload, RequestQos::none())
    }

    /// Blocking submit with scheduling inputs; deadline-infeasible
    /// requests are rejected at admission with [`SubmitError::Expired`].
    pub fn submit_to_qos(
        &self,
        pool: usize,
        payload: Vec<f32>,
        qos: RequestQos,
    ) -> Result<Receiver<InferResult>, SubmitError> {
        let p = self.pools.get(pool).ok_or(SubmitError::UnknownBackend)?;
        // Held across the push: see `admit`.
        let _admit = self.admit(pool, &qos)?;
        let (req, rx) = self.make_request(payload, qos, None);
        let id = req.id;
        match p.queue.push(req) {
            Ok(()) => {
                self.trace_instant(pool, "enqueue", id);
                Ok(rx)
            }
            Err(QueueError::Closed) => Err(SubmitError::Closed),
            Err(QueueError::Full) => unreachable!("push blocks on full"),
        }
    }

    /// Non-blocking submit — `Backpressure` tells the edge client to
    /// shed or retry.
    pub fn try_submit_to(
        &self,
        pool: usize,
        payload: Vec<f32>,
    ) -> Result<Receiver<InferResult>, SubmitError> {
        self.try_submit_to_qos(pool, payload, RequestQos::none())
    }

    /// Non-blocking submit with scheduling inputs: admission control
    /// first (deadline-infeasible → [`SubmitError::Expired`]), then a
    /// full queue sheds with `Backpressure`.
    pub fn try_submit_to_qos(
        &self,
        pool: usize,
        payload: Vec<f32>,
        qos: RequestQos,
    ) -> Result<Receiver<InferResult>, SubmitError> {
        self.try_submit_to_qos_notify(pool, payload, qos, None)
    }

    /// [`Coordinator::try_submit_to_qos`] with a completion hook: the
    /// worker fires `notify` right after pushing the result into the
    /// returned channel (and on teardown if the request is dropped
    /// unanswered). This is the event loop's handoff — one readiness
    /// nudge per completion instead of a blocked thread per in-flight
    /// request.
    pub fn try_submit_to_qos_notify(
        &self,
        pool: usize,
        payload: Vec<f32>,
        qos: RequestQos,
        notify: Option<CompletionNotify>,
    ) -> Result<Receiver<InferResult>, SubmitError> {
        let p = self.pools.get(pool).ok_or(SubmitError::UnknownBackend)?;
        // Held across the push: see `admit`.
        let _admit = self.admit(pool, &qos)?;
        let (req, rx) = self.make_request(payload, qos, notify);
        let id = req.id;
        match p.queue.try_push(req) {
            Ok(()) => {
                self.trace_instant(pool, "enqueue", id);
                Ok(rx)
            }
            Err(QueueError::Closed) => Err(SubmitError::Closed),
            Err(QueueError::Full) => {
                self.metrics.record_shed(&p.name);
                self.trace_instant(pool, "shed", id);
                Err(SubmitError::Backpressure)
            }
        }
    }

    /// Least-loaded submit across all pools: the request goes to the
    /// pool with the shallowest queue, so a saturated pool stops
    /// receiving new work while a drained one soaks it up.
    pub fn submit(&self, payload: Vec<f32>) -> Result<Receiver<InferResult>, SubmitError> {
        self.submit_qos(payload, RequestQos::none())
    }

    /// Least-loaded submit with scheduling inputs.
    pub fn submit_qos(
        &self,
        payload: Vec<f32>,
        qos: RequestQos,
    ) -> Result<Receiver<InferResult>, SubmitError> {
        let idx = self
            .least_loaded_scan(self.pools.len(), |k| k)
            .ok_or(SubmitError::UnknownBackend)?;
        self.submit_to_qos(idx, payload, qos)
    }

    /// Close the submission queues without consuming the coordinator:
    /// later submits fail with [`SubmitError::Closed`] while workers
    /// drain everything already queued and then exit. Needed by owners
    /// that hold the coordinator behind an `Arc` (the TCP server) and
    /// therefore cannot call [`Coordinator::shutdown`]; joining happens
    /// in `Drop`.
    pub fn stop(&self) {
        for p in &self.pools {
            p.queue.close();
        }
    }

    /// Close every queue and join every worker — active and retiring.
    fn join_all(&mut self) {
        for p in &self.pools {
            p.queue.close();
        }
        for p in &self.pools {
            let mut w = p.workers.lock().unwrap();
            for h in w.active.drain(..) {
                let _ = h.handle.join();
            }
            for h in w.retiring.drain(..) {
                let _ = h.handle.join();
            }
        }
    }

    /// Close queues and join workers (drains in-flight requests).
    pub fn shutdown(mut self) {
        self.join_all();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.join_all();
    }
}

/// Body of a pool worker thread. `name` is the pool label — replicas
/// share it, so metrics aggregate per pool. `retire` is this worker's
/// scale-down flag: once raised, the next `pop_batch_cancel` returns
/// empty instead of claiming more work (a batch already claimed is
/// finished in full first) and the loop exits.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    name: &str,
    backend: &mut dyn Backend,
    queue: &BoundedQueue<InferRequest>,
    metrics: &Metrics,
    policy: BatchPolicy,
    service_ema_ns: &AtomicU64,
    retire: &AtomicBool,
    trace: Option<&(Arc<TraceRecorder>, Arc<str>)>,
) {
    let max_batch = policy.max_batch.min(backend.max_batch()).max(1);
    let trace = trace.filter(|t| t.0.capacity() > 0);
    loop {
        let mut batch = queue.pop_batch_cancel(max_batch, policy.max_wait, retire);
        if batch.is_empty() {
            return; // closed + drained, or retired by a scale-down
        }
        // One "queued" span per dequeued request: enqueue → now is the
        // time it sat parked (the batcher wait window included).
        if let Some((rec, track)) = trace {
            if rec.enabled() {
                for req in &batch {
                    rec.span(
                        "queue",
                        "queued",
                        Some(track.clone()),
                        rec.instant_us(req.enqueued_at),
                        req.id,
                    );
                }
            }
        }
        // Second expiry gate (after admission): requests whose deadline
        // passed while queued are answered `Expired` without touching
        // the backend — running them would starve still-feasible work.
        let now = Instant::now();
        let mut expired = 0u64;
        batch.retain(|req| {
            if req.expired_at(now) {
                expired += 1;
                if let Some((rec, track)) = trace {
                    if rec.enabled() {
                        rec.instant("worker", "expired", Some(track.clone()), req.id);
                    }
                }
                let _ = req
                    .respond_to
                    .send(Err(InferError::expired(format!(
                        "backend '{name}': deadline passed after {:.1} ms in queue",
                        now.duration_since(req.enqueued_at).as_secs_f64() * 1e3
                    ))));
                false
            } else {
                true
            }
        });
        for _ in 0..expired {
            metrics.record_expired(name);
        }
        if batch.is_empty() {
            continue;
        }
        let inputs: Vec<Vec<f32>> = batch.iter().map(|r| r.payload.clone()).collect();
        let first_id = batch.first().map(|r| r.id).unwrap_or(0);
        let infer_start = Instant::now();
        // Fault containment: a backend that panics mid-batch fails only
        // this batch's requests (they get error responses below) — the
        // worker survives, keeps its queue position, and the pool keeps
        // serving. Pinned by `rust/tests/fault_injection.rs`.
        let result = match std::panic::catch_unwind(AssertUnwindSafe(|| backend.infer(&inputs))) {
            Ok(r) => r,
            Err(payload) => Err(anyhow::anyhow!(
                "backend panicked mid-batch: {}",
                panic_message(payload.as_ref())
            )),
        };
        // One "infer" span per batch, labeled by the first request's id
        // (the batch's other members are visible via their writebacks).
        if let Some((rec, track)) = trace {
            if rec.enabled() {
                rec.span(
                    "worker",
                    "infer",
                    Some(track.clone()),
                    rec.instant_us(infer_start),
                    first_id,
                );
            }
        }
        match result {
            Ok((outputs, cycle_stats)) => {
                debug_assert_eq!(outputs.len(), batch.len());
                let now = Instant::now();
                // Feed the admission estimator: smoothed per-request
                // service time (EWMA, alpha = 1/8). First observation
                // seeds the average directly.
                let per_req_ns = (now.duration_since(infer_start).as_nanos() as u64)
                    / batch.len().max(1) as u64;
                let old = service_ema_ns.load(Ordering::Relaxed);
                let ema = if old == 0 { per_req_ns } else { (old * 7 + per_req_ns) / 8 };
                service_ema_ns.store(ema.max(1), Ordering::Relaxed);
                let latencies: Vec<f64> = batch
                    .iter()
                    .map(|r| now.duration_since(r.enqueued_at).as_secs_f64())
                    .collect();
                metrics.record_batch(name, batch.len(), &latencies, cycle_stats.as_ref());
                for ((req, output), &latency_s) in
                    batch.into_iter().zip(outputs).zip(&latencies)
                {
                    if let Some((rec, track)) = trace {
                        if rec.enabled() {
                            rec.instant("worker", "writeback", Some(track.clone()), req.id);
                        }
                    }
                    let _ = req.respond_to.send(Ok(InferResponse {
                        id: req.id,
                        output,
                        latency_s,
                        backend: name.to_string(),
                        batch_size: inputs.len(),
                    }));
                }
            }
            Err(e) => {
                metrics.record_error(name);
                let err = InferError::backend(format!("backend '{name}': {e:#}"));
                for req in batch {
                    let _ = req.respond_to.send(Err(err.clone()));
                }
            }
        }
        // Refresh stage counters on BOTH outcomes: a failing pipeline's
        // `failed`/occupancy lines are most useful exactly when batches
        // are failing.
        if let Some(stages) = backend.stage_stats() {
            metrics.record_stage_stats(name, stages);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::FnBackend;
    use std::time::Duration;

    fn echo_factory(name: &str) -> (String, BackendFactory) {
        let n = name.to_string();
        (
            n.clone(),
            Box::new(move || {
                Ok(Box::new(FnBackend::new(n, 16, |inputs: &[Vec<f32>]| {
                    Ok(inputs.iter().map(|v| v.iter().map(|x| x * 2.0).collect()).collect())
                })) as Box<dyn Backend>)
            }),
        )
    }

    /// Shared factory for a replicated echo pool; counts constructions.
    fn shared_echo(
        name: &'static str,
        built: Arc<AtomicUsize>,
    ) -> SharedBackendFactory {
        Arc::new(move || {
            built.fetch_add(1, Ordering::SeqCst);
            Ok(Box::new(FnBackend::new(name, 16, |inputs: &[Vec<f32>]| {
                Ok(inputs.iter().map(|v| v.iter().map(|x| x * 2.0).collect()).collect())
            })) as Box<dyn Backend>)
        })
    }

    #[test]
    fn serves_requests_end_to_end() {
        let coord =
            Coordinator::start(vec![echo_factory("echo")], CoordinatorConfig::default())
                .unwrap();
        let rx = coord.submit(vec![1.0, 2.0]).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(resp.output, vec![2.0, 4.0]);
        assert_eq!(resp.backend, "echo");
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let coord = Coordinator::start(
            vec![echo_factory("echo")],
            CoordinatorConfig {
                queue_capacity: 512,
                policy: BatchPolicy::windowed(8, Duration::from_millis(1)),
            },
        )
        .unwrap();
        let receivers: Vec<_> =
            (0..200).map(|i| coord.submit(vec![i as f32]).unwrap()).collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.output, vec![2.0 * i as f32]);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.backends["echo"].requests, 200);
        coord.shutdown();
    }

    #[test]
    fn replicated_pool_builds_one_backend_per_replica() {
        let built = Arc::new(AtomicUsize::new(0));
        let coord = Coordinator::start(
            vec![PoolSpec::replicated("echo", 4, shared_echo("echo", built.clone()))],
            CoordinatorConfig::default(),
        )
        .unwrap();
        assert_eq!(built.load(Ordering::SeqCst), 4);
        assert_eq!(coord.num_pools(), 1);
        assert_eq!(coord.pool_replicas(0), Some(4));
        // All replicas answer from the shared queue.
        let receivers: Vec<_> =
            (0..64).map(|i| coord.submit(vec![i as f32]).unwrap()).collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.output, vec![2.0 * i as f32]);
        }
        assert_eq!(coord.metrics().snapshot().backends["echo"].requests, 64);
        coord.shutdown();
    }

    #[test]
    fn replicas_serve_concurrently() {
        // Each backend instance sleeps 60 ms per batch. Four requests
        // through 4 replicas must overlap: well under the 240 ms a
        // single worker would need (generous margin for CI jitter).
        let slow: SharedBackendFactory = Arc::new(|| {
            Ok(Box::new(FnBackend::new("slow", 1, |inputs: &[Vec<f32>]| {
                std::thread::sleep(Duration::from_millis(60));
                Ok(inputs.to_vec())
            })) as Box<dyn Backend>)
        });
        let coord = Coordinator::start(
            vec![PoolSpec::replicated("slow", 4, slow)],
            CoordinatorConfig { queue_capacity: 16, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        let t0 = Instant::now();
        let receivers: Vec<_> = (0..4).map(|i| coord.submit(vec![i as f32]).unwrap()).collect();
        for rx in receivers {
            rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        }
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_millis(200),
            "4 replicas took {elapsed:?} for 4 overlapping 60 ms requests"
        );
        coord.shutdown();
    }

    #[test]
    fn submit_routes_to_least_loaded_pool() {
        // Pool "clogged" has a backend wedged on a long sleep with
        // requests parked behind it; pool "idle" is empty. Every
        // depth-aware submit must land on "idle" — the saturated pool
        // stops receiving new requests while the drained one soaks
        // them up.
        let wedge: (String, BackendFactory) = (
            "clogged".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("clogged", 1, |inputs: &[Vec<f32>]| {
                    std::thread::sleep(Duration::from_millis(150));
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(
            vec![wedge, echo_factory("idle")],
            CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        // Park 6 requests on the clogged pool (1 in flight + 5 queued).
        let parked: Vec<_> =
            (0..6).map(|_| coord.submit_to(0, vec![0.0]).unwrap()).collect();
        // Give the worker a moment to pull the first one off the queue.
        std::thread::sleep(Duration::from_millis(20));
        let depth_before = coord.queue_depth(0).unwrap();
        assert!(depth_before >= 4, "clogged queue depth {depth_before}");
        // Depth-aware submits all route to the idle pool.
        for i in 0..10 {
            let rx = coord.submit(vec![i as f32]).unwrap();
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.backend, "idle", "request {i} routed to the saturated pool");
        }
        assert!(
            coord.queue_depth(0).unwrap() <= depth_before,
            "saturated pool kept receiving new requests"
        );
        for rx in parked {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        coord.shutdown();
    }

    #[test]
    fn least_loaded_breaks_ties_fairly() {
        let coord = Coordinator::start(
            vec![echo_factory("a"), echo_factory("b")],
            CoordinatorConfig::default(),
        )
        .unwrap();
        // Both queues empty: the rotating scan start must not pin every
        // pick to pool 0.
        let picks: Vec<usize> =
            (0..10).map(|_| coord.least_loaded_of(&[0, 1]).unwrap()).collect();
        assert!(picks.contains(&0) && picks.contains(&1), "ties all landed on {picks:?}");
        coord.shutdown();
    }

    #[test]
    fn failing_backend_start_is_synchronous_error() {
        let failing: (String, BackendFactory) = (
            "bad".into(),
            Box::new(|| anyhow::bail!("no device")),
        );
        match Coordinator::start(vec![failing], CoordinatorConfig::default()) {
            Ok(_) => panic!("expected startup failure"),
            Err(e) => assert!(format!("{e:#}").contains("no device")),
        }
    }

    #[test]
    fn failing_replica_start_cleans_up_earlier_pools() {
        // Pool 0 starts fine; pool 1's factory fails. start() must
        // error out and pool 0's worker must exit (not leak blocked on
        // its queue) — verified by the join inside the failure path
        // completing, i.e. this test not hanging.
        let flaky: (String, BackendFactory) = (
            "flaky".into(),
            Box::new(|| anyhow::bail!("replica died")),
        );
        let err = Coordinator::start(
            vec![echo_factory("ok"), flaky],
            CoordinatorConfig::default(),
        )
        .err()
        .expect("expected startup failure");
        assert!(format!("{err:#}").contains("replica died"));
    }

    #[test]
    fn failing_second_replica_does_not_deadlock_startup() {
        // Replica 0 starts; replica 1's factory fails. The pool's queue
        // is not yet registered at that point — startup must still
        // close it so replica 0 exits and the cleanup join returns.
        let calls = Arc::new(AtomicUsize::new(0));
        let factory: SharedBackendFactory = {
            let calls = calls.clone();
            Arc::new(move || {
                if calls.fetch_add(1, Ordering::SeqCst) == 0 {
                    Ok(Box::new(FnBackend::new("ok", 4, |inputs: &[Vec<f32>]| {
                        Ok(inputs.to_vec())
                    })) as Box<dyn Backend>)
                } else {
                    anyhow::bail!("second replica died")
                }
            })
        };
        let err = Coordinator::start(
            vec![PoolSpec::replicated("pool", 2, factory)],
            CoordinatorConfig::default(),
        )
        .err()
        .expect("expected startup failure");
        assert!(format!("{err:#}").contains("second replica died"));
        assert_eq!(calls.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn backend_error_propagates_to_clients() {
        let flaky: (String, BackendFactory) = (
            "flaky".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("flaky", 8, |_inputs: &[Vec<f32>]| {
                    anyhow::bail!("kaboom")
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(vec![flaky], CoordinatorConfig::default()).unwrap();
        let rx = coord.submit(vec![1.0]).unwrap();
        let result = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = result.unwrap_err();
        assert_eq!(err.kind, crate::coordinator::request::FailureKind::Backend);
        assert!(err.message.contains("kaboom"));
        assert_eq!(coord.metrics().snapshot().backends["flaky"].errors, 1);
        coord.shutdown();
    }

    #[test]
    fn panicking_backend_fails_batch_but_worker_survives() {
        // Inputs with a negative marker detonate the backend; the
        // requests of that batch get error responses, the worker thread
        // survives, and later requests are served normally.
        let bomb: (String, BackendFactory) = (
            "bomb".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("bomb", 8, |inputs: &[Vec<f32>]| {
                    if inputs.iter().any(|x| x[0] < 0.0) {
                        panic!("injected backend fault");
                    }
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(
            vec![bomb],
            CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        let rx = coord.submit(vec![1.0]).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap().output, vec![1.0]);
        // Poisoned batch: an error response, not a hang or a lost reply.
        let rx = coord.submit(vec![-1.0]).unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert!(err.message.contains("panicked"), "{err}");
        assert!(err.message.contains("injected backend fault"), "{err}");
        // The single worker survived the panic and keeps serving.
        for i in 0..10 {
            let rx = coord.submit(vec![i as f32]).unwrap();
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.output, vec![i as f32]);
        }
        assert_eq!(coord.metrics().snapshot().backends["bomb"].errors, 1);
        coord.shutdown(); // joins cleanly — the worker is still alive
    }

    #[test]
    fn backpressure_on_tiny_queue() {
        // A backend that blocks forever would hang shutdown; instead use
        // a slow backend and a capacity-1 queue.
        let slow: (String, BackendFactory) = (
            "slow".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("slow", 1, |inputs: &[Vec<f32>]| {
                    std::thread::sleep(Duration::from_millis(50));
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(
            vec![slow],
            CoordinatorConfig { queue_capacity: 1, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        // Fill: one in flight + one queued; the third must shed.
        let _a = coord.try_submit_to(0, vec![1.0]).unwrap();
        let mut shed = false;
        for _ in 0..50 {
            match coord.try_submit_to(0, vec![2.0]) {
                Err(SubmitError::Backpressure) => {
                    shed = true;
                    break;
                }
                Ok(_) => continue,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(shed, "expected backpressure on capacity-1 queue");
        assert!(coord.metrics().snapshot().rejected >= 1);
        coord.shutdown();
    }

    #[test]
    fn try_submit_full_on_saturated_queue() {
        // A capacity-1 queue behind a backend that never finishes its
        // first batch within the test window: once one request is in
        // flight and one is parked in the queue, try_submit must shed.
        let slow: (String, BackendFactory) = (
            "slow".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("slow", 1, |inputs: &[Vec<f32>]| {
                    std::thread::sleep(Duration::from_millis(200));
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(
            vec![slow],
            CoordinatorConfig { queue_capacity: 1, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        let _a = coord.try_submit_to(0, vec![1.0]).unwrap();
        let mut saw_full = false;
        let mut held = Vec::new();
        for _ in 0..50 {
            match coord.try_submit_to(0, vec![2.0]) {
                Ok(rx) => held.push(rx),
                Err(SubmitError::Backpressure) => {
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(saw_full, "saturated queue never reported Full/Backpressure");
        assert!(coord.metrics().snapshot().rejected >= 1);
        coord.shutdown();
    }

    #[test]
    fn submit_after_stop_returns_closed() {
        let coord =
            Coordinator::start(vec![echo_factory("echo")], CoordinatorConfig::default())
                .unwrap();
        coord.stop();
        assert!(matches!(coord.submit(vec![1.0]), Err(SubmitError::Closed)));
        assert!(matches!(coord.try_submit_to(0, vec![1.0]), Err(SubmitError::Closed)));
        coord.shutdown();
    }

    #[test]
    fn stop_drains_in_flight_work() {
        // Queue a pile of requests against a deliberately slow backend,
        // close the queues immediately, and verify every queued request
        // still gets an answer (graceful drain, not drop).
        let slow: (String, BackendFactory) = (
            "slow".into(),
            Box::new(|| {
                Ok(Box::new(FnBackend::new("slow", 4, |inputs: &[Vec<f32>]| {
                    std::thread::sleep(Duration::from_millis(2));
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        );
        let coord = Coordinator::start(
            vec![slow],
            CoordinatorConfig {
                queue_capacity: 64,
                policy: BatchPolicy::windowed(4, Duration::from_millis(1)),
            },
        )
        .unwrap();
        let receivers: Vec<_> =
            (0..20).map(|i| coord.submit(vec![i as f32]).unwrap()).collect();
        coord.stop();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.output, vec![i as f32], "request {i} lost in drain");
        }
        coord.shutdown();
    }

    #[test]
    fn routes_by_backend_index() {
        let coord = Coordinator::start(
            vec![echo_factory("a"), echo_factory("b")],
            CoordinatorConfig::default(),
        )
        .unwrap();
        assert_eq!(coord.backend_index("b"), Some(1));
        let rx = coord.submit_to(1, vec![3.0]).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().backend, "b");
        coord.shutdown();
    }

    /// A pool whose single worker sleeps `ms` per request.
    fn sleepy_factory(name: &str, ms: u64) -> (String, BackendFactory) {
        let n = name.to_string();
        (
            n.clone(),
            Box::new(move || {
                Ok(Box::new(FnBackend::new(n, 1, move |inputs: &[Vec<f32>]| {
                    std::thread::sleep(Duration::from_millis(ms));
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        )
    }

    #[test]
    fn admission_rejects_infeasible_deadline_on_arrival() {
        let coord = Coordinator::start(
            vec![sleepy_factory("slow", 40)],
            CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        // Warm the service-time estimator with a few real requests.
        for _ in 0..3 {
            coord.submit_to(0, vec![1.0]).unwrap().recv_timeout(Duration::from_secs(5))
                .unwrap()
                .unwrap();
        }
        assert!(coord.estimated_wait(0).is_zero(), "empty queue must estimate zero wait");
        // Park a backlog so the wait estimate is deep (~10 × 40 ms),
        // then offer a 1 ms deadline: reject at admission, nothing
        // enqueued.
        let parked: Vec<_> =
            (0..10).map(|_| coord.submit_to(0, vec![0.0]).unwrap()).collect();
        let depth_before = coord.queue_depth(0).unwrap();
        let qos = RequestQos::with_deadline(Instant::now() + Duration::from_millis(1));
        match coord.try_submit_to_qos(0, vec![9.0], qos) {
            Err(SubmitError::Expired { estimated_wait }) => {
                assert!(estimated_wait > Duration::from_millis(1), "wait {estimated_wait:?}");
            }
            other => panic!("expected Expired, got {other:?}"),
        }
        // The worker drains concurrently, so depth can only have
        // shrunk; growth would mean the rejected request was enqueued.
        assert!(coord.queue_depth(0).unwrap() <= depth_before, "rejected request enqueued");
        assert_eq!(coord.metrics().snapshot().expired, 1);
        // A feasible deadline on the same backlog is still admitted.
        let qos = RequestQos::with_deadline(Instant::now() + Duration::from_secs(30));
        let rx = coord.try_submit_to_qos(0, vec![2.0], qos).unwrap();
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap().output,
            vec![2.0]
        );
        for rx in parked {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        coord.shutdown();
    }

    #[test]
    fn queued_request_expiring_in_place_is_answered_expired() {
        // The estimator is cold (EMA = 0) so admission is optimistic and
        // lets a 30 ms deadline through — but the request sits behind a
        // 120 ms batch and must come back `Expired`, never silently
        // dropped and never run.
        let coord = Coordinator::start(
            vec![sleepy_factory("slow", 120)],
            CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        let _wedge = coord.submit_to(0, vec![0.0]).unwrap();
        std::thread::sleep(Duration::from_millis(20)); // worker picks up the wedge
        let qos = RequestQos::with_deadline(Instant::now() + Duration::from_millis(30));
        let rx = coord.submit_to_qos(0, vec![1.0], qos).unwrap();
        let err = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap_err();
        assert_eq!(err.kind, crate::coordinator::request::FailureKind::Expired);
        assert!(err.message.contains("deadline passed"), "{err}");
        assert!(coord.metrics().snapshot().expired >= 1);
        coord.shutdown();
    }

    /// Single worker that sleeps `ms` per batch and appends every
    /// payload marker it actually serves, in service order.
    fn recording_factory(
        name: &str,
        ms: u64,
        served: Arc<std::sync::Mutex<Vec<f32>>>,
    ) -> (String, BackendFactory) {
        let n = name.to_string();
        (
            n.clone(),
            Box::new(move || {
                Ok(Box::new(FnBackend::new(n, 1, move |inputs: &[Vec<f32>]| {
                    std::thread::sleep(Duration::from_millis(ms));
                    served.lock().unwrap().extend(inputs.iter().map(|v| v[0]));
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            }),
        )
    }

    #[test]
    fn edf_serves_earliest_deadline_first() {
        // Wedge the single worker, then enqueue deadlines out of
        // arrival order. The EDF queue must drain earliest-first, with
        // the deadline-free request last — asserted on the order the
        // backend actually served, not on recv timing.
        let served = Arc::new(std::sync::Mutex::new(Vec::new()));
        let coord = Coordinator::start(
            vec![recording_factory("slow", 60, served.clone())],
            CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        let wedge = coord.submit_to(0, vec![0.0]).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        let now = Instant::now();
        let mut pending = vec![
            coord
                .submit_to_qos(
                    0,
                    vec![3.0],
                    RequestQos::with_deadline(now + Duration::from_secs(30)),
                )
                .unwrap(),
            coord.submit_to(0, vec![4.0]).unwrap(), // deadline-free
            coord
                .submit_to_qos(
                    0,
                    vec![1.0],
                    RequestQos::with_deadline(now + Duration::from_secs(10)),
                )
                .unwrap(),
            coord
                .submit_to_qos(
                    0,
                    vec![2.0],
                    RequestQos::with_deadline(now + Duration::from_secs(20)),
                )
                .unwrap(),
        ];
        wedge.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        for rx in pending.drain(..) {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        assert_eq!(*served.lock().unwrap(), vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        coord.shutdown();
    }

    /// Backend that advertises a calibration input; every forward —
    /// the startup calibration pass included — sleeps `ms`.
    struct CalibratedSleeper {
        ms: u64,
    }

    impl Backend for CalibratedSleeper {
        fn name(&self) -> &str {
            "cal"
        }

        fn max_batch(&self) -> usize {
            1
        }

        fn infer(
            &mut self,
            inputs: &[Vec<f32>],
        ) -> Result<(Vec<Vec<f32>>, Option<crate::fpga::stats::CycleStats>)> {
            std::thread::sleep(Duration::from_millis(self.ms));
            Ok((inputs.to_vec(), None))
        }

        fn calibration_input(&self) -> Option<Vec<f32>> {
            Some(vec![0.0])
        }
    }

    #[test]
    fn calibration_seeds_estimator_to_shed_cold_burst() {
        // The backend takes ~60 ms per forward and offers a calibration
        // input, so startup seeds the service estimator before the pool
        // sees traffic: the very first deadline-checked request with a
        // 5 ms budget is rejected on arrival instead of admitted cold
        // and expired at dequeue 60 ms later.
        let factory: SharedBackendFactory =
            Arc::new(|| Ok(Box::new(CalibratedSleeper { ms: 60 }) as Box<dyn Backend>));
        let coord = Coordinator::start(
            vec![PoolSpec::replicated("cal", 1, factory)],
            CoordinatorConfig { queue_capacity: 16, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        // The calibration forward is unmetered — no served requests yet.
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.backends.get("cal").map(|b| b.requests).unwrap_or(0), 0);
        let qos = RequestQos::with_deadline(Instant::now() + Duration::from_millis(5));
        match coord.try_submit_to_qos(0, vec![1.0], qos) {
            Err(SubmitError::Expired { estimated_wait }) => {
                assert!(estimated_wait >= Duration::from_millis(5), "wait {estimated_wait:?}");
            }
            other => panic!("cold-start burst was admitted: {other:?}"),
        }
        assert_eq!(coord.metrics().snapshot().expired, 1);
        // A feasible budget on the same fresh pool is still served.
        let qos = RequestQos::with_deadline(Instant::now() + Duration::from_secs(30));
        let rx = coord.try_submit_to_qos(0, vec![2.0], qos).unwrap();
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap().output,
            vec![2.0]
        );
        coord.shutdown();
    }

    #[test]
    fn concurrent_admissions_share_one_wait_estimate() {
        // 32 threads race tight-deadline submits against a single
        // 40 ms/request worker. Each admission stays counted against
        // the estimate while its push is in flight, so the burst cannot
        // all reason against the same empty queue: only the handful
        // that fit the 400 ms budget are admitted, the rest shed on
        // arrival (instead of all 32 admitted and most expiring in
        // place).
        let factory: SharedBackendFactory =
            Arc::new(|| Ok(Box::new(CalibratedSleeper { ms: 40 }) as Box<dyn Backend>));
        let coord = Arc::new(
            Coordinator::start(
                vec![PoolSpec::replicated("cal", 1, factory)],
                CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
            )
            .unwrap(),
        );
        let deadline = Instant::now() + Duration::from_millis(400);
        let admitted = Arc::new(std::sync::Mutex::new(Vec::new()));
        let shed = Arc::new(AtomicUsize::new(0));
        let threads: Vec<_> = (0..32)
            .map(|i| {
                let coord = coord.clone();
                let admitted = admitted.clone();
                let shed = shed.clone();
                std::thread::spawn(move || {
                    let qos = RequestQos::with_deadline(deadline);
                    match coord.try_submit_to_qos(0, vec![i as f32], qos) {
                        Ok(rx) => admitted.lock().unwrap().push(rx),
                        Err(SubmitError::Expired { .. }) => {
                            shed.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(e) => panic!("unexpected {e:?}"),
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let admitted = Arc::try_unwrap(admitted).ok().unwrap().into_inner().unwrap();
        let n = admitted.len();
        assert!(n >= 1, "everything shed — estimator seeded wrong");
        assert!(n <= 12, "{n} of 32 admitted against a 400 ms budget at 40 ms/request");
        assert_eq!(n + shed.load(Ordering::SeqCst), 32);
        for rx in admitted {
            let _ = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        // The in-flight counter drained back to zero: a modest fresh
        // deadline against the now-empty queue is admitted again.
        let qos = RequestQos::with_deadline(Instant::now() + Duration::from_millis(300));
        let rx = coord.try_submit_to_qos(0, vec![99.0], qos).expect("leaked in-flight admits");
        rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        drop(rx);
        Arc::try_unwrap(coord).ok().unwrap().shutdown();
    }

    #[test]
    fn scale_up_adds_serving_replicas() {
        let built = Arc::new(AtomicUsize::new(0));
        let coord = Coordinator::start(
            vec![PoolSpec::replicated("echo", 1, shared_echo("echo", built.clone()))],
            CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        assert!(coord.scalable(0));
        assert_eq!(coord.pool_replicas(0), Some(1));
        assert_eq!(coord.scale_to(0, 3).unwrap(), 3);
        assert_eq!(built.load(Ordering::SeqCst), 3);
        assert_eq!(coord.pool_replicas(0), Some(3));
        // All replicas (startup and scaled-up alike) answer from the
        // shared queue.
        let receivers: Vec<_> =
            (0..30).map(|i| coord.submit(vec![i as f32]).unwrap()).collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            assert_eq!(resp.output, vec![2.0 * i as f32]);
        }
        assert_eq!(coord.metrics().snapshot().backends["echo"].requests, 30);
        coord.shutdown();
    }

    #[test]
    fn scale_down_with_in_flight_batch_loses_no_responses() {
        // Three replicas, 80 ms per request; park work on all of them,
        // then drop to one replica mid-flight. Retiring workers finish
        // the batch they already claimed, queued leftovers fall to the
        // survivor: every submitted request is answered.
        let slow: SharedBackendFactory = Arc::new(|| {
            Ok(Box::new(FnBackend::new("slow", 1, |inputs: &[Vec<f32>]| {
                std::thread::sleep(Duration::from_millis(80));
                Ok(inputs.to_vec())
            })) as Box<dyn Backend>)
        });
        let coord = Coordinator::start(
            vec![PoolSpec::replicated("slow", 3, slow)],
            CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        let receivers: Vec<_> =
            (0..12).map(|i| coord.submit_to(0, vec![i as f32]).unwrap()).collect();
        // Let the replicas claim their first batches before retiring.
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(coord.scale_to(0, 1).unwrap(), 1);
        assert_eq!(coord.pool_replicas(0), Some(1));
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
            assert_eq!(resp.output, vec![i as f32], "request {i} lost in scale-down");
        }
        // The survivor keeps serving new work.
        let rx = coord.submit_to(0, vec![42.0]).unwrap();
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap().output,
            vec![42.0]
        );
        coord.shutdown();
    }

    #[test]
    fn rescale_to_current_size_is_a_no_op() {
        // min == max in the autoscaler collapses to scale_to(current):
        // no backend built, no worker retired.
        let built = Arc::new(AtomicUsize::new(0));
        let coord = Coordinator::start(
            vec![PoolSpec::replicated("echo", 2, shared_echo("echo", built.clone()))],
            CoordinatorConfig::default(),
        )
        .unwrap();
        assert_eq!(coord.scale_to(0, 2).unwrap(), 2);
        assert_eq!(built.load(Ordering::SeqCst), 2, "no-op rescale built a backend");
        assert_eq!(coord.pool_replicas(0), Some(2));
        coord.shutdown();
    }

    #[test]
    fn single_factory_pool_refuses_to_scale() {
        let coord =
            Coordinator::start(vec![echo_factory("echo")], CoordinatorConfig::default())
                .unwrap();
        assert!(!coord.scalable(0));
        let err = coord.scale_to(0, 2).unwrap_err();
        assert!(format!("{err:#}").contains("not scalable"), "{err:#}");
        // Shrinking clamps at one replica and is a no-op here.
        assert_eq!(coord.scale_to(0, 0).unwrap(), 1);
        assert_eq!(coord.pool_replicas(0), Some(1));
        coord.shutdown();
    }

    #[test]
    fn traced_coordinator_records_request_lifecycle() {
        let rec = TraceRecorder::new(1024);
        let coord = Coordinator::start_traced(
            vec![echo_factory("echo")],
            CoordinatorConfig { queue_capacity: 8, policy: BatchPolicy::immediate(4) },
            Some(rec.clone()),
        )
        .unwrap();
        for i in 0..3 {
            let rx = coord.submit(vec![i as f32]).unwrap();
            rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        }
        coord.shutdown();
        let events = rec.snapshot();
        let count = |cat: &str, name: &str| {
            events.iter().filter(|e| e.cat == cat && e.name == name).count()
        };
        assert_eq!(count("queue", "enqueue"), 3);
        assert_eq!(count("queue", "queued"), 3);
        assert_eq!(count("worker", "writeback"), 3);
        assert!(count("worker", "infer") >= 1, "no infer span recorded");
        // Everything landed on the pool's track.
        assert!(events
            .iter()
            .all(|e| e.track.as_deref() == Some("echo")), "wrong track: {events:?}");
        // Queued spans measure enqueue → dequeue, so they carry a
        // duration; enqueue/writeback are instants.
        assert!(events
            .iter()
            .filter(|e| e.name == "queued")
            .all(|e| e.dur_us.is_some()));
    }

    #[test]
    fn untraced_coordinator_has_no_trace_overhead_path() {
        // The default constructor wires no recorder: nothing to record
        // into, and the lifecycle hooks must stay on the None path.
        let coord =
            Coordinator::start(vec![echo_factory("echo")], CoordinatorConfig::default())
                .unwrap();
        assert!(coord.trace.is_none());
        let rx = coord.submit(vec![1.0]).unwrap();
        rx.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        coord.shutdown();
    }

    #[test]
    fn high_priority_jumps_deadline_queue() {
        // Normal-priority with a near deadline vs high-priority with a
        // far one: priority dominates the EDF key.
        let served = Arc::new(std::sync::Mutex::new(Vec::new()));
        let coord = Coordinator::start(
            vec![recording_factory("slow", 60, served.clone())],
            CoordinatorConfig { queue_capacity: 64, policy: BatchPolicy::immediate(1) },
        )
        .unwrap();
        let wedge = coord.submit_to(0, vec![0.0]).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        let now = Instant::now();
        let normal = coord
            .submit_to_qos(0, vec![2.0], RequestQos::with_deadline(now + Duration::from_secs(1)))
            .unwrap();
        let high = coord
            .submit_to_qos(
                0,
                vec![1.0],
                RequestQos {
                    deadline: Some(now + Duration::from_secs(30)),
                    priority: 0, // High rank
                },
            )
            .unwrap();
        wedge.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        high.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        normal.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        assert_eq!(*served.lock().unwrap(), vec![0.0, 1.0, 2.0]);
        coord.shutdown();
    }
}
