//! Request/response types crossing the coordinator's queues.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// What a request's response channel carries: the response, or a
/// backend error description.
pub type InferResult = Result<InferResponse, String>;

/// A single inference request: one flattened input vector.
pub struct InferRequest {
    pub id: u64,
    pub payload: Vec<f32>,
    /// Enqueue timestamp — latency is measured from here.
    pub enqueued_at: Instant,
    /// Oneshot-style response channel.
    pub respond_to: Sender<InferResult>,
}

/// The answer: output vector plus accounting.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    pub output: Vec<f32>,
    /// End-to-end latency (enqueue → response send).
    pub latency_s: f64,
    /// Which backend served it.
    pub backend: String,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn request_roundtrip_through_channel() {
        let (tx, rx) = channel();
        let req = InferRequest {
            id: 7,
            payload: vec![1.0, 2.0],
            enqueued_at: Instant::now(),
            respond_to: tx,
        };
        req.respond_to
            .send(Ok(InferResponse {
                id: req.id,
                output: vec![0.5],
                latency_s: 0.001,
                backend: "test".into(),
                batch_size: 1,
            }))
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.batch_size, 1);
    }
}
