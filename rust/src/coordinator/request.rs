//! Request/response types crossing the coordinator's queues.

use std::cell::Cell;
use std::sync::mpsc::{SendError, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Scheduling rank riding on every request: lower runs sooner. The wire
/// layer maps its `Priority` enum onto this (High=0, Normal=1, Low=2);
/// the coordinator itself only compares ranks, keeping it independent
/// of wire-protocol types.
pub const PRIORITY_NORMAL: u8 = 1;

/// Why a request failed without producing an output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// The backend accepted the request and then failed (panic, error).
    Backend,
    /// The request's deadline passed before a worker reached it — no
    /// inference was computed.
    Expired,
}

/// A structured failure: the kind drives the wire status a server maps
/// it to (`Backend` → `BackendError`, `Expired` → `Expired`), the
/// message is diagnostic text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InferError {
    pub kind: FailureKind,
    pub message: String,
}

impl InferError {
    pub fn backend(message: impl Into<String>) -> InferError {
        InferError { kind: FailureKind::Backend, message: message.into() }
    }

    pub fn expired(message: impl Into<String>) -> InferError {
        InferError { kind: FailureKind::Expired, message: message.into() }
    }
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for InferError {}

/// What a request's response channel carries: the response, or a
/// structured failure.
pub type InferResult = Result<InferResponse, InferError>;

/// Completion hook riding along a [`Responder`]: invoked (from the
/// worker thread) after every result send, so an event-driven caller
/// can be nudged instead of blocking on the channel. The event loop
/// hands in a closure that marks the connection ready and writes the
/// wakeup pipe.
pub type CompletionNotify = Arc<dyn Fn() + Send + Sync>;

/// A request's response channel plus the optional completion hook.
/// Thread-based callers (the `submit_*` APIs' default) carry no hook
/// and behave exactly like a bare `Sender<InferResult>`.
pub struct Responder {
    tx: Sender<InferResult>,
    notify: Option<CompletionNotify>,
    sent: Cell<bool>,
}

impl Responder {
    pub fn new(tx: Sender<InferResult>) -> Responder {
        Responder { tx, notify: None, sent: Cell::new(false) }
    }

    pub fn with_notify(tx: Sender<InferResult>, notify: Option<CompletionNotify>) -> Responder {
        Responder { tx, notify, sent: Cell::new(false) }
    }

    /// Send the result, then fire the completion hook. `&self` so the
    /// expiry sweep can answer requests it only holds by reference.
    pub fn send(&self, result: InferResult) -> Result<(), SendError<InferResult>> {
        let out = self.tx.send(result);
        self.sent.set(true);
        if let Some(notify) = &self.notify {
            notify();
        }
        out
    }
}

impl Drop for Responder {
    fn drop(&mut self) {
        // A request dropped without an answer (queue torn down at
        // shutdown) still wakes the waiting connection, which then
        // observes the disconnected channel instead of sleeping until
        // its response deadline.
        if !self.sent.get() {
            if let Some(notify) = &self.notify {
                notify();
            }
        }
    }
}

impl From<Sender<InferResult>> for Responder {
    fn from(tx: Sender<InferResult>) -> Responder {
        Responder::new(tx)
    }
}

/// A single inference request: one flattened input vector.
pub struct InferRequest {
    pub id: u64,
    pub payload: Vec<f32>,
    /// Enqueue timestamp — latency is measured from here.
    pub enqueued_at: Instant,
    /// Completion deadline. A worker that pops this request after the
    /// deadline answers `Expired` instead of running the backend, and
    /// admission control rejects it up front when the estimated queue
    /// wait alone already overshoots. `None` = the pre-v3 behavior.
    pub deadline: Option<Instant>,
    /// Scheduling rank (lower first); see [`PRIORITY_NORMAL`].
    pub priority: u8,
    /// Oneshot-style response channel (+ optional completion hook).
    pub respond_to: Responder,
}

impl InferRequest {
    /// True once `now` is past the deadline (never for deadline-free
    /// requests).
    pub fn expired_at(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// The answer: output vector plus accounting.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    pub output: Vec<f32>,
    /// End-to-end latency (enqueue → response send).
    pub latency_s: f64,
    /// Which backend served it.
    pub backend: String,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    #[test]
    fn request_roundtrip_through_channel() {
        let (tx, rx) = channel();
        let req = InferRequest {
            id: 7,
            payload: vec![1.0, 2.0],
            enqueued_at: Instant::now(),
            deadline: None,
            priority: PRIORITY_NORMAL,
            respond_to: Responder::new(tx),
        };
        req.respond_to
            .send(Ok(InferResponse {
                id: req.id,
                output: vec![0.5],
                latency_s: 0.001,
                backend: "test".into(),
                batch_size: 1,
            }))
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.batch_size, 1);
    }

    #[test]
    fn expiry_is_deadline_relative() {
        let (tx, _rx) = channel();
        let now = Instant::now();
        let mut req = InferRequest {
            id: 1,
            payload: vec![],
            enqueued_at: now,
            deadline: None,
            priority: PRIORITY_NORMAL,
            respond_to: tx.into(),
        };
        assert!(!req.expired_at(now + Duration::from_secs(3600)));
        req.deadline = Some(now + Duration::from_millis(50));
        assert!(!req.expired_at(now));
        assert!(req.expired_at(now + Duration::from_millis(50)));
        assert!(req.expired_at(now + Duration::from_secs(1)));
    }

    #[test]
    fn responder_fires_hook_on_send_and_on_unanswered_drop() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let fired = Arc::new(AtomicUsize::new(0));
        let hook: CompletionNotify = {
            let fired = fired.clone();
            Arc::new(move || {
                fired.fetch_add(1, Ordering::SeqCst);
            })
        };
        let (tx, rx) = channel();
        let responder = Responder::with_notify(tx, Some(hook.clone()));
        responder.send(Err(InferError::backend("boom"))).unwrap();
        assert_eq!(fired.load(Ordering::SeqCst), 1, "send fires the hook");
        drop(responder);
        assert_eq!(fired.load(Ordering::SeqCst), 1, "an answered responder drops silently");
        assert!(rx.recv().unwrap().is_err());

        let (tx, _rx) = channel::<InferResult>();
        drop(Responder::with_notify(tx, Some(hook)));
        assert_eq!(fired.load(Ordering::SeqCst), 2, "unanswered drop still wakes the waiter");
    }

    #[test]
    fn error_kinds_distinguish_expiry_from_backend_failure() {
        let e = InferError::expired("deadline passed in queue");
        assert_eq!(e.kind, FailureKind::Expired);
        assert_eq!(e.to_string(), "deadline passed in queue");
        let b = InferError::backend("kaboom");
        assert_eq!(b.kind, FailureKind::Backend);
        assert_ne!(e, b);
    }
}
