//! Serving metrics: counters, a log-bucketed latency histogram, and
//! per-backend aggregation. Shared across threads via `Arc<Metrics>`;
//! everything is lock-protected (contention is negligible next to
//! inference work — confirmed in the §Perf pass).

use crate::fpga::stats::CycleStats;
use crate::nn::kernels::pipeline::StageSnapshot;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Latency histogram with power-of-two microsecond buckets:
/// bucket i covers [2^i, 2^{i+1}) µs, 32 buckets ≈ up to ~70 minutes.
#[derive(Debug, Default, Clone)]
pub struct Histogram {
    buckets: [u64; 32],
    count: u64,
    sum_s: f64,
    max_s: f64,
}

impl Histogram {
    pub fn record(&mut self, latency_s: f64) {
        let us = (latency_s * 1e6).max(0.0);
        let idx = if us < 1.0 { 0 } else { (us.log2() as usize).min(31) };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_s += latency_s;
        self.max_s = self.max_s.max(latency_s);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Approximate quantile from the buckets — [`Histogram::quantile_s`]
    /// under the name the serving `Stats` opcode documents.
    pub fn quantile(&self, q: f64) -> f64 {
        self.quantile_s(q)
    }

    /// Median latency in seconds.
    pub fn p50_s(&self) -> f64 {
        self.quantile_s(0.50)
    }

    /// 95th-percentile latency in seconds.
    pub fn p95_s(&self) -> f64 {
        self.quantile_s(0.95)
    }

    /// 99th-percentile latency in seconds.
    pub fn p99_s(&self) -> f64 {
        self.quantile_s(0.99)
    }

    /// 99.9th-percentile latency in seconds — the tail the replica
    /// sweep (E8) watches, since queueing behind a saturated pool shows
    /// up here long before it moves p50.
    pub fn p999_s(&self) -> f64 {
        self.quantile_s(0.999)
    }

    /// Approximate quantile from the buckets with within-bucket linear
    /// interpolation: the q-th ranked sample lands in some bucket
    /// [lo, hi); assuming samples spread uniformly inside the bucket,
    /// the estimate is `lo + frac·(hi − lo)` where `frac` is the
    /// target rank's position among that bucket's samples. Power-of-two
    /// buckets bound the error to one bucket width, so the estimate is
    /// always within 2× of the exact sample quantile (pinned by
    /// `interpolated_quantiles_track_exact_sample_quantiles`).
    ///
    /// Edge cases, pinned by tests: an empty histogram reports 0.0 for
    /// every quantile, and estimates are clamped to the observed
    /// maximum (skipped when the only observation is 0.0 so that a
    /// recorded sample never reports as "no latency").
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 && seen + c >= target {
                let lo_us = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi_us = (1u64 << (i + 1)) as f64;
                let frac = (target - seen) as f64 / c as f64;
                let v = (lo_us + frac * (hi_us - lo_us)) * 1e-6;
                return if self.max_s > 0.0 { v.min(self.max_s) } else { v };
            }
            seen += c;
        }
        self.max_s
    }

    /// Total of recorded values in seconds (the Prometheus `_sum`
    /// counterpart to [`Histogram::count`]).
    pub fn sum_s(&self) -> f64 {
        self.sum_s
    }

    /// Cumulative bucket view for Prometheus exposition: one
    /// `(le_us, cumulative_count)` entry per bucket, where
    /// `le_us = 2^(i+1)` is bucket i's inclusive upper bound in
    /// microseconds and the count covers every sample ≤ that bound.
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::with_capacity(self.buckets.len());
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            out.push((1u64 << (i + 1), cum));
        }
        out
    }
}

/// Per-backend counters.
#[derive(Debug, Default, Clone)]
pub struct BackendMetrics {
    pub latency: Histogram,
    pub requests: u64,
    pub batches: u64,
    pub batch_size_sum: u64,
    pub errors: u64,
    /// Requests shed at this pool's queue (backpressure / admission).
    pub shed: u64,
    /// Requests answered `Expired` for this pool: rejected at admission
    /// because the estimated wait overshot the deadline, or expired in
    /// the queue before a worker reached them.
    pub expired: u64,
    /// Accumulated simulator events (FPGA backend only).
    pub cycle_stats: CycleStats,
    /// Latest per-stage occupancy/stall snapshot (stage-pipelined
    /// backends only; empty for monolithic ones). Cumulative since the
    /// backend was built — the worker refreshes it after every batch.
    pub stages: Vec<StageSnapshot>,
    /// Weight bytes this pool streams per served sample (packed codes +
    /// scales + biases at the pool's precision; 0 when the engine never
    /// registered a figure). Lower is better — the serving bench
    /// reports it as `<pool>_bytes_per_sample`.
    pub bytes_per_sample: u64,
}

impl BackendMetrics {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }
}

/// Snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub backends: BTreeMap<String, BackendMetrics>,
    pub rejected: u64,
    /// Total `Expired` answers across pools (admission + in-queue).
    pub expired: u64,
    /// Degraded-mode flips (normal→degraded and back) since startup.
    pub degraded_transitions: u64,
    /// Connections turned away with `Busy` at accept time (connection
    /// cap reached) — these never reach a pool, so they are invisible
    /// to the per-pool shed counters.
    pub busy_rejected: u64,
    /// `BadRequest` answers by cause label (e.g. "magic", "version",
    /// "opcode", "payload"). Causes are short stable strings — they
    /// become the `cause` label on `edgemlp_bad_requests_total`.
    pub bad_requests: BTreeMap<String, u64>,
}

impl MetricsSnapshot {
    /// Requests served across all backends.
    pub fn total_requests(&self) -> u64 {
        self.backends.values().map(|b| b.requests).sum()
    }

    /// One line per pool with counters and latency percentiles — what
    /// the serving `Stats` opcode puts on the wire. Pool labels embed
    /// the served model for engine-built pools (`cpu/mnist`), so this
    /// is the per-pool/per-model breakdown. Stage-pipelined pools get
    /// one extra line per stage: occupancy (busy fraction of observed
    /// wall time) and the stall split between waiting for upstream
    /// input and blocking on a full downstream channel.
    pub fn render(&self) -> String {
        use crate::bench_harness::fmt_time;
        let mut out = format!(
            "rejected: {} expired: {} degraded_transitions: {} busy_rejected: {} \
             bad_requests: {}\n",
            self.rejected,
            self.expired,
            self.degraded_transitions,
            self.busy_rejected,
            self.bad_requests.values().sum::<u64>(),
        );
        for (name, m) in &self.backends {
            let bytes = if m.bytes_per_sample > 0 {
                format!(" bytes_per_sample={}", m.bytes_per_sample)
            } else {
                String::new()
            };
            out.push_str(&format!(
                "pool {name}: requests={} batches={} errors={} shed={} expired={} \
                 mean_batch={:.1} p50={} p95={} p99={} p99.9={} max={}{bytes}\n",
                m.requests,
                m.batches,
                m.errors,
                m.shed,
                m.expired,
                m.mean_batch(),
                fmt_time(m.latency.p50_s()),
                fmt_time(m.latency.p95_s()),
                fmt_time(m.latency.p99_s()),
                fmt_time(m.latency.p999_s()),
                fmt_time(m.latency.max_s()),
            ));
            for s in &m.stages {
                let total = s.busy_s + s.stall_in_s + s.stall_out_s;
                let pct = |part: f64| if total > 0.0 { 100.0 * part / total } else { 0.0 };
                out.push_str(&format!(
                    "  stage {}: jobs={} failed={} occupancy={:.1}% stall_in={:.1}% \
                     stall_out={:.1}%\n",
                    s.label,
                    s.processed,
                    s.failed,
                    100.0 * s.occupancy(),
                    pct(s.stall_in_s),
                    pct(s.stall_out_s),
                ));
            }
        }
        out
    }
}

/// Thread-shared metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

#[derive(Debug, Default)]
struct MetricsInner {
    backends: BTreeMap<String, BackendMetrics>,
    rejected: u64,
    expired: u64,
    degraded_transitions: u64,
    busy_rejected: u64,
    bad_requests: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served batch for `backend`.
    pub fn record_batch(
        &self,
        backend: &str,
        batch_size: usize,
        latencies_s: &[f64],
        cycle_stats: Option<&CycleStats>,
    ) {
        let mut inner = self.inner.lock().unwrap();
        let m = inner.backends.entry(backend.to_string()).or_default();
        m.batches += 1;
        m.batch_size_sum += batch_size as u64;
        m.requests += latencies_s.len() as u64;
        for &l in latencies_s {
            m.latency.record(l);
        }
        if let Some(cs) = cycle_stats {
            m.cycle_stats.merge(cs);
        }
    }

    pub fn record_error(&self, backend: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.backends.entry(backend.to_string()).or_default().errors += 1;
    }

    /// Install the latest per-stage snapshot for a stage-pipelined
    /// backend (counters are cumulative, so replacing is correct; with
    /// replicated workers the last reporter wins — each replica's
    /// pipeline has the same shape).
    pub fn record_stage_stats(&self, backend: &str, stages: Vec<StageSnapshot>) {
        let mut inner = self.inner.lock().unwrap();
        inner.backends.entry(backend.to_string()).or_default().stages = stages;
    }

    /// A request was shed due to backpressure.
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// A request was shed at a known pool's full queue — the per-pool
    /// flavor of [`Metrics::record_rejected`] (increments both).
    pub fn record_shed(&self, backend: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.rejected += 1;
        inner.backends.entry(backend.to_string()).or_default().shed += 1;
    }

    /// A request was answered `Expired` (admission reject or in-queue
    /// expiry) at `backend`'s pool.
    pub fn record_expired(&self, backend: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.expired += 1;
        inner.backends.entry(backend.to_string()).or_default().expired += 1;
    }

    /// Degraded-mode routing flipped (either direction).
    pub fn record_degraded_transition(&self) {
        self.inner.lock().unwrap().degraded_transitions += 1;
    }

    /// A connection was turned away with `Busy` at accept time.
    pub fn record_busy_rejected(&self) {
        self.inner.lock().unwrap().busy_rejected += 1;
    }

    /// A frame drew a `BadRequest` answer; `cause` is a short stable
    /// label naming what was malformed (it becomes a Prometheus label
    /// value, so keep the vocabulary small and fixed).
    pub fn record_bad_request(&self, cause: &str) {
        let mut inner = self.inner.lock().unwrap();
        *inner.bad_requests.entry(cause.to_string()).or_default() += 1;
    }

    /// Register `backend`'s weight footprint in bytes per served sample
    /// — a static property of the (model, precision) pair, set once at
    /// engine assembly and surfaced by `Stats`, `StatsV2` and the
    /// serving bench.
    pub fn set_pool_bytes(&self, backend: &str, bytes: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.backends.entry(backend.to_string()).or_default().bytes_per_sample = bytes;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        MetricsSnapshot {
            backends: inner.backends.clone(),
            rejected: inner.rejected,
            expired: inner.expired,
            degraded_transitions: inner.degraded_transitions,
            busy_rejected: inner.busy_rejected,
            bad_requests: inner.bad_requests.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_count() {
        let mut h = Histogram::default();
        h.record(1e-3);
        h.record(3e-3);
        assert_eq!(h.count(), 2);
        assert!((h.mean_s() - 2e-3).abs() < 1e-9);
        assert_eq!(h.max_s(), 3e-3);
    }

    #[test]
    fn histogram_quantile_monotone() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64 * 1e-4);
        }
        assert!(h.quantile_s(0.5) <= h.quantile_s(0.99));
        // p50 ≈ 5 ms: bucket upper bound within 2×.
        let p50 = h.quantile_s(0.5);
        assert!(p50 >= 4e-3 && p50 <= 1.7e-2, "p50 {p50}");
    }

    #[test]
    fn metrics_aggregate_per_backend() {
        let m = Metrics::new();
        m.record_batch("cpu", 4, &[1e-3; 4], None);
        m.record_batch("cpu", 2, &[2e-3; 2], None);
        m.record_batch("fpga", 1, &[1e-6], Some(&CycleStats { macs: 10, ..Default::default() }));
        m.record_rejected();
        let snap = m.snapshot();
        assert_eq!(snap.backends["cpu"].requests, 6);
        assert_eq!(snap.backends["cpu"].batches, 2);
        assert!((snap.backends["cpu"].mean_batch() - 3.0).abs() < 1e-9);
        assert_eq!(snap.backends["fpga"].cycle_stats.macs, 10);
        assert_eq!(snap.rejected, 1);
    }

    #[test]
    fn resilience_counters_aggregate() {
        let m = Metrics::new();
        m.record_shed("cpu");
        m.record_shed("cpu");
        m.record_expired("cpu");
        m.record_expired("fpga");
        m.record_degraded_transition();
        m.record_degraded_transition();
        m.record_rejected(); // pool-less legacy shed still counts globally
        let snap = m.snapshot();
        assert_eq!(snap.backends["cpu"].shed, 2);
        assert_eq!(snap.backends["cpu"].expired, 1);
        assert_eq!(snap.backends["fpga"].expired, 1);
        assert_eq!(snap.rejected, 3);
        assert_eq!(snap.expired, 2);
        assert_eq!(snap.degraded_transitions, 2);
        let text = snap.render();
        assert!(text.contains("expired: 2"), "{text}");
        assert!(text.contains("degraded_transitions: 2"), "{text}");
        assert!(text.contains("shed=2"), "{text}");
    }

    #[test]
    fn quantile_accessors_are_ordered() {
        let mut h = Histogram::default();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5);
        }
        assert_eq!(h.quantile(0.5), h.quantile_s(0.5));
        assert!(h.p50_s() <= h.p95_s());
        assert!(h.p95_s() <= h.p99_s());
        assert!(h.p99_s() <= h.p999_s());
        assert!(h.p999_s() <= h.max_s() * 2.0 + 1e-12);
    }

    #[test]
    fn p999_separates_a_heavy_tail_p99_misses() {
        // 9989 fast samples (~100 µs) + 11 slow outliers (~100 ms): the
        // outliers are ~0.1% of traffic, so p99 stays in the fast
        // bucket while the 9990th-ranked sample (p99.9 of 10000) is the
        // first outlier.
        let mut h = Histogram::default();
        for _ in 0..9989 {
            h.record(1e-4);
        }
        for _ in 0..11 {
            h.record(1e-1);
        }
        assert!(h.p99_s() < 1e-3, "p99 {} caught the outliers", h.p99_s());
        assert!(h.p999_s() > 5e-2, "p99.9 {} missed the outliers", h.p999_s());
    }

    #[test]
    fn quantiles_on_empty_histogram_are_zero() {
        let h = Histogram::default();
        for q in [0.0, 0.5, 0.99, 0.999, 1.0] {
            assert_eq!(h.quantile_s(q), 0.0, "q={q}");
        }
        assert_eq!(h.p999_s(), 0.0);
        assert_eq!(h.max_s(), 0.0);
        assert_eq!(h.mean_s(), 0.0);
    }

    #[test]
    fn single_bucket_histogram_interpolates_within_the_bucket() {
        // All samples in the [1024, 2048) µs bucket: quantiles sweep
        // linearly across the bucket with rank (no more "every quantile
        // reports the upper bound"), stay inside
        // [bucket_lo, min(bucket_hi, max)], and are monotone in q.
        let mut h = Histogram::default();
        for _ in 0..100 {
            h.record(1.5e-3);
        }
        let lo = 1024e-6;
        let mut prev = 0.0;
        for q in [0.0, 0.25, 0.5, 0.99, 0.999, 1.0] {
            let v = h.quantile_s(q);
            assert!(v >= lo && v <= h.max_s() + 1e-12, "q={q} v={v}");
            assert!(v >= prev, "q={q} not monotone: {v} < {prev}");
            prev = v;
        }
        // Interpolation actually spreads the estimates: the low and
        // high quantiles must not collapse to one value.
        assert!(h.quantile_s(1.0) > h.quantile_s(0.0), "quantiles collapsed");
        // The top quantile clamps to the observed max, not the bucket
        // upper bound (2048 µs would overreport by ~37%).
        assert!((h.quantile_s(1.0) - 1.5e-3).abs() < 1e-9);
    }

    #[test]
    fn interpolated_quantiles_track_exact_sample_quantiles() {
        // Randomized pin of the satellite fix: latencies spread
        // log-uniformly across four decades (10 µs .. 100 ms) via a
        // deterministic LCG, then p50/p90/p99 are compared against the
        // exact sorted-sample quantiles. Tolerance: power-of-two
        // buckets bound the interpolation error to one bucket width,
        // so the estimate must land within 2× of the exact value
        // (the pre-fix upper-bound rule failed this at ~2× bias high).
        let mut state = 0x853c49e6748fea9b_u64;
        let mut next_unit = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let mut h = Histogram::default();
        let mut samples = Vec::with_capacity(10_000);
        for _ in 0..10_000 {
            let lat = 1e-5 * 10f64.powf(4.0 * next_unit());
            h.record(lat);
            samples.push(lat);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.9, 0.99] {
            let rank = ((q * samples.len() as f64).ceil() as usize).max(1);
            let exact = samples[rank - 1];
            let est = h.quantile_s(q);
            let ratio = est / exact;
            assert!(
                ratio > 0.5 && ratio < 2.0,
                "q={q}: est {est} vs exact {exact} (ratio {ratio})"
            );
        }
    }

    #[test]
    fn snapshot_render_includes_percentiles() {
        let m = Metrics::new();
        m.record_batch("cpu", 3, &[1e-3, 2e-3, 3e-3], None);
        m.record_rejected();
        let snap = m.snapshot();
        assert_eq!(snap.total_requests(), 3);
        let text = snap.render();
        assert!(text.contains("rejected: 1"));
        assert!(text.contains("pool cpu"));
        assert!(text.contains("p50="));
        assert!(text.contains("p99="));
        assert!(text.contains("p99.9="));
    }

    #[test]
    fn render_includes_stage_lines_for_pipelined_pools() {
        let m = Metrics::new();
        m.record_batch("pipeline/default", 2, &[1e-3; 2], None);
        m.record_stage_stats(
            "pipeline/default",
            vec![
                StageSnapshot {
                    label: "layer0".into(),
                    processed: 4,
                    failed: 1,
                    busy_s: 0.75,
                    stall_in_s: 0.25,
                    stall_out_s: 0.0,
                },
                StageSnapshot { label: "layer1".into(), processed: 4, ..Default::default() },
            ],
        );
        let text = m.snapshot().render();
        assert!(text.contains("stage layer0: jobs=4 failed=1 occupancy=75.0%"), "{text}");
        assert!(text.contains("stall_in=25.0%"), "{text}");
        assert!(text.contains("stage layer1: jobs=4 failed=0 occupancy=0.0%"), "{text}");
        // Monolithic pools render no stage lines.
        let m2 = Metrics::new();
        m2.record_batch("cpu", 1, &[1e-3], None);
        assert!(!m2.snapshot().render().contains("stage "), "{}", m2.snapshot().render());
    }

    #[test]
    fn zero_latency_goes_to_first_bucket() {
        let mut h = Histogram::default();
        h.record(0.0);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_s(1.0) > 0.0);
    }

    #[test]
    fn cumulative_buckets_cover_all_samples() {
        let mut h = Histogram::default();
        h.record(1e-6); // 1 µs → bucket 0, le 2
        h.record(3e-6); // 3 µs → bucket 1, le 4
        h.record(3e-6);
        h.record(1e-3); // 1000 µs → bucket 9, le 1024
        let cum = h.cumulative_buckets();
        assert_eq!(cum.len(), 32);
        assert_eq!(cum[0], (2, 1));
        assert_eq!(cum[1], (4, 3));
        assert_eq!(cum[8], (512, 3));
        assert_eq!(cum[9], (1024, 4));
        assert_eq!(cum[31].1, h.count(), "last bucket must be cumulative total");
        assert!(cum.windows(2).all(|w| w[0].1 <= w[1].1 && w[0].0 < w[1].0));
        assert!((h.sum_s() - (1e-6 + 3e-6 + 3e-6 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn pool_bytes_register_once_and_survive_traffic() {
        let m = Metrics::new();
        m.set_pool_bytes("int4/default", 27_000);
        m.set_pool_bytes("cpu/default", 407_000);
        m.record_batch("int4/default", 2, &[1e-3; 2], None);
        let snap = m.snapshot();
        assert_eq!(snap.backends["int4/default"].bytes_per_sample, 27_000);
        assert_eq!(snap.backends["int4/default"].requests, 2);
        assert_eq!(snap.backends["cpu/default"].bytes_per_sample, 407_000);
        let text = snap.render();
        assert!(text.contains("bytes_per_sample=27000"), "{text}");
        // Pools that never registered a figure render no bytes field.
        let m2 = Metrics::new();
        m2.record_batch("cpu", 1, &[1e-3], None);
        assert!(!m2.snapshot().render().contains("bytes_per_sample"), "unregistered leaked");
    }

    #[test]
    fn busy_and_bad_request_counters_surface_in_snapshot() {
        let m = Metrics::new();
        m.record_busy_rejected();
        m.record_busy_rejected();
        m.record_bad_request("magic");
        m.record_bad_request("version");
        m.record_bad_request("version");
        let snap = m.snapshot();
        assert_eq!(snap.busy_rejected, 2);
        assert_eq!(snap.bad_requests["magic"], 1);
        assert_eq!(snap.bad_requests["version"], 2);
        let text = snap.render();
        assert!(text.contains("busy_rejected: 2"), "{text}");
        assert!(text.contains("bad_requests: 3"), "{text}");
    }
}
