//! L3 serving coordinator — the edge-inference deployment shell the
//! paper's introduction motivates (camera → edge box → answer).
//!
//! Architecture (threads + bounded channels; tokio is not in the
//! offline vendor set, and a thread-per-backend design is required
//! anyway because PJRT handles are not `Send`):
//!
//! ```text
//!  clients ──submit──► router (least-loaded) ──► per-pool BoundedQueue (MPMC)
//!                                                   │ dynamic batcher (max_batch / max_wait)
//!                                                   ▼
//!                                     N replica worker threads per pool
//!                                         (CPU | FPGA-sim | XLA/PJRT)
//!                                                   │ per-request response channel
//!                                                   ▼
//!                                          metrics (latency histogram, power)
//! ```
//!
//! Requests carry their payload and a oneshot response sender; the
//! batcher groups up to `max_batch` requests within a `max_wait`
//! window (vLLM-style dynamic batching, scaled to this paper's sizes).
//! A pool's replicas share one queue and pop batches concurrently —
//! the software analogue of the paper's parallel PU array — and the
//! router picks the pool with the shallowest queue instead of blind
//! round-robin.

pub mod autoscale;
pub mod backend;
pub mod batcher;
pub mod degrade;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod server;

pub use autoscale::{Autoscaler, AutoscaleHooks, AutoscalePolicy, AutoscaleStats};
pub use backend::{Backend, CpuBackend, FpgaBackend, VsqBackend};
pub use batcher::BatchPolicy;
pub use degrade::{DegradeController, DegradePolicy};
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{
    CompletionNotify, FailureKind, InferError, InferRequest, InferResponse, Responder,
};
pub use server::{
    Coordinator, CoordinatorConfig, PoolSpec, RequestQos, SharedBackendFactory, SubmitError,
};
