//! Degraded-mode controller: the scheduler-level analogue of the
//! paper's precision-for-power dial. When a model's pools stay
//! saturated past a dwell threshold, `BACKEND_ANY` traffic is routed to
//! the model's cheapest backend (e.g. the SPx shift-add datapath
//! instead of CPU f32) until load subsides — trading a little accuracy
//! for queue headroom instead of letting deadlines blow out.
//!
//! The controller is a pure hysteresis state machine over an occupancy
//! signal in `[0, 1]` (queue depth / capacity of the best pool the
//! router could pick). Hysteresis is double: separate enter/exit
//! thresholds AND separate dwell times, so occupancy flapping around
//! either threshold cannot flap the mode. Every method takes `now`
//! explicitly — tests drive it with a synthetic clock, and the server
//! samples it on each routing decision and `Health` poll.
//!
//! Since the power-budget autoscaler landed, the mode is **two-signal**:
//! occupancy (hysteresis above) OR an externally latched power signal
//! ([`DegradeController::set_power`], raised by the autoscaler when the
//! modeled board draw overshoots `--power-budget-w`, with its own
//! hysteresis applied *before* the latch). The route is degraded while
//! either signal holds; transitions are counted on edges of the
//! combined flag, so flipping one signal while the other already holds
//! the mode is not a new transition.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Hysteresis thresholds for [`DegradeController`].
#[derive(Debug, Clone, Copy)]
pub struct DegradePolicy {
    /// Enter degraded mode after occupancy stays `>= enter_occupancy`
    /// for `enter_after`.
    pub enter_occupancy: f64,
    /// Leave degraded mode after occupancy stays `< exit_occupancy`
    /// for `exit_after`. Must be below `enter_occupancy` for the
    /// hysteresis band to exist.
    pub exit_occupancy: f64,
    pub enter_after: Duration,
    pub exit_after: Duration,
}

impl Default for DegradePolicy {
    fn default() -> Self {
        DegradePolicy {
            enter_occupancy: 0.75,
            exit_occupancy: 0.25,
            enter_after: Duration::from_millis(250),
            exit_after: Duration::from_millis(500),
        }
    }
}

impl DegradePolicy {
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.enter_occupancy)
            || !(0.0..=1.0).contains(&self.exit_occupancy)
        {
            return Err("degrade occupancy thresholds must be in [0, 1]".into());
        }
        if self.exit_occupancy >= self.enter_occupancy {
            return Err(format!(
                "degrade exit occupancy {} must be below enter occupancy {} \
                 (no hysteresis band)",
                self.exit_occupancy, self.enter_occupancy
            ));
        }
        Ok(())
    }
}

#[derive(Debug, Default)]
struct DegradeState {
    /// Occupancy-signal half of the mode (hysteresis state machine).
    occ_degraded: bool,
    /// Power-signal half, latched by the autoscaler's budget hysteresis.
    power_degraded: bool,
    /// Start of the current continuous stretch above the enter
    /// threshold (while normal) or below the exit threshold (while
    /// degraded). Cleared whenever the signal leaves the stretch.
    stretch_start: Option<Instant>,
    transitions: u64,
}

impl DegradeState {
    fn degraded(&self) -> bool {
        self.occ_degraded || self.power_degraded
    }
}

/// The per-model mode state machine. Interior-mutable so routing
/// threads can observe through a shared reference.
#[derive(Debug)]
pub struct DegradeController {
    policy: DegradePolicy,
    state: Mutex<DegradeState>,
}

impl DegradeController {
    pub fn new(policy: DegradePolicy) -> DegradeController {
        debug_assert!(policy.validate().is_ok());
        DegradeController { policy, state: Mutex::new(DegradeState::default()) }
    }

    /// Feed one occupancy sample at `now`; returns the (possibly newly
    /// flipped) degraded flag. Also returns whether this sample flipped
    /// the mode, so the caller can count transitions exactly once. The
    /// returned flag is the *combined* mode (occupancy OR power), and a
    /// flip is an edge of that combined flag — an occupancy recovery
    /// while the power signal still holds reports no flip.
    pub fn observe(&self, occupancy: f64, now: Instant) -> (bool, bool) {
        let mut st = self.state.lock().unwrap();
        let before = st.degraded();
        let (in_stretch, dwell) = if st.occ_degraded {
            (occupancy < self.policy.exit_occupancy, self.policy.exit_after)
        } else {
            (occupancy >= self.policy.enter_occupancy, self.policy.enter_after)
        };
        if !in_stretch {
            st.stretch_start = None;
            return (st.degraded(), false);
        }
        let start = *st.stretch_start.get_or_insert(now);
        if now.saturating_duration_since(start) >= dwell {
            st.occ_degraded = !st.occ_degraded;
            st.stretch_start = None;
            if st.degraded() != before {
                st.transitions += 1;
                return (st.degraded(), true);
            }
        }
        (st.degraded(), false)
    }

    /// Latch or clear the power half of the mode. The caller applies
    /// its own hysteresis (budget dwell) before flipping this — the
    /// controller only combines the signals. Returns whether the
    /// combined degraded flag flipped, so transitions can be counted.
    pub fn set_power(&self, over_budget: bool) -> bool {
        let mut st = self.state.lock().unwrap();
        let before = st.degraded();
        st.power_degraded = over_budget;
        let flipped = st.degraded() != before;
        if flipped {
            st.transitions += 1;
        }
        flipped
    }

    /// The power half of the combined mode, alone.
    pub fn power_degraded(&self) -> bool {
        self.state.lock().unwrap().power_degraded
    }

    pub fn is_degraded(&self) -> bool {
        self.state.lock().unwrap().degraded()
    }

    pub fn transitions(&self) -> u64 {
        self.state.lock().unwrap().transitions
    }

    pub fn policy(&self) -> DegradePolicy {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller() -> DegradeController {
        DegradeController::new(DegradePolicy {
            enter_occupancy: 0.8,
            exit_occupancy: 0.2,
            enter_after: Duration::from_millis(100),
            exit_after: Duration::from_millis(200),
        })
    }

    /// Synthetic clock: all tests drive `observe` with explicit
    /// instants, so no sleeping and no wall-clock flakiness.
    fn clock() -> impl FnMut(u64) -> Instant {
        let epoch = Instant::now();
        move |ms| epoch + Duration::from_millis(ms)
    }

    #[test]
    fn enters_only_after_sustained_saturation() {
        let c = controller();
        let mut at = clock();
        // A short burst above the threshold is not enough.
        assert_eq!(c.observe(0.9, at(0)), (false, false));
        assert_eq!(c.observe(0.9, at(50)), (false, false));
        // Dip below: the stretch resets.
        assert_eq!(c.observe(0.5, at(60)), (false, false));
        assert_eq!(c.observe(0.9, at(70)), (false, false));
        assert_eq!(c.observe(0.9, at(150)), (false, false)); // only 80ms in
        // Sustained past the dwell: flip.
        assert_eq!(c.observe(0.9, at(170)), (true, true));
        assert_eq!(c.transitions(), 1);
        // Further saturated samples do not re-flip.
        assert_eq!(c.observe(0.95, at(400)), (true, false));
        assert_eq!(c.transitions(), 1);
    }

    #[test]
    fn exits_only_after_sustained_calm() {
        let c = controller();
        let mut at = clock();
        c.observe(1.0, at(0));
        assert_eq!(c.observe(1.0, at(100)), (true, true));
        // Calm, but not for long enough.
        assert_eq!(c.observe(0.1, at(110)), (true, false));
        assert_eq!(c.observe(0.1, at(250)), (true, false)); // 140ms < 200ms
        // A load spike resets the calm stretch.
        assert_eq!(c.observe(0.5, at(260)), (true, false));
        assert_eq!(c.observe(0.1, at(270)), (true, false));
        assert_eq!(c.observe(0.1, at(400)), (true, false)); // 130ms back in
        assert_eq!(c.observe(0.1, at(470)), (false, true)); // 200ms: recover
        assert_eq!(c.transitions(), 2);
    }

    #[test]
    fn flapping_inside_the_band_never_flips() {
        // Occupancy oscillating between the two thresholds (0.2..0.8)
        // belongs to neither stretch — the mode must hold steady.
        let c = controller();
        let mut at = clock();
        for t in 0..50u64 {
            let occ = if t % 2 == 0 { 0.3 } else { 0.7 };
            let (deg, flipped) = c.observe(occ, at(t * 50));
            assert!(!deg && !flipped, "t={t}");
        }
        assert_eq!(c.transitions(), 0);
    }

    #[test]
    fn boundary_samples_count_toward_the_correct_side() {
        let c = controller();
        let mut at = clock();
        // Exactly at the enter threshold counts as saturated (>=).
        c.observe(0.8, at(0));
        assert_eq!(c.observe(0.8, at(100)), (true, true));
        // Exactly at the exit threshold is NOT calm (<).
        c.observe(0.2, at(110));
        assert_eq!(c.observe(0.2, at(500)), (true, false));
        // Just below it is.
        c.observe(0.19, at(510));
        assert_eq!(c.observe(0.19, at(710)), (false, true));
    }

    #[test]
    fn power_signal_degrades_independently_of_occupancy() {
        let c = controller();
        let mut at = clock();
        assert!(!c.is_degraded());
        // Power latch raises the combined mode with no occupancy input.
        assert!(c.set_power(true));
        assert!(c.is_degraded() && c.power_degraded());
        assert_eq!(c.transitions(), 1);
        // Idempotent latch: no new transition.
        assert!(!c.set_power(true));
        assert_eq!(c.transitions(), 1);
        // Calm occupancy samples cannot clear a power-held mode.
        assert_eq!(c.observe(0.0, at(0)), (true, false));
        assert_eq!(c.observe(0.0, at(1000)), (true, false));
        assert!(c.set_power(false));
        assert!(!c.is_degraded());
        assert_eq!(c.transitions(), 2);
    }

    #[test]
    fn overlapping_signals_count_combined_edges_only() {
        // Occupancy enters first, then power joins, then occupancy
        // recovers: the mode must hold (power still over budget) and
        // the recovery is not a counted transition.
        let c = controller();
        let mut at = clock();
        c.observe(1.0, at(0));
        assert_eq!(c.observe(1.0, at(100)), (true, true));
        assert_eq!(c.transitions(), 1);
        assert!(!c.set_power(true), "already degraded — no combined edge");
        assert_eq!(c.transitions(), 1);
        // Occupancy half recovers (calm past exit dwell)...
        c.observe(0.1, at(110));
        assert_eq!(c.observe(0.1, at(310)), (true, false), "power still holds the mode");
        // ...and only the power release ends the degraded stretch.
        assert!(c.set_power(false));
        assert!(!c.is_degraded());
        assert_eq!(c.transitions(), 2);
    }

    #[test]
    fn policy_validation_rejects_inverted_band() {
        assert!(DegradePolicy::default().validate().is_ok());
        let bad = DegradePolicy { enter_occupancy: 0.3, exit_occupancy: 0.5, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = DegradePolicy { enter_occupancy: 1.5, ..Default::default() };
        assert!(bad.validate().is_err());
    }
}
