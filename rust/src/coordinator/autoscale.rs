//! Power-budget replica autoscaling: a feedback controller that samples
//! each pool's queue occupancy on a fixed cadence and resizes its
//! replica set within a configured band ([`Coordinator::scale_to`]),
//! plus a server-wide power budget that trades accuracy for watts
//! before any request is shed.
//!
//! Two nested control loops, mirroring the paper's precision-for-power
//! dial at the fleet level:
//!
//! * **Replica loop** — per pool, a hysteresis controller
//!   ([`PoolScaler`]): occupancy sustained above the scale-up threshold
//!   for a dwell grows the pool by one replica; sustained below the
//!   scale-down threshold shrinks it. A cooldown between actions keeps
//!   a square-wave load from flapping the replica count, and shrinking
//!   retires workers gracefully — a retired worker finishes the batch
//!   it already claimed, so scale-down mid-traffic never loses a
//!   response.
//! * **Power loop** — the modeled board draw (static + windowed dynamic
//!   from the energy model) is compared against `--power-budget-w`
//!   through its own hysteresis ([`BudgetGate`]). Overshooting the
//!   budget for a dwell latches the *power* half of every route's
//!   degrade mode ([`super::degrade::DegradeController::set_power`]),
//!   re-routing `BACKEND_ANY` traffic to the cheapest (lowest-bit)
//!   pool; recovering at-or-under budget for the dwell releases it.
//!   Degradation fires before load shedding by construction: it is a
//!   routing decision made at admission, not a rejection.
//!
//! The decision cores ([`PoolScaler`], [`BudgetGate`]) are pure state
//! machines over explicit `now` instants, tested with a synthetic
//! clock; [`Autoscaler`] is the thin sampling thread around them.

use super::server::Coordinator;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Replica-band and controller knobs for one [`Autoscaler`].
#[derive(Debug, Clone, Copy)]
pub struct AutoscalePolicy {
    /// Replica floor per scalable pool (≥ 1).
    pub min: usize,
    /// Replica ceiling per scalable pool (≥ `min`).
    pub max: usize,
    /// Grow when occupancy stays `>= scale_up_occupancy` for `dwell`.
    pub scale_up_occupancy: f64,
    /// Shrink when occupancy stays `<= scale_down_occupancy` for
    /// `dwell`. Must sit below `scale_up_occupancy` so a hysteresis
    /// band exists.
    pub scale_down_occupancy: f64,
    /// How long a stretch must hold before the controller acts on it.
    pub dwell: Duration,
    /// Minimum spacing between two scaling actions on one pool —
    /// the flap-resistance knob.
    pub cooldown: Duration,
    /// Sampling cadence of the autoscaler thread.
    pub sample_every: Duration,
}

impl AutoscalePolicy {
    /// Default controller knobs over an explicit `[min, max]` band.
    pub fn band(min: usize, max: usize) -> AutoscalePolicy {
        AutoscalePolicy {
            min,
            max,
            scale_up_occupancy: 0.5,
            scale_down_occupancy: 0.05,
            dwell: Duration::from_millis(300),
            cooldown: Duration::from_secs(1),
            sample_every: Duration::from_millis(100),
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.min == 0 {
            return Err("autoscale min replicas must be >= 1".into());
        }
        if self.max < self.min {
            return Err(format!(
                "autoscale max replicas {} must be >= min {}",
                self.max, self.min
            ));
        }
        if !(0.0..=1.0).contains(&self.scale_up_occupancy)
            || !(0.0..=1.0).contains(&self.scale_down_occupancy)
        {
            return Err("autoscale occupancy thresholds must be in [0, 1]".into());
        }
        if self.scale_down_occupancy >= self.scale_up_occupancy {
            return Err(format!(
                "autoscale scale-down occupancy {} must be below scale-up occupancy {} \
                 (no hysteresis band)",
                self.scale_down_occupancy, self.scale_up_occupancy
            ));
        }
        if self.sample_every.is_zero() {
            return Err("autoscale sample interval must be nonzero".into());
        }
        Ok(())
    }
}

/// What one occupancy sample asks the coordinator to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Up,
    Down,
    Hold,
}

/// Per-pool replica controller: double hysteresis (threshold band +
/// dwell) plus an action cooldown. Pure — every input arrives as an
/// explicit sample, so tests drive it with a synthetic clock.
#[derive(Debug)]
pub struct PoolScaler {
    policy: AutoscalePolicy,
    over_since: Option<Instant>,
    under_since: Option<Instant>,
    last_action: Option<Instant>,
}

impl PoolScaler {
    pub fn new(policy: AutoscalePolicy) -> PoolScaler {
        PoolScaler { policy, over_since: None, under_since: None, last_action: None }
    }

    fn cooled(&self, now: Instant) -> bool {
        match self.last_action {
            Some(t) => now.saturating_duration_since(t) >= self.policy.cooldown,
            None => true,
        }
    }

    /// Feed one occupancy sample for a pool currently at `replicas`
    /// active workers. `Up`/`Down` means the caller should resize by
    /// one replica now; the scaler has already started its cooldown.
    pub fn decide(&mut self, occupancy: f64, replicas: usize, now: Instant) -> ScaleDecision {
        let p = self.policy;
        if occupancy >= p.scale_up_occupancy {
            self.under_since = None;
            let start = *self.over_since.get_or_insert(now);
            if now.saturating_duration_since(start) >= p.dwell
                && replicas < p.max
                && self.cooled(now)
            {
                self.over_since = None;
                self.last_action = Some(now);
                return ScaleDecision::Up;
            }
        } else if occupancy <= p.scale_down_occupancy {
            self.over_since = None;
            let start = *self.under_since.get_or_insert(now);
            if now.saturating_duration_since(start) >= p.dwell
                && replicas > p.min
                && self.cooled(now)
            {
                self.under_since = None;
                self.last_action = Some(now);
                return ScaleDecision::Down;
            }
        } else {
            // Inside the hysteresis band: neither stretch accumulates.
            self.over_since = None;
            self.under_since = None;
        }
        ScaleDecision::Hold
    }
}

/// Hysteresis over the power budget: strictly over budget for `dwell`
/// latches degraded; at-or-under budget for `dwell` releases it. Draw
/// exactly at the budget is *within* it — a server running precisely
/// at its cap is compliant, not degraded.
#[derive(Debug)]
pub struct BudgetGate {
    budget_w: f64,
    dwell: Duration,
    over_since: Option<Instant>,
    under_since: Option<Instant>,
    degraded: bool,
}

impl BudgetGate {
    pub fn new(budget_w: f64, dwell: Duration) -> BudgetGate {
        BudgetGate { budget_w, dwell, over_since: None, under_since: None, degraded: false }
    }

    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Feed one power sample; returns the (possibly newly flipped)
    /// degraded flag.
    pub fn observe(&mut self, watts: f64, now: Instant) -> bool {
        if !self.degraded {
            if watts > self.budget_w {
                let start = *self.over_since.get_or_insert(now);
                if now.saturating_duration_since(start) >= self.dwell {
                    self.degraded = true;
                    self.over_since = None;
                }
            } else {
                self.over_since = None;
            }
        } else if watts <= self.budget_w {
            let start = *self.under_since.get_or_insert(now);
            if now.saturating_duration_since(start) >= self.dwell {
                self.degraded = false;
                self.under_since = None;
            }
        } else {
            self.under_since = None;
        }
        self.degraded
    }
}

/// Shared counters the autoscaler thread maintains and the metrics /
/// health endpoints export. All relaxed atomics — they are telemetry,
/// not synchronization.
#[derive(Debug, Default)]
pub struct AutoscaleStats {
    pub scale_ups: AtomicU64,
    pub scale_downs: AtomicU64,
    /// Modeled board draw at the last sample, milliwatts.
    pub power_mw: AtomicU64,
    /// Configured power budget, milliwatts (0 = no budget).
    pub budget_mw: AtomicU64,
    pub power_degraded: AtomicBool,
    pub samples: AtomicU64,
}

/// Callbacks wiring the autoscaler to the serving layer without a
/// dependency cycle: the server owns the energy model and the routes,
/// the autoscaler owns the control loop.
pub struct AutoscaleHooks {
    /// Returns the modeled board draw (static + windowed dynamic) in
    /// watts. Called once per sample when a budget is configured.
    pub power_watts: Box<dyn FnMut() -> f64 + Send>,
    /// Latch (`true`) or release (`false`) the power half of every
    /// route's degrade mode. Called only on budget-gate edges.
    pub set_power_degraded: Box<dyn FnMut(bool) + Send>,
}

impl AutoscaleHooks {
    /// No-op hooks for budget-less autoscaling (and tests).
    pub fn disabled() -> AutoscaleHooks {
        AutoscaleHooks {
            power_watts: Box::new(|| 0.0),
            set_power_degraded: Box::new(|_| {}),
        }
    }
}

/// The sampling thread. Holds the coordinator behind an `Arc`; stop it
/// with [`Autoscaler::shutdown`] (or Drop) *before* the coordinator is
/// shut down for a clean exit, though a closed coordinator is also
/// harmless — `scale_to` keeps working on closed queues.
pub struct Autoscaler {
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<JoinHandle<()>>>,
    stats: Arc<AutoscaleStats>,
    policy: AutoscalePolicy,
    budget_w: Option<f64>,
}

impl Autoscaler {
    /// Clamp every scalable pool into `[min, max]` immediately, then
    /// start the sampling thread.
    pub fn spawn(
        coord: Arc<Coordinator>,
        policy: AutoscalePolicy,
        budget_w: Option<f64>,
        mut hooks: AutoscaleHooks,
    ) -> Result<Autoscaler> {
        policy.validate().map_err(anyhow::Error::msg)?;
        for i in 0..coord.num_pools() {
            if coord.scalable(i) {
                let r = coord.pool_replicas(i).unwrap_or(1);
                let target = r.clamp(policy.min, policy.max);
                if target != r {
                    coord
                        .scale_to(i, target)
                        .with_context(|| format!("clamp pool {i} into autoscale band"))?;
                }
            }
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(AutoscaleStats::default());
        stats
            .budget_mw
            .store(budget_w.map(|w| (w * 1e3) as u64).unwrap_or(0), Ordering::Relaxed);
        let handle = {
            let stop = stop.clone();
            let stats = stats.clone();
            std::thread::Builder::new()
                .name("edgemlp-autoscale".into())
                .spawn(move || {
                    let mut scalers: Vec<PoolScaler> =
                        (0..coord.num_pools()).map(|_| PoolScaler::new(policy)).collect();
                    let mut gate = budget_w.map(|b| BudgetGate::new(b, policy.dwell));
                    let mut degraded = false;
                    while !stop.load(Ordering::Acquire) {
                        std::thread::sleep(policy.sample_every);
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        let now = Instant::now();
                        let cap = coord.queue_capacity().max(1) as f64;
                        for (i, scaler) in scalers.iter_mut().enumerate() {
                            if !coord.scalable(i) {
                                continue;
                            }
                            let depth = coord.queue_depth(i).unwrap_or(0) as f64;
                            let replicas = coord.pool_replicas(i).unwrap_or(1);
                            match scaler.decide(depth / cap, replicas, now) {
                                ScaleDecision::Up => {
                                    if coord.scale_to(i, replicas + 1).is_ok() {
                                        stats.scale_ups.fetch_add(1, Ordering::Relaxed);
                                        coord.trace_scale_event(i, "scale_up");
                                    }
                                }
                                ScaleDecision::Down => {
                                    if coord.scale_to(i, replicas - 1).is_ok() {
                                        stats.scale_downs.fetch_add(1, Ordering::Relaxed);
                                        coord.trace_scale_event(i, "scale_down");
                                    }
                                }
                                ScaleDecision::Hold => {}
                            }
                        }
                        if let Some(gate) = gate.as_mut() {
                            let watts = (hooks.power_watts)();
                            stats.power_mw.store((watts.max(0.0) * 1e3) as u64, Ordering::Relaxed);
                            let deg = gate.observe(watts, now);
                            if deg != degraded {
                                degraded = deg;
                                (hooks.set_power_degraded)(deg);
                                stats.power_degraded.store(deg, Ordering::Relaxed);
                            }
                        }
                        stats.samples.fetch_add(1, Ordering::Relaxed);
                    }
                })
                .context("spawn autoscaler thread")?
        };
        Ok(Autoscaler { stop, handle: Mutex::new(Some(handle)), stats, policy, budget_w })
    }

    pub fn stats(&self) -> Arc<AutoscaleStats> {
        self.stats.clone()
    }

    pub fn policy(&self) -> AutoscalePolicy {
        self.policy
    }

    pub fn budget_w(&self) -> Option<f64> {
        self.budget_w
    }

    /// Stop the sampling thread and join it. Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Backend, FnBackend};
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::server::{
        CoordinatorConfig, PoolSpec, SharedBackendFactory,
    };

    fn policy() -> AutoscalePolicy {
        AutoscalePolicy {
            min: 1,
            max: 4,
            scale_up_occupancy: 0.5,
            scale_down_occupancy: 0.1,
            dwell: Duration::from_millis(300),
            cooldown: Duration::from_secs(2),
            sample_every: Duration::from_millis(100),
        }
    }

    /// Synthetic clock, as in the degrade controller tests.
    fn clock() -> impl FnMut(u64) -> Instant {
        let epoch = Instant::now();
        move |ms| epoch + Duration::from_millis(ms)
    }

    #[test]
    fn scaler_grows_after_sustained_saturation_only() {
        let mut s = PoolScaler::new(policy());
        let mut at = clock();
        assert_eq!(s.decide(0.9, 1, at(0)), ScaleDecision::Hold);
        assert_eq!(s.decide(0.9, 1, at(200)), ScaleDecision::Hold); // < dwell
        assert_eq!(s.decide(0.2, 1, at(250)), ScaleDecision::Hold); // stretch reset
        assert_eq!(s.decide(0.9, 1, at(300)), ScaleDecision::Hold);
        assert_eq!(s.decide(0.9, 1, at(650)), ScaleDecision::Up); // 350 ms sustained
        // Cooldown gates the next action even under sustained load.
        assert_eq!(s.decide(0.9, 2, at(1100)), ScaleDecision::Hold);
        assert_eq!(s.decide(0.9, 2, at(2700)), ScaleDecision::Up); // cooled + dwelled
    }

    #[test]
    fn scaler_shrinks_after_sustained_idle_and_respects_floor() {
        let mut s = PoolScaler::new(policy());
        let mut at = clock();
        assert_eq!(s.decide(0.0, 3, at(0)), ScaleDecision::Hold);
        assert_eq!(s.decide(0.05, 3, at(350)), ScaleDecision::Down);
        // At the floor, idle never shrinks further.
        let mut s = PoolScaler::new(policy());
        assert_eq!(s.decide(0.0, 1, at(1000)), ScaleDecision::Hold);
        assert_eq!(s.decide(0.0, 1, at(5000)), ScaleDecision::Hold);
    }

    #[test]
    fn scaler_holds_at_ceiling_and_with_min_equals_max() {
        let mut s = PoolScaler::new(policy());
        let mut at = clock();
        assert_eq!(s.decide(1.0, 4, at(0)), ScaleDecision::Hold);
        assert_eq!(s.decide(1.0, 4, at(1000)), ScaleDecision::Hold); // at max
        // min == max: a degenerate band never acts in either direction.
        let fixed = AutoscalePolicy { min: 2, max: 2, ..policy() };
        let mut s = PoolScaler::new(fixed);
        for t in 0..20u64 {
            let occ = if t < 10 { 1.0 } else { 0.0 };
            assert_eq!(s.decide(occ, 2, at(t * 500)), ScaleDecision::Hold, "t={t}");
        }
    }

    #[test]
    fn cooldown_bounds_actions_under_square_wave_load() {
        // Occupancy square wave: 600 ms at 0.9, 600 ms at 0.0, sampled
        // every 100 ms for 12 s. Each half-period outlasts the 300 ms
        // dwell, so a cooldown-less controller would act ~every half
        // period (~20 times). The 2 s cooldown bounds it to ≤ 7.
        let mut s = PoolScaler::new(policy());
        let mut at = clock();
        let mut replicas = 2usize;
        let mut actions = 0u32;
        for tick in 0..120u64 {
            let ms = tick * 100;
            let occ = if (ms / 600) % 2 == 0 { 0.9 } else { 0.0 };
            match s.decide(occ, replicas, at(ms)) {
                ScaleDecision::Up => {
                    replicas += 1;
                    actions += 1;
                }
                ScaleDecision::Down => {
                    replicas -= 1;
                    actions += 1;
                }
                ScaleDecision::Hold => {}
            }
            assert!((1..=4).contains(&replicas), "left the band at {replicas}");
        }
        assert!(actions >= 1, "controller never acted");
        assert!(actions <= 7, "{actions} actions in 12 s despite a 2 s cooldown");
    }

    #[test]
    fn budget_gate_is_exact_at_the_boundary() {
        let mut g = BudgetGate::new(5.0, Duration::from_millis(300));
        let mut at = clock();
        // Draw exactly at the budget, indefinitely: never degraded.
        for t in 0..20u64 {
            assert!(!g.observe(5.0, at(t * 100)), "t={t}");
        }
        // Strictly over, sustained: degraded after the dwell.
        assert!(!g.observe(5.001, at(3000)));
        assert!(!g.observe(5.001, at(3200)));
        assert!(g.observe(5.001, at(3350)));
        // Back to exactly at budget: that counts as compliant and
        // releases after the dwell.
        assert!(g.observe(5.0, at(3400)));
        assert!(!g.observe(5.0, at(3750)));
    }

    #[test]
    fn budget_gate_flicker_does_not_flip() {
        let mut g = BudgetGate::new(5.0, Duration::from_millis(300));
        let mut at = clock();
        for t in 0..30u64 {
            let w = if t % 2 == 0 { 6.0 } else { 4.0 };
            assert!(!g.observe(w, at(t * 100)), "flickering draw latched at t={t}");
        }
    }

    #[test]
    fn policy_validation_rejects_bad_bands() {
        assert!(AutoscalePolicy::band(1, 4).validate().is_ok());
        assert!(AutoscalePolicy::band(2, 2).validate().is_ok());
        assert!(AutoscalePolicy::band(0, 4).validate().is_err());
        assert!(AutoscalePolicy::band(4, 1).validate().is_err());
        let p = AutoscalePolicy { scale_down_occupancy: 0.8, ..AutoscalePolicy::band(1, 4) };
        assert!(p.validate().is_err());
        let p = AutoscalePolicy { sample_every: Duration::ZERO, ..AutoscalePolicy::band(1, 4) };
        assert!(p.validate().is_err());
    }

    fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        cond()
    }

    #[test]
    fn autoscaler_tracks_load_and_budget_end_to_end() {
        // Workers block until the gate opens, so a flooded queue pins
        // occupancy at ~1.0 (scale up to max); opening the gate drains
        // it to 0.0 (scale back down to min). The power probe is a
        // shared cell, so the budget crossing is equally deterministic.
        let gate = Arc::new(AtomicBool::new(false));
        let factory: SharedBackendFactory = {
            let gate = gate.clone();
            Arc::new(move || {
                let gate = gate.clone();
                Ok(Box::new(FnBackend::new("pool", 1, move |inputs: &[Vec<f32>]| {
                    while !gate.load(Ordering::Acquire) {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Ok(inputs.to_vec())
                })) as Box<dyn Backend>)
            })
        };
        let coord = Arc::new(
            crate::coordinator::server::Coordinator::start(
                vec![PoolSpec::replicated("pool", 1, factory)],
                CoordinatorConfig { queue_capacity: 8, policy: BatchPolicy::immediate(1) },
            )
            .unwrap(),
        );
        let policy = AutoscalePolicy {
            min: 1,
            max: 3,
            scale_up_occupancy: 0.5,
            scale_down_occupancy: 0.1,
            dwell: Duration::from_millis(40),
            cooldown: Duration::from_millis(60),
            sample_every: Duration::from_millis(20),
        };
        let power = Arc::new(Mutex::new(10.0f64)); // over the 5 W budget
        let degraded_seen = Arc::new(AtomicBool::new(false));
        let hooks = AutoscaleHooks {
            power_watts: {
                let p = power.clone();
                Box::new(move || *p.lock().unwrap())
            },
            set_power_degraded: {
                let d = degraded_seen.clone();
                Box::new(move |on| d.store(on, Ordering::Release))
            },
        };
        let scaler = Autoscaler::spawn(coord.clone(), policy, Some(5.0), hooks).unwrap();
        // Flood: one request wedges each worker, the rest park in the
        // queue and hold occupancy over the scale-up threshold.
        let receivers: Vec<_> =
            (0..8).filter_map(|i| coord.try_submit_to(0, vec![i as f32]).ok()).collect();
        assert!(
            wait_until(Duration::from_secs(10), || coord.pool_replicas(0) == Some(3)),
            "never scaled up to max (replicas {:?})",
            coord.pool_replicas(0)
        );
        assert!(
            wait_until(Duration::from_secs(10), || degraded_seen.load(Ordering::Acquire)),
            "10 W draw against a 5 W budget never degraded"
        );
        // Open the gate: the queue drains, idle dwell shrinks the pool
        // back to the floor.
        gate.store(true, Ordering::Release);
        for rx in receivers {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        assert!(
            wait_until(Duration::from_secs(10), || coord.pool_replicas(0) == Some(1)),
            "never scaled back down to min (replicas {:?})",
            coord.pool_replicas(0)
        );
        // Draw drops under budget: the degrade latch releases.
        *power.lock().unwrap() = 2.0;
        assert!(
            wait_until(Duration::from_secs(10), || !degraded_seen.load(Ordering::Acquire)),
            "under-budget draw never released the degrade latch"
        );
        let stats = scaler.stats();
        assert!(stats.scale_ups.load(Ordering::Relaxed) >= 2);
        assert!(stats.scale_downs.load(Ordering::Relaxed) >= 2);
        assert!(stats.samples.load(Ordering::Relaxed) > 0);
        assert_eq!(stats.budget_mw.load(Ordering::Relaxed), 5000);
        assert!(!stats.power_degraded.load(Ordering::Relaxed));
        scaler.shutdown();
        drop(scaler);
        Arc::try_unwrap(coord).ok().unwrap().shutdown();
    }
}
