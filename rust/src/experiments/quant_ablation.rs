//! E4 — quantization-scheme ablation (§3.2's argument quantified):
//! uniform vs PoT vs SP2 vs SPx(3) across bit budgets, reporting test
//! accuracy, SQNR, and the tail-region MSE where PoT is weakest.

use super::common::{trained_mnist_mlp, ExperimentScale, TrainedSetup};
use crate::bench_harness::Table;
use crate::nn::metrics::accuracy;
use crate::nn::Mlp;
use crate::quant::error::{sqnr_db, tail_split_mse};
use crate::quant::spx::{SpxConfig, SpxTensor};
use crate::quant::uniform::uniform;
use crate::quant::{fake_quantize, pot::pot, Calibration};

/// One (scheme, bits) cell.
#[derive(Debug, Clone)]
pub struct QuantRow {
    pub scheme: String,
    pub bits: u32,
    pub accuracy: f64,
    pub sqnr_db: f64,
    pub tail_mse: f64,
    /// Shift-adds per MAC this scheme costs in hardware (1 for
    /// uniform/PoT-style single-term, x for SPx).
    pub shifts_per_mac: usize,
}

/// Quantize every layer of `mlp` with `quantize` and return the copy.
fn quantize_model(mlp: &Mlp, quantize: &dyn Fn(&[f32]) -> Vec<f32>) -> Mlp {
    let mut q = mlp.clone();
    for layer in &mut q.layers {
        layer.w.data = quantize(&layer.w.data);
    }
    q
}

/// Weight-space error metrics of a quantized copy vs the original.
fn weight_metrics(original: &Mlp, quantized: &Mlp) -> (f64, f64) {
    let orig: Vec<f32> =
        original.layers.iter().flat_map(|l| l.w.data.iter().copied()).collect();
    let quant: Vec<f32> =
        quantized.layers.iter().flat_map(|l| l.w.data.iter().copied()).collect();
    let (tail, _, _) = tail_split_mse(&orig, &quant, 0.5);
    (sqnr_db(&orig, &quant), tail)
}

/// Run the ablation over `bits_range`.
pub fn run(scale: ExperimentScale, bits_range: &[u32]) -> Vec<QuantRow> {
    let setup: TrainedSetup = trained_mnist_mlp(scale);
    let mut rows = Vec::new();
    for &bits in bits_range {
        // (scheme name, quantizer fn, shift cost)
        type Quantizer<'a> = Box<dyn Fn(&[f32]) -> Vec<f32> + 'a>;
        let mut schemes: Vec<(String, Quantizer, usize)> = vec![(
            format!("uniform(b={bits})"),
            Box::new(move |w: &[f32]| fake_quantize(&uniform(bits), w, Calibration::MaxAbs)),
            1,
        )];
        if (2..=6).contains(&bits) {
            schemes.push((
                format!("pot(b={bits})"),
                Box::new(move |w: &[f32]| fake_quantize(&pot(bits), w, Calibration::MaxAbs)),
                1,
            ));
        }
        if bits >= 3 {
            schemes.push((
                format!("sp2(b={bits})"),
                Box::new(move |w: &[f32]| {
                    SpxTensor::encode(&SpxConfig::sp2(bits), w, &[w.len()], Calibration::MaxAbs)
                        .decode()
                }),
                2,
            ));
        }
        if bits >= 4 {
            schemes.push((
                format!("spx(b={bits},x=3)"),
                Box::new(move |w: &[f32]| {
                    SpxTensor::encode(
                        &SpxConfig::spx(bits, 3),
                        w,
                        &[w.len()],
                        Calibration::MaxAbs,
                    )
                    .decode()
                }),
                3,
            ));
        }
        for (name, quantize, shifts) in schemes {
            let q = quantize_model(&setup.mlp, quantize.as_ref());
            let acc = accuracy(&q, &setup.test_set.inputs, &setup.test_set.labels);
            let (sqnr, tail) = weight_metrics(&setup.mlp, &q);
            rows.push(QuantRow {
                scheme: name,
                bits,
                accuracy: acc,
                sqnr_db: sqnr,
                tail_mse: tail,
                shifts_per_mac: shifts,
            });
        }
    }
    rows
}

/// fp32 reference accuracy for the header line.
pub fn fp32_accuracy(scale: ExperimentScale) -> f64 {
    let setup = trained_mnist_mlp(scale);
    accuracy(&setup.mlp, &setup.test_set.inputs, &setup.test_set.labels)
}

pub fn render(rows: &[QuantRow], fp32_acc: f64) -> String {
    let mut table = Table::new(&[
        "scheme",
        "bits",
        "accuracy",
        "Δ vs fp32",
        "SQNR (dB)",
        "tail MSE",
        "shifts/MAC",
    ]);
    for r in rows {
        table.row(&[
            r.scheme.clone(),
            r.bits.to_string(),
            format!("{:.3}", r.accuracy),
            format!("{:+.3}", r.accuracy - fp32_acc),
            format!("{:.1}", r.sqnr_db),
            format!("{:.2e}", r.tail_mse),
            r.shifts_per_mac.to_string(),
        ]);
    }
    format!("fp32 reference accuracy: {fp32_acc:.3}\n{}", table.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spx_beats_pot_in_tail_mse_and_tracks_accuracy() {
        let scale = ExperimentScale { n_train: 500, n_test: 200, epochs: 2 };
        let rows = run(scale, &[5]);
        let find = |prefix: &str| rows.iter().find(|r| r.scheme.starts_with(prefix)).unwrap();
        let pot = find("pot");
        let sp2 = find("sp2");
        // §3.2's quantitative core: same bit budget, smaller tail error.
        assert!(
            sp2.tail_mse < pot.tail_mse,
            "sp2 tail {} vs pot {}",
            sp2.tail_mse,
            pot.tail_mse
        );
        // SQNR ordering follows.
        assert!(sp2.sqnr_db > pot.sqnr_db);
        // At b=5 neither scheme collapses accuracy by more than 25 pts
        // relative to uniform.
        let uni = find("uniform");
        assert!(sp2.accuracy > uni.accuracy - 0.25);
    }

    #[test]
    fn more_bits_never_hurt_sqnr() {
        let scale = ExperimentScale { n_train: 300, n_test: 100, epochs: 1 };
        let rows = run(scale, &[3, 5, 7]);
        let sp2: Vec<&QuantRow> =
            rows.iter().filter(|r| r.scheme.starts_with("sp2")).collect();
        assert!(sp2.windows(2).all(|w| w[1].sqnr_db >= w[0].sqnr_db - 0.5));
    }
}
