//! E4 — quantization-scheme ablation (§3.2's argument quantified):
//! uniform vs PoT vs SP2 vs SPx(3) across bit budgets, reporting test
//! accuracy, SQNR, and the tail-region MSE where PoT is weakest.

use super::common::{trained_mnist_mlp, ExperimentScale, TrainedSetup};
use crate::bench_harness::Table;
use crate::nn::metrics::{accuracy, accuracy_from_preds};
use crate::nn::mlp::argmax;
use crate::nn::vsq::{f32_weight_bytes, VsqMlp, DEFAULT_GROUP_ROWS};
use crate::nn::Mlp;
use crate::quant::error::{sqnr_db, tail_split_mse};
use crate::quant::spx::{SpxConfig, SpxTensor};
use crate::quant::uniform::uniform;
use crate::quant::{fake_quantize, pot::pot, Calibration};

/// One (scheme, bits) cell.
#[derive(Debug, Clone)]
pub struct QuantRow {
    pub scheme: String,
    pub bits: u32,
    pub accuracy: f64,
    pub sqnr_db: f64,
    pub tail_mse: f64,
    /// Shift-adds per MAC this scheme costs in hardware (1 for
    /// uniform/PoT-style single-term, x for SPx).
    pub shifts_per_mac: usize,
}

/// Quantize every layer of `mlp` with `quantize` and return the copy.
fn quantize_model(mlp: &Mlp, quantize: &dyn Fn(&[f32]) -> Vec<f32>) -> Mlp {
    let mut q = mlp.clone();
    for layer in &mut q.layers {
        layer.w.data = quantize(&layer.w.data);
    }
    q
}

/// Weight-space error metrics of a quantized copy vs the original.
fn weight_metrics(original: &Mlp, quantized: &Mlp) -> (f64, f64) {
    let orig: Vec<f32> =
        original.layers.iter().flat_map(|l| l.w.data.iter().copied()).collect();
    let quant: Vec<f32> =
        quantized.layers.iter().flat_map(|l| l.w.data.iter().copied()).collect();
    let (tail, _, _) = tail_split_mse(&orig, &quant, 0.5);
    (sqnr_db(&orig, &quant), tail)
}

/// Run the ablation over `bits_range`.
pub fn run(scale: ExperimentScale, bits_range: &[u32]) -> Vec<QuantRow> {
    let setup: TrainedSetup = trained_mnist_mlp(scale);
    let mut rows = Vec::new();
    for &bits in bits_range {
        // (scheme name, quantizer fn, shift cost)
        type Quantizer<'a> = Box<dyn Fn(&[f32]) -> Vec<f32> + 'a>;
        let mut schemes: Vec<(String, Quantizer, usize)> = vec![(
            format!("uniform(b={bits})"),
            Box::new(move |w: &[f32]| fake_quantize(&uniform(bits), w, Calibration::MaxAbs)),
            1,
        )];
        if (2..=6).contains(&bits) {
            schemes.push((
                format!("pot(b={bits})"),
                Box::new(move |w: &[f32]| fake_quantize(&pot(bits), w, Calibration::MaxAbs)),
                1,
            ));
        }
        if bits >= 3 {
            schemes.push((
                format!("sp2(b={bits})"),
                Box::new(move |w: &[f32]| {
                    SpxTensor::encode(&SpxConfig::sp2(bits), w, &[w.len()], Calibration::MaxAbs)
                        .decode()
                }),
                2,
            ));
        }
        if bits >= 4 {
            schemes.push((
                format!("spx(b={bits},x=3)"),
                Box::new(move |w: &[f32]| {
                    SpxTensor::encode(
                        &SpxConfig::spx(bits, 3),
                        w,
                        &[w.len()],
                        Calibration::MaxAbs,
                    )
                    .decode()
                }),
                3,
            ));
        }
        for (name, quantize, shifts) in schemes {
            let q = quantize_model(&setup.mlp, quantize.as_ref());
            let acc = accuracy(&q, &setup.test_set.inputs, &setup.test_set.labels);
            let (sqnr, tail) = weight_metrics(&setup.mlp, &q);
            rows.push(QuantRow {
                scheme: name,
                bits,
                accuracy: acc,
                sqnr_db: sqnr,
                tail_mse: tail,
                shifts_per_mac: shifts,
            });
        }
    }
    rows
}

/// fp32 reference accuracy for the header line.
pub fn fp32_accuracy(scale: ExperimentScale) -> f64 {
    let setup = trained_mnist_mlp(scale);
    accuracy(&setup.mlp, &setup.test_set.inputs, &setup.test_set.labels)
}

/// One serving-precision cell of the accuracy-vs-bits ablation: unlike
/// [`QuantRow`] (weight-only fake quantization), these rows run the
/// ACTUAL serving datapaths end to end — the VSQ rows quantize
/// activations to int8 per layer exactly as the int8/int4 pools do.
#[derive(Debug, Clone)]
pub struct PrecisionRow {
    /// Serving-precision label (`f32`/`spx`/`int8`/`int4`).
    pub precision: String,
    pub accuracy: f64,
    /// Weight bytes streamed per served sample at this precision.
    pub bytes_per_sample: u64,
}

/// Accuracy vs serving precision on the MNIST head: f32, SPx (sp2 b=5,
/// the serving default), and VSQ int8/int4 with per-row-group scales,
/// each through its real forward path (EXPERIMENTS.md §Quantized
/// serving). Returns `(fp32_accuracy, rows)`.
pub fn run_precision_modes(scale: ExperimentScale) -> (f64, Vec<PrecisionRow>) {
    let setup: TrainedSetup = trained_mnist_mlp(scale);
    let test = &setup.test_set;
    let fp32 = accuracy(&setup.mlp, &test.inputs, &test.labels);
    let mut rows =
        vec![PrecisionRow {
            precision: "f32".into(),
            accuracy: fp32,
            bytes_per_sample: f32_weight_bytes(&setup.mlp),
        }];

    // SPx at the serving default (sp2, b=5): weight-only, the FPGA-sim
    // pool decodes to f32 before the MAC.
    let spx = quantize_model(&setup.mlp, &|w: &[f32]| {
        SpxTensor::encode(&SpxConfig::sp2(5), w, &[w.len()], Calibration::MaxAbs).decode()
    });
    let spx_bits = crate::fpga::accelerator::QuantizedMlp::from_mlp(
        &setup.mlp,
        &SpxConfig::sp2(5),
        Calibration::MaxAbs,
        None,
    )
    .weight_bits();
    let spx_bias: u64 = setup.mlp.layers.iter().map(|l| 4 * l.b.len() as u64).sum();
    rows.push(PrecisionRow {
        precision: "spx".into(),
        accuracy: accuracy(&spx, &test.inputs, &test.labels),
        bytes_per_sample: spx_bits.div_ceil(8) + spx_bias,
    });

    // VSQ int8/int4: weights AND activations quantized, the real
    // integer kernel end to end.
    for bits in [8u8, 4] {
        let v = VsqMlp::from_mlp(&setup.mlp, bits, DEFAULT_GROUP_ROWS, Calibration::MaxAbs, None);
        let out = v.forward_batch(&test.inputs);
        let preds: Vec<usize> = (0..out.rows).map(|r| argmax(out.row(r))).collect();
        rows.push(PrecisionRow {
            precision: format!("int{bits}"),
            accuracy: accuracy_from_preds(&preds, &test.labels),
            bytes_per_sample: v.weight_bytes(),
        });
    }
    (fp32, rows)
}

pub fn render_precision_modes(fp32: f64, rows: &[PrecisionRow]) -> String {
    let mut table = Table::new(&["precision", "accuracy", "Δ vs f32", "bytes/sample", "vs f32"]);
    let f32_bytes = rows.first().map(|r| r.bytes_per_sample).unwrap_or(0);
    for r in rows {
        table.row(&[
            r.precision.clone(),
            format!("{:.3}", r.accuracy),
            format!("{:+.3}", r.accuracy - fp32),
            r.bytes_per_sample.to_string(),
            format!("{:.2}x", f32_bytes as f64 / r.bytes_per_sample.max(1) as f64),
        ]);
    }
    table.render()
}

pub fn render(rows: &[QuantRow], fp32_acc: f64) -> String {
    let mut table = Table::new(&[
        "scheme",
        "bits",
        "accuracy",
        "Δ vs fp32",
        "SQNR (dB)",
        "tail MSE",
        "shifts/MAC",
    ]);
    for r in rows {
        table.row(&[
            r.scheme.clone(),
            r.bits.to_string(),
            format!("{:.3}", r.accuracy),
            format!("{:+.3}", r.accuracy - fp32_acc),
            format!("{:.1}", r.sqnr_db),
            format!("{:.2e}", r.tail_mse),
            r.shifts_per_mac.to_string(),
        ]);
    }
    format!("fp32 reference accuracy: {fp32_acc:.3}\n{}", table.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spx_beats_pot_in_tail_mse_and_tracks_accuracy() {
        let scale = ExperimentScale { n_train: 500, n_test: 200, epochs: 2 };
        let rows = run(scale, &[5]);
        let find = |prefix: &str| rows.iter().find(|r| r.scheme.starts_with(prefix)).unwrap();
        let pot = find("pot");
        let sp2 = find("sp2");
        // §3.2's quantitative core: same bit budget, smaller tail error.
        assert!(
            sp2.tail_mse < pot.tail_mse,
            "sp2 tail {} vs pot {}",
            sp2.tail_mse,
            pot.tail_mse
        );
        // SQNR ordering follows.
        assert!(sp2.sqnr_db > pot.sqnr_db);
        // At b=5 neither scheme collapses accuracy by more than 25 pts
        // relative to uniform.
        let uni = find("uniform");
        assert!(sp2.accuracy > uni.accuracy - 0.25);
    }

    #[test]
    fn int8_precision_mode_holds_fp32_accuracy_within_one_point() {
        // The tentpole acceptance criterion: the end-to-end int8 VSQ
        // datapath (weights AND activations quantized) stays within
        // 1% of f32 on the MNIST head, and the bytes column orders
        // int4 < int8 < f32 with spx < f32.
        let scale = ExperimentScale { n_train: 800, n_test: 300, epochs: 3 };
        let (fp32, rows) = run_precision_modes(scale);
        let find = |p: &str| rows.iter().find(|r| r.precision == p).unwrap();
        let i8r = find("int8");
        assert!(
            (fp32 - i8r.accuracy).abs() <= 0.01,
            "int8 accuracy {} drifted more than 1% from f32 {}",
            i8r.accuracy,
            fp32
        );
        // int4 may lose accuracy but must still beat chance by a wide
        // margin on 10 classes.
        assert!(find("int4").accuracy > 0.5, "int4 collapsed: {}", find("int4").accuracy);
        let bytes = |p: &str| find(p).bytes_per_sample;
        assert!(bytes("int4") < bytes("int8"));
        assert!(bytes("int8") < bytes("f32"));
        assert!(bytes("spx") < bytes("f32"));
    }

    #[test]
    fn more_bits_never_hurt_sqnr() {
        let scale = ExperimentScale { n_train: 300, n_test: 100, epochs: 1 };
        let rows = run(scale, &[3, 5, 7]);
        let sp2: Vec<&QuantRow> =
            rows.iter().filter(|r| r.scheme.starts_with("sp2")).collect();
        assert!(sp2.windows(2).all(|w| w[1].sqnr_db >= w[0].sqnr_db - 0.5));
    }
}
