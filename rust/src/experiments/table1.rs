//! E1 — Table I: time per sample and power for CPU / GPU / FPGA on the
//! handwritten-digit task.
//!
//! Substitutions (DESIGN.md §5): the "GPU" row is the batched XLA/PJRT
//! executable (a throughput-optimized batch device), its wattage and the
//! CPU's are the paper's own wall measurements imported as constants;
//! the "FPGA" row is the cycle-accurate simulator at the configured
//! compute clock with the activity-based power model.

use super::common::{sci, trained_mnist_mlp, ExperimentScale};
use crate::bench_harness::{bench, BenchConfig, Table};
use crate::data::batch::gather;
use crate::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use crate::fpga::power::PlatformPower;
use crate::fpga::stats::CycleStats;
use crate::nn::metrics::{accuracy, accuracy_from_preds};
use crate::nn::mlp::argmax;
use crate::quant::spx::SpxConfig;
use crate::quant::Calibration;
use crate::runtime::executable::mlp_fp32_inputs;
use crate::runtime::{Registry, Runtime};
use anyhow::Result;

/// One device row of Table I.
#[derive(Debug, Clone)]
pub struct DeviceRow {
    pub device: String,
    pub time_per_sample_s: f64,
    pub power_w: f64,
    pub accuracy: f64,
    /// The paper's measured values for the same row, for the ratio
    /// column.
    pub paper_time_s: f64,
    pub paper_power_w: f64,
}

/// Result of the full experiment.
pub struct Table1 {
    pub rows: Vec<DeviceRow>,
}

/// Run E1. `artifacts_dir` optional: without it the GPU/XLA row is
/// skipped (e.g. before `make artifacts`).
pub fn run(scale: ExperimentScale, with_xla: bool) -> Result<Table1> {
    let setup = trained_mnist_mlp(scale);
    let bench_cfg = BenchConfig::from_env();
    let platform = PlatformPower::paper_measured();
    let mut rows = Vec::new();

    // --- CPU row: batched rust forward (batch 64, per §4.4.A) through
    // the blocked GEMM + reusable scratch, so the row measures the
    // kernel rather than allocator churn (EXPERIMENTS.md §Perf). ---
    let batch = 64.min(setup.test_set.len());
    let idx: Vec<usize> = (0..batch).collect();
    let x64 = gather(&setup.test_set.inputs, &idx);
    let mut scratch = crate::nn::mlp::ForwardScratch::new();
    let timing = bench("cpu", bench_cfg, || setup.mlp.forward_with(&x64, &mut scratch).data[0]);
    let cpu_acc = accuracy(&setup.mlp, &setup.test_set.inputs, &setup.test_set.labels);
    rows.push(DeviceRow {
        device: "CPU".into(),
        time_per_sample_s: timing.mean_s() / batch as f64,
        power_w: platform.cpu_w,
        accuracy: cpu_acc,
        paper_time_s: 2.6e-3,
        paper_power_w: 47.2,
    });

    // --- GPU row: batched XLA/PJRT artifact. ---
    if with_xla {
        let runtime = Runtime::new(Registry::open_default()?)?;
        let model = runtime.load("mlp_fp32_b64")?;
        // The artifact's batch is fixed at 64; pad if the test set is
        // smaller (scale.quick never goes below 64 in practice).
        let mut flat = x64.data.clone();
        flat.resize(64 * 784, 0.0);
        let inputs = mlp_fp32_inputs(&setup.mlp, &flat);
        let timing = bench("xla", bench_cfg, || model.run(&inputs).expect("xla run"));
        // Accuracy through the artifact on the test set (chunked by 64).
        let mut preds = Vec::new();
        for chunk_start in (0..setup.test_set.len()).step_by(64) {
            let end = (chunk_start + 64).min(setup.test_set.len());
            let idx: Vec<usize> = (chunk_start..end).collect();
            let mut chunk = gather(&setup.test_set.inputs, &idx).data;
            chunk.resize(64 * 784, 0.0);
            let out = model.run(&mlp_fp32_inputs(&setup.mlp, &chunk))?;
            for r in 0..(end - chunk_start) {
                preds.push(argmax(&out[r * 10..(r + 1) * 10]));
            }
        }
        let xla_acc = accuracy_from_preds(&preds, &setup.test_set.labels);
        rows.push(DeviceRow {
            device: "GPU (XLA sub)".into(),
            time_per_sample_s: timing.mean_s() / 64.0,
            power_w: platform.gpu_w,
            accuracy: xla_acc,
            paper_time_s: 3e-4,
            paper_power_w: 115.2,
        });
    }

    // --- FPGA row: cycle-accurate simulator, SP2 b=5 quantization. ---
    let q = QuantizedMlp::from_mlp(
        &setup.mlp,
        &SpxConfig::sp2(5),
        Calibration::MaxAbs,
        Some(&setup.train_set.inputs),
    );
    let accel = Accelerator::new(q, AccelConfig::default_fpga());
    let n_eval = setup.test_set.len().min(if scale.n_test > 500 { 300 } else { 100 });
    let mut stats = CycleStats::default();
    let mut correct = 0usize;
    for i in 0..n_eval {
        let (pred, s) = accel.classify_one(setup.test_set.inputs.row(i));
        stats.merge(&s);
        if pred == setup.test_set.labels[i] {
            correct += 1;
        }
    }
    let sim_time_total = accel.config.pipeline.clocks.cycles_to_seconds(stats.compute_cycles);
    let fpga_power = accel.config.energy.average_power_w(&stats, sim_time_total);
    rows.push(DeviceRow {
        device: "FPGA (sim)".into(),
        time_per_sample_s: sim_time_total / n_eval as f64,
        power_w: fpga_power,
        accuracy: correct as f64 / n_eval as f64,
        paper_time_s: 1.6e-6,
        paper_power_w: 10.0,
    });

    Ok(Table1 { rows })
}

/// Render like the paper's Table I, with ratio columns.
pub fn render(t: &Table1) -> String {
    let mut table = Table::new(&[
        "device",
        "time/sample (s)",
        "power (W)",
        "accuracy",
        "paper time (s)",
        "paper power (W)",
        "speedup vs CPU",
    ]);
    let cpu_time = t.rows[0].time_per_sample_s;
    for r in &t.rows {
        table.row(&[
            r.device.clone(),
            sci(r.time_per_sample_s),
            format!("{:.1}", r.power_w),
            format!("{:.3}", r.accuracy),
            sci(r.paper_time_s),
            format!("{:.1}", r.paper_power_w),
            format!("{:.0}x", cpu_time / r.time_per_sample_s),
        ]);
    }
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_holds_without_xla() {
        // The paper's qualitative claim: FPGA time/sample ≪ CPU, FPGA
        // power < CPU power. (XLA row needs artifacts; integration
        // tests cover it.)
        let t = run(
            ExperimentScale { n_train: 400, n_test: 150, epochs: 1 },
            false,
        )
        .unwrap();
        assert_eq!(t.rows.len(), 2);
        let cpu = &t.rows[0];
        let fpga = &t.rows[1];
        // Dev-profile CPU timing compresses the gap; release benches
        // show the full ratio (EXPERIMENTS.md E1).
        assert!(
            fpga.time_per_sample_s * 5.0 < cpu.time_per_sample_s,
            "FPGA {} vs CPU {}",
            fpga.time_per_sample_s,
            cpu.time_per_sample_s
        );
        assert!(fpga.power_w < cpu.power_w);
        // Quantized accuracy should not collapse.
        assert!(fpga.accuracy > cpu.accuracy - 0.2);
        // Render runs.
        assert!(render(&t).contains("FPGA"));
    }
}
