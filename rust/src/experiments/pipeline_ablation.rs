//! E3 — the §3.1 design claims, measured on the simulator:
//!
//! 1. pipelined vs serialized matmul (Fig 2's point);
//! 2. the dual-clock decoupling: sweep `clk_inbuff` (with fixed
//!    bandwidth) and watch stalls vanish once loading outruns compute —
//!    the paper's "feasible as long as data loading is faster";
//! 3. buffer capacity: how many rows of slack the decoupling needs;
//! 4. PU count: compute-parallelism scaling.

use crate::bench_harness::Table;
use crate::fpga::clock::ClockConfig;
use crate::fpga::pipeline::{run_matvec, run_matvec_unpipelined, PipelineConfig};
use crate::quant::spx::{SpxConfig, SpxTensor};
use crate::quant::Calibration;
use crate::util::rng::Pcg32;

/// One configuration's cycle outcome.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub label: String,
    pub compute_cycles: u64,
    pub stall_cycles: u64,
    pub macs_per_cycle: f64,
    pub buffer_peak_rows: u64,
}

pub struct PipelineAblation {
    pub pipelined_vs_serial: Vec<AblationRow>,
    pub clock_sweep: Vec<AblationRow>,
    pub buffer_sweep: Vec<AblationRow>,
    pub pu_sweep: Vec<AblationRow>,
}

fn layer_operands() -> (SpxTensor, Vec<f32>) {
    // The paper's hidden layer: 128×784 weights.
    let mut rng = Pcg32::new(3);
    let wdata: Vec<f32> = (0..128 * 784).map(|_| rng.normal() as f32 * 0.3).collect();
    let w = SpxTensor::encode(&SpxConfig::sp2(5), &wdata, &[128, 784], Calibration::MaxAbs);
    let d: Vec<f32> = (0..784).map(|_| rng.uniform() as f32).collect();
    (w, d)
}

fn row(label: impl Into<String>, stats: &crate::fpga::stats::CycleStats) -> AblationRow {
    AblationRow {
        label: label.into(),
        compute_cycles: stats.compute_cycles,
        stall_cycles: stats.stall_cycles,
        macs_per_cycle: stats.macs_per_cycle(),
        buffer_peak_rows: stats.buffer_peak_rows,
    }
}

/// Run the full ablation.
pub fn run() -> PipelineAblation {
    let (w, d) = layer_operands();
    let base = PipelineConfig::streaming();

    // 1. Pipelined vs serialized.
    let piped = run_matvec(&w, &d, 1.0, &base);
    let serial = run_matvec_unpipelined(&w, &d, 1.0, &base);
    let pipelined_vs_serial = vec![
        row("pipelined (§3.1)", &piped.stats),
        row("serialized baseline", &serial.stats),
    ];

    // 2. Load-clock sweep at fixed compute clock + bandwidth. 16 PUs
    // keep the aggregate demand (2 words/MAC × 16 MACs/cycle = 32 w/cc)
    // within reach of the fastest load clock, so the sweep crosses from
    // load-bound to stall-free — the §3.1 feasibility boundary.
    let clock_sweep = [3.0, 8.0, 16.0, 33.0, 66.0, 133.0]
        .iter()
        .map(|&inbuff_mhz| {
            let cfg = PipelineConfig {
                clocks: ClockConfig {
                    clk_inbuff_mhz: inbuff_mhz,
                    clk_compute_mhz: 100.0,
                    bandwidth_words: 32,
                },
                num_pus: 16,
                ..base
            };
            let r = run_matvec(&w, &d, 1.0, &cfg);
            row(
                format!(
                    "clk_inbuff {inbuff_mhz} MHz ({:.1} w/cc)",
                    cfg.clocks.words_per_compute_cycle()
                ),
                &r.stats,
            )
        })
        .collect();

    // 3. Buffer-capacity sweep under a moderately slow load clock.
    let buffer_sweep = [1usize, 2, 4, 8, 16, 64]
        .iter()
        .map(|&cap| {
            let cfg = PipelineConfig {
                clocks: ClockConfig {
                    clk_inbuff_mhz: 33.0,
                    clk_compute_mhz: 100.0,
                    bandwidth_words: 32,
                },
                buffer_capacity_rows: cap,
                ..base
            };
            let r = run_matvec(&w, &d, 1.0, &cfg);
            row(format!("buffer {cap} rows"), &r.stats)
        })
        .collect();

    // 4. PU-count sweep.
    let pu_sweep = [8usize, 16, 32, 64, 128]
        .iter()
        .map(|&pus| {
            let cfg = PipelineConfig { num_pus: pus, ..base };
            let r = run_matvec(&w, &d, 1.0, &cfg);
            row(format!("{pus} PUs"), &r.stats)
        })
        .collect();

    PipelineAblation { pipelined_vs_serial, clock_sweep, buffer_sweep, pu_sweep }
}

pub fn render_section(title: &str, rows: &[AblationRow]) -> String {
    let mut table = Table::new(&["config", "cycles", "stalls", "MACs/cycle", "peak rows"]);
    for r in rows {
        table.row(&[
            r.label.clone(),
            r.compute_cycles.to_string(),
            r.stall_cycles.to_string(),
            format!("{:.2}", r.macs_per_cycle),
            r.buffer_peak_rows.to_string(),
        ]);
    }
    format!("### {title}\n{}", table.render())
}

pub fn render(a: &PipelineAblation) -> String {
    [
        render_section("Pipelined vs serialized (Fig 2)", &a.pipelined_vs_serial),
        render_section("Load-clock sweep (dual-clock decoupling)", &a.clock_sweep),
        render_section("Input-buffer capacity", &a.buffer_sweep),
        render_section("PU count", &a.pu_sweep),
    ]
    .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_claims_hold() {
        let a = run();
        // Pipelining wins big.
        assert!(
            a.pipelined_vs_serial[0].compute_cycles * 4
                < a.pipelined_vs_serial[1].compute_cycles
        );
        // Faster load clock monotonically reduces cycles, and the
        // fastest configuration is effectively stall-free.
        let cycles: Vec<u64> = a.clock_sweep.iter().map(|r| r.compute_cycles).collect();
        assert!(cycles.windows(2).all(|w| w[1] <= w[0]), "{cycles:?}");
        let last = a.clock_sweep.last().unwrap();
        let first = &a.clock_sweep[0];
        // Startup transient (the first P rows arrive serially) leaves a
        // small residue; steady state is stall-free.
        assert!(
            last.stall_cycles as f64 <= 0.10 * last.compute_cycles as f64,
            "fastest load clock should be (near) stall-free: {last:?}"
        );
        assert!(first.stall_cycles > 10 * last.stall_cycles.max(1));
        // Bigger buffers help under a slow load clock.
        let buf: Vec<u64> = a.buffer_sweep.iter().map(|r| r.compute_cycles).collect();
        assert!(buf.windows(2).all(|w| w[1] <= w[0]), "{buf:?}");
        // More PUs never hurt.
        let pus: Vec<u64> = a.pu_sweep.iter().map(|r| r.compute_cycles).collect();
        assert!(pus.windows(2).all(|w| w[1] <= w[0]), "{pus:?}");
    }
}
