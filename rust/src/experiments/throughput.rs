//! E6 — serving throughput/latency of the coordinator under Poisson
//! load: the edge-deployment scenario (§1) quantified. Sweeps the
//! dynamic-batching window to expose the latency/throughput trade-off
//! Table I's CPU-batch-64 vs FPGA-stream rows embody. Both backends
//! dispatch whole batches through the blocked/batched kernels
//! (EXPERIMENTS.md §Perf), so a wider window buys real per-sample
//! savings rather than just amortized queue overhead.

use super::common::{sci, trained_mnist_mlp, ExperimentScale};
use crate::bench_harness::Table;
use crate::coordinator::backend::{Backend, CpuBackend, FpgaBackend};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::server::{BackendFactory, Coordinator, CoordinatorConfig};
use crate::data::batch::SampleStream;
use crate::fpga::accelerator::{AccelConfig, Accelerator, QuantizedMlp};
use crate::quant::spx::SpxConfig;
use crate::quant::Calibration;
use crate::util::rng::Pcg32;
use anyhow::Result;
use std::time::{Duration, Instant};

/// One (backend, policy, rate) measurement.
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    pub backend: String,
    pub window_ms: f64,
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub mean_batch: f64,
    pub shed: u64,
}

/// Drive `n_requests` Poisson arrivals at `rate_rps` into `backend_idx`.
fn drive(
    coord: &Coordinator,
    backend_idx: usize,
    stream: &mut SampleStream<'_>,
    rate_rps: f64,
    n_requests: usize,
    rng: &mut Pcg32,
) -> (Vec<f64>, u64, f64) {
    let mut receivers = Vec::with_capacity(n_requests);
    let mut shed = 0u64;
    let t0 = Instant::now();
    let mut next_arrival = 0.0f64;
    for _ in 0..n_requests {
        // Exponential inter-arrival times.
        let u: f64 = rng.uniform().max(1e-12);
        next_arrival += -u.ln() / rate_rps;
        let wait = next_arrival - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(wait));
        }
        let (payload, _) = stream.next_sample();
        match coord.try_submit_to(backend_idx, payload) {
            Ok(rx) => receivers.push(rx),
            Err(_) => shed += 1,
        }
    }
    let mut latencies = Vec::with_capacity(receivers.len());
    for rx in receivers {
        if let Ok(Ok(resp)) = rx.recv_timeout(Duration::from_secs(30)) {
            latencies.push(resp.latency_s);
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    (latencies, shed, elapsed)
}

/// Run the sweep with sizes derived from the environment.
pub fn run(scale: ExperimentScale) -> Result<Vec<ThroughputRow>> {
    run_with(scale, std::env::var("EDGEMLP_BENCH_QUICK").is_ok())
}

/// Run the sweep. Spawns a fresh coordinator per policy so histograms
/// do not mix.
pub fn run_with(scale: ExperimentScale, quick: bool) -> Result<Vec<ThroughputRow>> {
    let setup = trained_mnist_mlp(scale);
    let n_requests = if quick { 150 } else { 600 };
    let rates = if quick { vec![500.0] } else { vec![300.0, 1500.0] };
    let windows = [Duration::ZERO, Duration::from_millis(2)];

    let mut rows = Vec::new();
    for &window in &windows {
        for &rate in &rates {
            // Fresh backends per run.
            let mlp = setup.mlp.clone();
            let cpu_factory: BackendFactory =
                Box::new(move || Ok(Box::new(CpuBackend::new(mlp)) as Box<dyn Backend>));
            let q = QuantizedMlp::from_mlp(
                &setup.mlp,
                &SpxConfig::sp2(5),
                Calibration::MaxAbs,
                None,
            );
            let fpga_factory: BackendFactory = Box::new(move || {
                Ok(Box::new(FpgaBackend::new(Accelerator::new(q, AccelConfig::default_fpga())))
                    as Box<dyn Backend>)
            });
            let coord = Coordinator::start(
                vec![("cpu".into(), cpu_factory), ("fpga".into(), fpga_factory)],
                CoordinatorConfig {
                    queue_capacity: 256,
                    policy: BatchPolicy { max_batch: 64, max_wait: window },
                },
            )?;
            let mut rng = Pcg32::new(99);
            for backend in ["cpu", "fpga"] {
                let idx = coord.backend_index(backend).unwrap();
                let mut stream = SampleStream::new(&setup.test_set, 5);
                let (latencies, shed, elapsed) =
                    drive(&coord, idx, &mut stream, rate, n_requests, &mut rng);
                let snap = coord.metrics().snapshot();
                let m = &snap.backends[backend];
                rows.push(ThroughputRow {
                    backend: backend.into(),
                    window_ms: window.as_secs_f64() * 1e3,
                    offered_rps: rate,
                    achieved_rps: latencies.len() as f64 / elapsed,
                    p50_s: crate::util::percentile(&latencies, 50.0),
                    p99_s: crate::util::percentile(&latencies, 99.0),
                    mean_batch: m.mean_batch(),
                    shed,
                });
            }
            coord.shutdown();
        }
    }
    Ok(rows)
}

pub fn render(rows: &[ThroughputRow]) -> String {
    let mut table = Table::new(&[
        "backend",
        "window (ms)",
        "offered rps",
        "achieved rps",
        "p50",
        "p99",
        "mean batch",
        "shed",
    ]);
    for r in rows {
        table.row(&[
            r.backend.clone(),
            format!("{:.1}", r.window_ms),
            format!("{:.0}", r.offered_rps),
            format!("{:.0}", r.achieved_rps),
            sci(r.p50_s),
            sci(r.p99_s),
            format!("{:.1}", r.mean_batch),
            r.shed.to_string(),
        ]);
    }
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_sweep_completes_and_serves() {
        let rows =
            run_with(ExperimentScale { n_train: 300, n_test: 100, epochs: 1 }, true).unwrap();
        assert!(!rows.is_empty());
        for r in &rows {
            // Served the vast majority of offered load.
            assert!(
                r.achieved_rps > 0.0,
                "{}: no requests served",
                r.backend
            );
            assert!(r.p50_s <= r.p99_s + 1e-12);
        }
        assert!(render(&rows).contains("backend"));
    }
}
