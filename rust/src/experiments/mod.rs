//! Experiment drivers — one per paper table/figure (DESIGN.md §4).
//! Both the CLI subcommands (`rust/src/main.rs`) and the bench binaries
//! (`rust/benches/*.rs`) call into these, so a table is regenerated the
//! same way everywhere.
//!
//! | driver | paper artifact |
//! |--------|----------------|
//! | [`table1`] | Table I — CPU/GPU/FPGA time-per-sample + power |
//! | [`fig5`] | Figure 5 — per-epoch inference time per sample |
//! | [`pipeline_ablation`] | §3.1 pipelining + clock-decoupling claims |
//! | [`quant_ablation`] | §3.2 uniform/PoT/SP2/SPx accuracy + error |
//! | [`throughput`] | edge-serving latency/throughput (coordinator) |

pub mod common;
pub mod fig5;
pub mod pipeline_ablation;
pub mod quant_ablation;
pub mod table1;
pub mod throughput;
