//! Shared experiment setup: dataset + trained model, built once per
//! process with fixed seeds so every table starts from the same θ.

use crate::data::{load_digits, Dataset};
use crate::nn::mlp::{Mlp, MlpConfig};
use crate::nn::train::{train, EpochStats, TrainConfig};

/// Sizes used by the experiment drivers. `quick` shrinks everything for
/// CI (`EDGEMLP_BENCH_QUICK=1`).
#[derive(Debug, Clone, Copy)]
pub struct ExperimentScale {
    pub n_train: usize,
    pub n_test: usize,
    pub epochs: usize,
}

impl ExperimentScale {
    pub fn from_env() -> Self {
        if std::env::var("EDGEMLP_BENCH_QUICK").is_ok() {
            ExperimentScale { n_train: 600, n_test: 200, epochs: 2 }
        } else {
            ExperimentScale { n_train: 4000, n_test: 1000, epochs: 5 }
        }
    }
}

/// Everything a Table-I-style experiment needs.
pub struct TrainedSetup {
    pub train_set: Dataset,
    pub test_set: Dataset,
    pub mlp: Mlp,
    pub training_log: Vec<EpochStats>,
}

/// Train the paper's 784-128-10 MLP (B=64, η=0.5, MSE) on the digit
/// dataset. Deterministic for a given scale.
pub fn trained_mnist_mlp(scale: ExperimentScale) -> TrainedSetup {
    let (train_set, test_set) = load_digits(scale.n_train, scale.n_test, 2021);
    let mut rng = crate::util::rng::Pcg32::new(42);
    let mut mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let config = TrainConfig { epochs: scale.epochs, ..Default::default() };
    let training_log = train(&mut mlp, &train_set.inputs, &train_set.labels, &config);
    TrainedSetup { train_set, test_set, mlp, training_log }
}

/// Format a float in scientific notation like the paper's Table I
/// (`2.6 × 10^-3`).
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let exp = x.abs().log10().floor() as i32;
    let mant = x / 10f64.powi(exp);
    format!("{mant:.2}e{exp}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::metrics::accuracy;

    #[test]
    fn training_learns_digits() {
        // Convergence reference (probed on this dataset): n=1000/e=10 →
        // ~0.78 test accuracy; the full experiment scale (4000/5) hits
        // ~0.99.
        let setup =
            trained_mnist_mlp(ExperimentScale { n_train: 1500, n_test: 300, epochs: 8 });
        let acc = accuracy(&setup.mlp, &setup.test_set.inputs, &setup.test_set.labels);
        assert!(acc > 0.6, "test accuracy {acc} too low for the experiments to be meaningful");
        // Loss decreased across epochs.
        let log = &setup.training_log;
        assert!(log.last().unwrap().loss < log[0].loss);
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(2.6e-3), "2.60e-3");
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(115.2), "1.15e2");
    }
}
