//! E2 — Figure 5: measured inference time per sample across training
//! epochs (CPU). The paper's figure shows an essentially flat series —
//! inference cost does not depend on the weights' values — and we
//! reproduce it literally: retrain epoch by epoch, timing a batched
//! inference pass after each.

use super::common::{sci, ExperimentScale};
use crate::bench_harness::{bench, BenchConfig, Table};
use crate::data::batch::gather;
use crate::data::load_digits;
use crate::nn::mlp::{Mlp, MlpConfig};
use crate::nn::train::{train, TrainConfig};
use crate::util::rng::Pcg32;

/// One epoch's measurement.
#[derive(Debug, Clone)]
pub struct EpochPoint {
    pub epoch: usize,
    pub time_per_sample_s: f64,
    pub train_loss: f64,
}

/// Run E2: `epochs` training epochs, measuring after each.
pub fn run(scale: ExperimentScale) -> Vec<EpochPoint> {
    let (train_set, test_set) = load_digits(scale.n_train, scale.n_test, 2021);
    let mut rng = Pcg32::new(42);
    let mut mlp = Mlp::new(MlpConfig::paper_mnist(), &mut rng);
    let bench_cfg = BenchConfig::from_env();
    let batch = 64.min(test_set.len());
    let idx: Vec<usize> = (0..batch).collect();
    let x = gather(&test_set.inputs, &idx);

    let mut points = Vec::with_capacity(scale.epochs);
    for epoch in 0..scale.epochs {
        // One epoch of training (same hyper-parameters as the paper).
        let stats = train(
            &mut mlp,
            &train_set.inputs,
            &train_set.labels,
            &TrainConfig { epochs: 1, seed: 7 + epoch as u64, ..Default::default() },
        );
        let timing = bench(&format!("epoch{epoch}"), bench_cfg, || mlp.forward(&x));
        points.push(EpochPoint {
            epoch,
            time_per_sample_s: timing.mean_s() / batch as f64,
            train_loss: stats[0].loss,
        });
    }
    points
}

/// Render the series (the "figure" as a table of its points).
pub fn render(points: &[EpochPoint]) -> String {
    let mut table = Table::new(&["epoch", "time/sample (s)", "train loss"]);
    for p in points {
        table.row(&[
            p.epoch.to_string(),
            sci(p.time_per_sample_s),
            format!("{:.4}", p.train_loss),
        ]);
    }
    table.render()
}

/// Coefficient of variation of the timing series — Figure 5's flatness
/// claim quantified.
pub fn flatness(points: &[EpochPoint]) -> f64 {
    let times: Vec<f64> = points.iter().map(|p| p.time_per_sample_s).collect();
    let mean = crate::util::mean(&times);
    if mean == 0.0 {
        return 0.0;
    }
    crate::util::stddev(&times) / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_series_is_flat_and_loss_decreases() {
        let points = run(ExperimentScale { n_train: 600, n_test: 128, epochs: 3 });
        assert_eq!(points.len(), 3);
        // Inference time varies far less than the loss does: the CV of
        // the time series stays small (generous bound — CI machines are
        // noisy).
        assert!(flatness(&points) < 0.5, "cv {}", flatness(&points));
        // Training actually progressed.
        assert!(points.last().unwrap().train_loss < points[0].train_loss);
        assert!(render(&points).contains("epoch"));
    }
}
