//! The TCP serving front-end: a `TcpListener` acceptor plus a bounded
//! pool of per-connection worker threads layered on the
//! [`crate::coordinator::Coordinator`].
//!
//! Each accepted connection gets a *reader* thread (decodes frames,
//! submits into the coordinator's batching queues) and a *writer*
//! thread (resolves responses in submission order and puts them back on
//! the wire, echoing each request's id). Because the reader never waits
//! for inference to finish, a single connection can keep many requests
//! in flight — that pipelining is what lets the dynamic batcher form
//! real batches from one client.
//!
//! Load shedding and shutdown map onto protocol status codes
//! ([`SubmitError::Backpressure`] → `Status::Backpressure`,
//! [`SubmitError::Closed`] → `Status::Closed`); connections over the
//! pool limit are answered with a `Status::Busy` error frame and
//! dropped.

use super::registry::ModelRegistry;
use super::wire::{self, Frame, Opcode, ReadError, Status, BACKEND_ANY, DEFAULT_MAX_PAYLOAD};
use crate::coordinator::request::InferResult;
use crate::coordinator::server::{Coordinator, SubmitError};
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Server knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Connection-pool bound; further connections get `Status::Busy`.
    pub max_conns: usize,
    /// Per-frame payload cap.
    pub max_payload: u32,
    /// How long the writer waits for one inference result before
    /// answering `Status::Internal`.
    pub response_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_conns: 64,
            max_payload: DEFAULT_MAX_PAYLOAD,
            response_timeout: Duration::from_secs(30),
        }
    }
}

/// How often blocked connection reads wake up to check the stop flag.
const READ_TICK: Duration = Duration::from_millis(100);

struct Shared {
    coord: Coordinator,
    registry: Arc<ModelRegistry>,
    config: ServeConfig,
    /// Input dimension of the served model — invariant for the server's
    /// lifetime (`ModelRegistry::activate` refuses dim changes), cached
    /// here so per-frame validation does not lock the registry.
    input_dim: usize,
    stop: AtomicBool,
    round_robin: AtomicUsize,
    active_conns: AtomicUsize,
    conn_seq: AtomicUsize,
}

/// A running server. [`Server::shutdown`] (or drop) stops accepting,
/// winds down connections, and drains the coordinator.
pub struct Server {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and start
    /// accepting. The server owns the coordinator; submit paths go
    /// through the wire protocol from here on.
    pub fn start(
        coord: Coordinator,
        registry: Arc<ModelRegistry>,
        addr: &str,
        config: ServeConfig,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local_addr = listener.local_addr()?;
        let input_dim = registry.active().input_dim();
        let shared = Arc::new(Shared {
            coord,
            registry,
            config,
            input_dim,
            stop: AtomicBool::new(false),
            round_robin: AtomicUsize::new(0),
            active_conns: AtomicUsize::new(0),
            conn_seq: AtomicUsize::new(0),
        });
        let conns = Arc::new(Mutex::new(Vec::new()));
        let acceptor = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("edgemlp-accept".into())
                .spawn(move || accept_loop(listener, shared, conns))
                .context("spawn acceptor")?
        };
        Ok(Server { shared, local_addr, acceptor: Some(acceptor), conns })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Shared serving metrics (the coordinator's sink).
    pub fn metrics(&self) -> Arc<crate::coordinator::Metrics> {
        self.shared.coord.metrics()
    }

    /// Stop accepting, wind down connection threads (their in-flight
    /// responses are still written), close the coordinator queues and
    /// join everything.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Unblock the acceptor with a throwaway connection. A bind to
        // 0.0.0.0/:: is not connectable on every platform — aim the
        // wakeup at loopback on the bound port instead.
        let mut wake = self.local_addr;
        if wake.ip().is_unspecified() {
            match wake.ip() {
                std::net::IpAddr::V4(_) => {
                    wake.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST))
                }
                std::net::IpAddr::V6(_) => {
                    wake.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST))
                }
            }
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.conns.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        // Queues close only after every connection finished submitting;
        // workers drain what is left and exit (joined by Coordinator's
        // Drop when `shared` goes away).
        self.shared.coord.stop();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.shared.stop.load(Ordering::SeqCst) {
            self.stop_and_join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    loop {
        let (stream, _peer) = match listener.accept() {
            Ok(s) => s,
            Err(_) if shared.stop.load(Ordering::SeqCst) => return,
            Err(_) => {
                // Persistent failures (e.g. EMFILE when the fd limit is
                // hit) must not busy-spin the acceptor core.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        // Reap finished handlers so the vec stays bounded.
        {
            let mut held = conns.lock().unwrap();
            let mut live = Vec::with_capacity(held.len());
            for h in held.drain(..) {
                if h.is_finished() {
                    let _ = h.join();
                } else {
                    live.push(h);
                }
            }
            *held = live;
        }
        if shared.active_conns.load(Ordering::SeqCst) >= shared.config.max_conns {
            // Over the pool bound: answer Busy, then close carefully so
            // the frame survives (see `drain_then_close`).
            {
                let mut w = BufWriter::new(&stream);
                let frame = Frame::error(
                    Opcode::Ping,
                    0,
                    Status::Busy,
                    "server connection limit reached",
                );
                let _ = wire::write_frame(&mut w, &frame);
                let _ = w.flush();
            }
            // Off-thread: the drain can dwell up to its deadline and
            // must not stall the acceptor during a connection flood.
            std::thread::spawn(move || drain_then_close(stream));
            continue;
        }
        shared.active_conns.fetch_add(1, Ordering::SeqCst);
        let id = shared.conn_seq.fetch_add(1, Ordering::Relaxed);
        let shared2 = shared.clone();
        let handle = std::thread::Builder::new()
            .name(format!("edgemlp-conn-{id}"))
            .spawn(move || {
                let _guard = ConnGuard(shared2.clone());
                handle_connection(stream, &shared2);
            });
        match handle {
            Ok(h) => conns.lock().unwrap().push(h),
            Err(_) => {
                shared.active_conns.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

struct ConnGuard(Arc<Shared>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.active_conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Work items handed from the reader to the writer, in request order.
enum Outgoing {
    /// Response already known (ping, stats, errors, swap results).
    Ready(Frame),
    /// Waiting on one coordinator response.
    Pending { request_id: u64, rx: Receiver<InferResult> },
    /// Waiting on a whole submitted batch.
    PendingBatch { request_id: u64, receivers: Vec<Receiver<InferResult>> },
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_TICK));
    let write_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = write_stream.set_write_timeout(Some(Duration::from_secs(10)));
    let (tx, rx) = channel::<Outgoing>();
    let response_timeout = shared.config.response_timeout;
    let writer = std::thread::Builder::new()
        .name("edgemlp-conn-writer".into())
        .spawn(move || writer_loop(write_stream, rx, response_timeout));
    let writer = match writer {
        Ok(w) => w,
        Err(_) => return,
    };

    let mut reader = BufReader::new(stream);
    let mut framing_error = false;
    loop {
        match wire::read_frame_with(&mut reader, shared.config.max_payload, Some(&shared.stop))
        {
            Ok(frame) => {
                if !dispatch(frame, &tx, shared) {
                    break;
                }
            }
            Err(ReadError::Eof) | Err(ReadError::Stopped) | Err(ReadError::Io(_)) => break,
            Err(ReadError::Protocol(msg)) => {
                // The stream position is unreliable after a framing
                // error: answer once, then close.
                let _ = tx.send(Outgoing::Ready(Frame::error(
                    Opcode::Ping,
                    0,
                    Status::BadRequest,
                    &msg,
                )));
                framing_error = true;
                break;
            }
        }
    }
    // Dropping the sender lets the writer drain every queued/pending
    // response before exiting — in-flight work is never dropped.
    drop(tx);
    let _ = writer.join();
    if framing_error {
        // A malformed stream usually has more bytes in flight; closing
        // with unread data would RST away the BadRequest frame.
        drain_then_close(reader.into_inner());
    }
}

/// Close a socket so that a just-written error frame survives: send our
/// FIN first, then briefly discard whatever the peer already sent —
/// closing with unread receive data turns into a RST that destroys
/// in-flight output on common TCP stacks.
fn drain_then_close(mut stream: TcpStream) {
    use std::io::Read;
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut sink = [0u8; 4096];
    let deadline = std::time::Instant::now() + Duration::from_millis(250);
    while std::time::Instant::now() < deadline {
        match stream.read(&mut sink) {
            Ok(0) => break, // peer acknowledged the FIN and closed
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(_) => break,
        }
    }
}

fn writer_loop(stream: TcpStream, rx: Receiver<Outgoing>, response_timeout: Duration) {
    let mut w = BufWriter::new(stream);
    for item in rx {
        let frame = resolve(item, response_timeout);
        if wire::write_frame(&mut w, &frame).is_err() || w.flush().is_err() {
            return;
        }
    }
}

/// Turn one queued work item into the frame that goes on the wire.
fn resolve(item: Outgoing, timeout: Duration) -> Frame {
    match item {
        Outgoing::Ready(frame) => frame,
        Outgoing::Pending { request_id, rx } => match rx.recv_timeout(timeout) {
            Ok(Ok(resp)) => {
                Frame::ok(Opcode::Infer, request_id, wire::encode_outputs(&resp.output))
            }
            Ok(Err(msg)) => Frame::error(Opcode::Infer, request_id, Status::BackendError, &msg),
            Err(_) => Frame::error(
                Opcode::Infer,
                request_id,
                Status::Internal,
                "response channel lost or timed out",
            ),
        },
        Outgoing::PendingBatch { request_id, receivers } => {
            // One deadline for the whole batch — a per-receiver timeout
            // would multiply worst-case head-of-line blocking by the
            // batch size.
            let deadline = std::time::Instant::now() + timeout;
            let mut rows = Vec::with_capacity(receivers.len());
            for rx in receivers {
                let left = deadline.saturating_duration_since(std::time::Instant::now());
                match rx.recv_timeout(left) {
                    Ok(Ok(resp)) => rows.push(resp.output),
                    Ok(Err(msg)) => {
                        return Frame::error(
                            Opcode::InferBatch,
                            request_id,
                            Status::BackendError,
                            &msg,
                        )
                    }
                    Err(_) => {
                        return Frame::error(
                            Opcode::InferBatch,
                            request_id,
                            Status::Internal,
                            "response channel lost or timed out",
                        )
                    }
                }
            }
            Frame::ok(Opcode::InferBatch, request_id, wire::encode_batch_outputs(&rows))
        }
    }
}

/// Handle one request frame. Returns `false` to close the connection.
fn dispatch(frame: Frame, tx: &Sender<Outgoing>, shared: &Shared) -> bool {
    let id = frame.request_id;
    let out = match frame.opcode {
        Opcode::Ping => Outgoing::Ready(Frame::ok(Opcode::Ping, id, frame.payload)),
        Opcode::Stats => {
            let snap = shared.coord.metrics().snapshot();
            let active = shared.registry.active();
            let text = format!(
                "model: {} v{} (generation {})\nconnections: {}\n{}",
                active.name,
                active.version,
                shared.registry.generation(),
                shared.active_conns.load(Ordering::SeqCst),
                snap.render()
            );
            Outgoing::Ready(Frame::ok(Opcode::Stats, id, text.into_bytes()))
        }
        Opcode::SwapModel => match wire::decode_str(&frame.payload) {
            Err(e) => bad_request(Opcode::SwapModel, id, &e),
            Ok(name) => match shared.registry.activate(&name) {
                Ok((model, generation)) => Outgoing::Ready(Frame::ok(
                    Opcode::SwapModel,
                    id,
                    format!(
                        "model {} v{} active (generation {generation})",
                        model.name, model.version
                    )
                    .into_bytes(),
                )),
                Err(e @ super::registry::SwapError::UnknownModel(_)) => Outgoing::Ready(
                    Frame::error(Opcode::SwapModel, id, Status::UnknownModel, &e.to_string()),
                ),
                Err(e) => bad_request(Opcode::SwapModel, id, &e.to_string()),
            },
        },
        Opcode::Infer => match wire::decode_infer(&frame.payload) {
            Err(e) => bad_request(Opcode::Infer, id, &e),
            Ok((backend, x)) => match check_dim(shared, x.len())
                .and_then(|()| resolve_backend(shared, backend))
            {
                Err(out) => Outgoing::Ready(out.into_frame(Opcode::Infer, id)),
                Ok(idx) => match shared.coord.try_submit_to(idx, x) {
                    Ok(rx) => Outgoing::Pending { request_id: id, rx },
                    Err(e) => Outgoing::Ready(submit_error_frame(Opcode::Infer, id, e)),
                },
            },
        },
        Opcode::InferBatch => match wire::decode_infer_batch(&frame.payload) {
            Err(e) => bad_request(Opcode::InferBatch, id, &e),
            Ok((backend, samples)) => match check_dim(shared, samples[0].len())
                .and_then(|()| resolve_backend(shared, backend))
            {
                Err(out) => Outgoing::Ready(out.into_frame(Opcode::InferBatch, id)),
                Ok(idx) => {
                    let total = samples.len();
                    let mut receivers = Vec::with_capacity(total);
                    let mut failed = None;
                    for x in samples {
                        match shared.coord.try_submit_to(idx, x) {
                            Ok(rx) => receivers.push(rx),
                            Err(e) => {
                                failed = Some(e);
                                break;
                            }
                        }
                    }
                    match failed {
                        // Partially submitted samples still run; their
                        // receivers are dropped and the batch is
                        // reported shed as a unit.
                        Some(SubmitError::Backpressure) => Outgoing::Ready(Frame::error(
                            Opcode::InferBatch,
                            id,
                            Status::Backpressure,
                            &format!("queue full after {}/{total} samples", receivers.len()),
                        )),
                        Some(e) => Outgoing::Ready(submit_error_frame(Opcode::InferBatch, id, e)),
                        None => Outgoing::PendingBatch { request_id: id, receivers },
                    }
                }
            },
        },
    };
    tx.send(out).is_ok()
}

fn bad_request(opcode: Opcode, id: u64, msg: &str) -> Outgoing {
    Outgoing::Ready(Frame::error(opcode, id, Status::BadRequest, msg))
}

/// A backend-resolution failure, opcode-agnostic.
struct BackendLookupError(Status, String);

impl BackendLookupError {
    fn into_frame(self, opcode: Opcode, id: u64) -> Frame {
        Frame::error(opcode, id, self.0, &self.1)
    }
}

/// Reject wrong-dimension payloads before they reach a queue: a batch
/// formed by the coordinator mixes requests from every connection, and
/// one bad sample would fail the whole batch (`stage_inputs` errors are
/// batch-wide) — other clients' valid requests must not pay for it.
fn check_dim(shared: &Shared, got: usize) -> Result<(), BackendLookupError> {
    let want = shared.input_dim;
    if got != want {
        return Err(BackendLookupError(
            Status::BadRequest,
            format!("input dimension {got} != model input {want}"),
        ));
    }
    Ok(())
}

fn resolve_backend(shared: &Shared, requested: u32) -> Result<usize, BackendLookupError> {
    let n = shared.coord.backend_names().len();
    if requested == BACKEND_ANY {
        return Ok(shared.round_robin.fetch_add(1, Ordering::Relaxed) % n);
    }
    let idx = requested as usize;
    if idx >= n {
        return Err(BackendLookupError(
            Status::UnknownBackend,
            format!("backend index {idx} out of range ({n} backends)"),
        ));
    }
    Ok(idx)
}

fn submit_error_frame(opcode: Opcode, id: u64, e: SubmitError) -> Frame {
    match e {
        SubmitError::Backpressure => {
            Frame::error(opcode, id, Status::Backpressure, "queue full — retry later")
        }
        SubmitError::Closed => {
            Frame::error(opcode, id, Status::Closed, "coordinator shutting down")
        }
        SubmitError::UnknownBackend => {
            Frame::error(opcode, id, Status::UnknownBackend, "unknown backend")
        }
    }
}
