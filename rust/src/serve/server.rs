//! The TCP serving front-end: a single-threaded readiness event loop
//! (epoll on Linux, kqueue on macOS/BSD — `serve/poll.rs`) driving
//! per-connection state machines (`serve/conn.rs`) layered on the
//! [`crate::coordinator::Coordinator`].
//!
//! Every socket is nonblocking and registered with the OS readiness
//! queue; one loop thread accepts, decodes frames incrementally from
//! partial reads, submits into the coordinator's batching queues, and
//! flushes responses in request order as sockets become writable.
//! Coordinator workers hand completions back through a wakeup pipe
//! ([`NotifyHub`]), so connection count is a memory problem, not a
//! thread-count problem: the process runs O(pools + 1) threads whether
//! it holds ten connections or ten thousand (docs/async-net.md).
//! Because the loop never waits for inference to finish, a single
//! connection can keep many requests in flight — that pipelining is
//! what lets the dynamic batcher form real batches from one client.
//!
//! Multi-model routing: every served model (a registry *slot*) owns a
//! list of coordinator pools, one per backend kind, each pool holding
//! `replicas` workers. A v2 `Infer`/`InferBatch` frame names its model;
//! v1 frames (and the empty name) resolve to the default model.
//! [`Server::serve`] builds the whole engine — pools, routes, registry
//! wiring — from an [`EngineConfig`]; [`Server::start`] remains the
//! low-level single-model entry for custom coordinators.
//!
//! Load shedding and shutdown map onto protocol status codes
//! ([`SubmitError::Backpressure`] → `Status::Backpressure`,
//! [`SubmitError::Closed`] → `Status::Closed`); connections over the
//! pool limit are answered with a `Status::Busy` error frame and
//! dropped. Per-frame read deadlines (the slowloris defense) are
//! enforced by a timer wheel inside the loop instead of blocking
//! socket timeouts.

use super::conn::{Conn, NotifyHub, Outgoing};
use super::pipeline_backend::{pipeline_cpu_factory_traced, pipeline_fpga_factory_traced};
use super::registry::{ModelRegistry, ModelSlot, SwapError};
use super::wire::{
    self, Frame, HealthReport, ModelInfo, Opcode, PoolHealth, Precision, Status, BACKEND_ANY,
    DEFAULT_MAX_PAYLOAD,
};
use crate::coordinator::autoscale::{
    AutoscaleHooks, AutoscalePolicy, AutoscaleStats, Autoscaler,
};
use crate::coordinator::degrade::{DegradeController, DegradePolicy};
use crate::coordinator::request::CompletionNotify;
use crate::coordinator::server::{Coordinator, PoolSpec, RequestQos, SubmitError};
use crate::coordinator::CoordinatorConfig;
use crate::fpga::accelerator::AccelConfig;
use crate::fpga::power::EnergyModel;
use crate::obs::{
    render_energy_text, render_prometheus, AutoscaleExport, MetricsHttp, TraceRecorder,
};
use crate::serve::poll::{Event, LoopStats, Poller, TimerWheel, WakePipe};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Connection-pool bound; further connections get `Status::Busy`.
    pub max_conns: usize,
    /// Per-frame payload cap.
    pub max_payload: u32,
    /// How long the writeback path waits for one inference result
    /// (clock starts when the item reaches the head of its
    /// connection's response queue) before answering
    /// `Status::Internal`.
    pub response_timeout: Duration,
    /// Reader deadline per frame: a connection that stays silent — or
    /// dribbles a partial frame — longer than this is answered
    /// `Status::Timeout` and closed, so slowloris peers cannot pin
    /// connection-pool slots (`docs/serving-resilience.md`).
    pub read_timeout: Duration,
    /// Degraded-mode hysteresis; every model's controller shares it.
    pub degrade: DegradePolicy,
    /// Bind address for the Prometheus exposition sidecar
    /// (`GET /metrics`); `None` = no sidecar. The same text is always
    /// reachable in-band via the `StatsV2` opcode.
    pub metrics_addr: Option<String>,
    /// Request-lifecycle trace ring capacity, in events; 0 disables
    /// tracing entirely (the recorder still exists so `DumpTrace`
    /// answers an empty trace instead of an error).
    pub trace_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_conns: 64,
            max_payload: DEFAULT_MAX_PAYLOAD,
            response_timeout: Duration::from_secs(30),
            read_timeout: Duration::from_secs(30),
            degrade: DegradePolicy::default(),
            metrics_addr: None,
            trace_capacity: 8192,
        }
    }
}

/// Which backend kinds an engine pool runs.
#[derive(Debug, Clone, Copy)]
pub enum BackendKind {
    /// The f32 CPU forward ([`crate::coordinator::CpuBackend`]).
    Cpu,
    /// The cycle-accurate SPx accelerator simulator.
    FpgaSim(AccelConfig),
    /// The stage-pipelined f32 forward
    /// ([`super::pipeline_backend::PipelineCpuBackend`]): one thread
    /// per layer, `depth` micro-batches in flight, bitwise identical
    /// outputs to [`BackendKind::Cpu`].
    PipelineCpu {
        /// Maximum in-flight micro-batches (CLI `--pipeline-depth`).
        depth: usize,
    },
    /// The stage-pipelined SPx path
    /// ([`super::pipeline_backend::PipelineFpgaBackend`]): bitwise
    /// identical outputs to [`BackendKind::FpgaSim`].
    PipelineFpga {
        /// Simulator microarchitecture (same as [`BackendKind::FpgaSim`]).
        config: AccelConfig,
        /// Maximum in-flight micro-batches (CLI `--pipeline-depth`).
        depth: usize,
    },
    /// The VSQ int8 integer forward ([`crate::coordinator::VsqBackend`]):
    /// per-row-group scaled int8 weights through the SIMD widening dot.
    Int8,
    /// The VSQ int4 variant — the smallest weight footprint the engine
    /// can serve, and what degraded mode prefers.
    Int4,
}

impl BackendKind {
    fn label(&self) -> &'static str {
        match self {
            BackendKind::Cpu => "cpu",
            BackendKind::FpgaSim(_) => "fpga",
            BackendKind::PipelineCpu { .. } => "pipeline",
            BackendKind::PipelineFpga { .. } => "pipeline-fpga",
            BackendKind::Int8 => "int8",
            BackendKind::Int4 => "int4",
        }
    }

    /// The numeric precision this kind serves at — what `ListModels`
    /// reports for a slot with no explicit preference, and the key for
    /// its weight-footprint metrics.
    fn precision(&self) -> Precision {
        match self {
            BackendKind::Cpu | BackendKind::PipelineCpu { .. } => Precision::F32,
            BackendKind::FpgaSim(_) | BackendKind::PipelineFpga { .. } => Precision::Spx,
            BackendKind::Int8 => Precision::Int8,
            BackendKind::Int4 => Precision::Int4,
        }
    }

    /// Relative serving cost, lower = cheaper — ordered by weight bytes
    /// moved per sample. Degraded mode routes `BACKEND_ANY` traffic to
    /// the model's cheapest kind: the packed int4/int8 integer paths
    /// beat the SPx shift-add datapaths, which beat the f32 CPU
    /// forwards — the paper's precision-for-power trade.
    fn cost_rank(&self) -> u8 {
        match self {
            BackendKind::Int4 => 0,
            BackendKind::Int8 => 1,
            BackendKind::FpgaSim(_) => 2,
            BackendKind::PipelineFpga { .. } => 3,
            BackendKind::PipelineCpu { .. } => 4,
            BackendKind::Cpu => 5,
        }
    }
}

/// Everything [`Server::serve`] needs to assemble the engine: which
/// backend kinds to run, how many replica workers per pool, and the
/// coordinator/server knobs.
pub struct EngineConfig {
    /// Worker replicas per (backend kind × model) pool. When
    /// `autoscale` is set this is only the starting point — the
    /// controller clamps it into the band at startup.
    pub replicas: usize,
    /// Backend kinds, in wire `backend`-index order.
    pub backends: Vec<BackendKind>,
    pub coordinator: CoordinatorConfig,
    pub serve: ServeConfig,
    /// Replica-band feedback controller (CLI `--autoscale min:max`);
    /// `None` = fixed replica counts.
    pub autoscale: Option<AutoscalePolicy>,
    /// Server-wide power budget in watts (CLI `--power-budget-w`).
    /// Modeled draw sustained strictly over it latches the power half
    /// of every route's degrade mode — `BACKEND_ANY` traffic re-routes
    /// to the cheapest (lowest-bit) pool *before* anything is shed.
    /// Works with or without `autoscale` (without, a degenerate
    /// fixed-size controller still runs the power loop).
    pub power_budget_w: Option<f64>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            replicas: 1,
            backends: vec![BackendKind::Cpu],
            coordinator: CoordinatorConfig::default(),
            serve: ServeConfig::default(),
            autoscale: None,
            power_budget_w: None,
        }
    }
}

/// The event loop's poll timeout — the ceiling on how stale a timer
/// check can be, and the timer wheel's tick.
const READ_TICK: Duration = Duration::from_millis(100);

/// Timer-wheel slots: 64 ticks × 100 ms ≈ 6.4 s horizon; deadlines
/// beyond it re-arm on fire (`poll.rs`).
const TIMER_SLOTS: usize = 64;

/// How long a graceful shutdown waits for in-flight responses to flush
/// before force-closing the remaining connections.
const STOP_GRACE: Duration = Duration::from_secs(5);

/// Routing entry for one served model: its slot, the coordinator pools
/// serving it (in backend-kind order), and the cached input dimension
/// (invariant for the server's lifetime — `activate_into` refuses dim
/// changes), so per-frame validation does not lock the registry.
struct ModelRoute {
    slot: Arc<ModelSlot>,
    pools: Vec<usize>,
    /// Serving precision of each pool, parallel to `pools` — the
    /// `ListModels` column and the filter for a slot's precision
    /// preference (empty on the low-level [`Server::start`] path, where
    /// backend kinds are unknown).
    precisions: Vec<Precision>,
    input_dim: usize,
    /// Hysteresis state machine deciding when sustained saturation
    /// flips this model's `BACKEND_ANY` routing to `cheapest_pool`.
    degrade: DegradeController,
    /// The pool degraded mode routes to (cheapest
    /// [`BackendKind::cost_rank`] among `pools`).
    cheapest_pool: usize,
}

/// What the metrics/health renderers need to know about a running
/// autoscaler: its live counters plus the static band and budget.
struct AutoscaleView {
    stats: Arc<AutoscaleStats>,
    policy: AutoscalePolicy,
    budget_w: Option<f64>,
}

struct Shared {
    /// Behind an `Arc` because the autoscaler thread samples and
    /// resizes pools through its own handle.
    coord: Arc<Coordinator>,
    registry: Arc<ModelRegistry>,
    config: ServeConfig,
    /// Behind an `Arc` because the autoscaler's power hook latches
    /// degrade mode on every route without holding `Shared`.
    routes: Arc<BTreeMap<String, ModelRoute>>,
    default_model: String,
    stop: AtomicBool,
    active_conns: AtomicUsize,
    /// Connections closed by the reader deadline (slowloris defense);
    /// surfaced by the `Health` opcode.
    read_timeouts: AtomicU64,
    /// Request-lifecycle trace ring shared with the coordinator and
    /// pipeline stages; the `DumpTrace` opcode exports it.
    tracer: Arc<TraceRecorder>,
    /// Per-operation energy coefficients used to convert aggregate
    /// [`crate::fpga::accelerator::CycleStats`] into joules on the
    /// `Stats` / `StatsV2` responses and the `/metrics` sidecar.
    energy: EnergyModel,
    /// Event-loop gauges (registered connections, ready events, poll
    /// ticks, writeback backlog, timer depth) — written by the loop,
    /// read by `/metrics`, `Stats`, and v4 `Health`.
    loop_stats: LoopStats,
    /// Server start, the origin of `edgemlp_uptime_seconds` and the
    /// window for average-power figures.
    start: Instant,
    /// Autoscaler counters for the metrics/health surfaces; `None`
    /// when no controller is running (families still render as zeros).
    autoscale: Option<AutoscaleView>,
}

/// A running server. [`Server::shutdown`] (or drop) stops accepting,
/// winds down connections, and drains the coordinator.
pub struct Server {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    evloop: Option<JoinHandle<()>>,
    /// Wakes the loop from other threads (completions and shutdown).
    hub: Arc<NotifyHub>,
    /// Prometheus exposition sidecar, when `metrics_addr` was set.
    metrics_http: Option<MetricsHttp>,
    /// Replica/power feedback controller, when the engine asked for
    /// one. Shut down before the coordinator so no resize races the
    /// queue teardown.
    autoscaler: Option<Autoscaler>,
}

impl Server {
    /// Assemble and start the full engine: one coordinator pool per
    /// (backend kind × registry slot), each pool `replicas` workers
    /// deep, with routes wired so wire-protocol model names reach the
    /// right pools. Pool labels are `"<kind>/<model>"` (the per-model
    /// metrics breakdown).
    pub fn serve(
        registry: Arc<ModelRegistry>,
        addr: &str,
        engine: EngineConfig,
    ) -> Result<Server> {
        if engine.backends.is_empty() {
            bail!("engine needs at least one backend kind");
        }
        engine.serve.degrade.validate().map_err(|e| anyhow::anyhow!(e))?;
        if let Some(p) = &engine.autoscale {
            p.validate().map_err(anyhow::Error::msg)?;
        }
        if let Some(w) = engine.power_budget_w {
            if !w.is_finite() || w <= 0.0 {
                bail!("power budget must be a positive number of watts (got {w})");
            }
        }
        let replicas = engine.replicas.max(1);
        // One trace ring for the whole engine: connection handlers, the
        // coordinator's queues/workers, and every pipeline stage write
        // into it. Capacity 0 keeps the recorder (DumpTrace still
        // answers) but disables recording.
        let tracer = TraceRecorder::new(engine.serve.trace_capacity);
        let pool_tracer =
            if engine.serve.trace_capacity > 0 { Some(tracer.clone()) } else { None };
        let mut pools = Vec::new();
        let mut routes = BTreeMap::new();
        for slot in registry.slots() {
            let mut indices = Vec::with_capacity(engine.backends.len());
            for kind in &engine.backends {
                let factory = match kind {
                    BackendKind::Cpu => super::registry::swappable_cpu_factory(slot.clone()),
                    BackendKind::FpgaSim(config) => {
                        super::registry::swappable_fpga_factory(slot.clone(), *config)
                    }
                    BackendKind::PipelineCpu { depth } => {
                        pipeline_cpu_factory_traced(slot.clone(), *depth, pool_tracer.clone())
                    }
                    BackendKind::PipelineFpga { config, depth } => {
                        pipeline_fpga_factory_traced(
                            slot.clone(),
                            *config,
                            *depth,
                            pool_tracer.clone(),
                        )
                    }
                    BackendKind::Int8 => {
                        super::registry::swappable_vsq_factory(slot.clone(), 8)
                    }
                    BackendKind::Int4 => {
                        super::registry::swappable_vsq_factory(slot.clone(), 4)
                    }
                };
                indices.push(pools.len());
                pools.push(PoolSpec::replicated(
                    format!("{}/{}", kind.label(), slot.name()),
                    replicas,
                    factory,
                ));
            }
            let input_dim = slot.active().input_dim();
            // Position of the cheapest backend kind in this route's
            // pool list, precomputed so degraded routing is a lookup.
            let cheapest = engine
                .backends
                .iter()
                .enumerate()
                .min_by_key(|(_, k)| k.cost_rank())
                .map(|(i, _)| indices[i])
                .expect("backends is non-empty");
            routes.insert(
                slot.name().to_string(),
                ModelRoute {
                    slot,
                    pools: indices,
                    precisions: engine.backends.iter().map(|k| k.precision()).collect(),
                    input_dim,
                    degrade: DegradeController::new(engine.serve.degrade),
                    cheapest_pool: cheapest,
                },
            );
        }
        let coord = Coordinator::start_traced(pools, engine.coordinator, pool_tracer)?;
        // Register each pool's weight footprint (bytes streamed per
        // sample) with the metrics sink — a static property of the
        // (model, precision) pair: `activate_into` refuses dimension
        // changes, so the figure holds across swaps.
        for route in routes.values() {
            let active = route.slot.active();
            for (kind, _) in engine.backends.iter().zip(&route.pools) {
                coord.metrics().set_pool_bytes(
                    &format!("{}/{}", kind.label(), route.slot.name()),
                    active.weight_bytes(kind.precision()),
                );
            }
        }
        let default_model = registry.default_slot_name().to_string();
        // A power budget without a replica band still needs the
        // sampling thread: run the controller over a degenerate
        // (fixed-size) band so only the power loop acts.
        let autoscale = match (engine.autoscale, engine.power_budget_w) {
            (Some(policy), budget) => Some((policy, budget)),
            (None, Some(budget)) => {
                Some((AutoscalePolicy::band(replicas, replicas), Some(budget)))
            }
            (None, None) => None,
        };
        Self::start_inner(
            coord,
            registry,
            routes,
            default_model,
            addr,
            engine.serve,
            tracer,
            autoscale,
        )
    }

    /// Bind `addr` (use port 0 for an ephemeral port) and start
    /// accepting on a caller-built coordinator. Single-model routing:
    /// every pool of `coord` serves the registry's default slot, and
    /// wire backend indices map straight onto pool indices.
    pub fn start(
        coord: Coordinator,
        registry: Arc<ModelRegistry>,
        addr: &str,
        config: ServeConfig,
    ) -> Result<Server> {
        config.degrade.validate().map_err(|e| anyhow::anyhow!(e))?;
        let slot = registry.default_slot();
        let input_dim = slot.active().input_dim();
        let mut routes = BTreeMap::new();
        routes.insert(
            slot.name().to_string(),
            ModelRoute {
                slot,
                pools: (0..coord.num_pools()).collect(),
                precisions: Vec::new(),
                input_dim,
                degrade: DegradeController::new(config.degrade),
                // A caller-built coordinator carries no backend-kind
                // info; degraded mode falls back to the first pool.
                cheapest_pool: 0,
            },
        );
        let default_model = registry.default_slot_name().to_string();
        // A caller-built coordinator carries no tracer, so only the
        // connection-level events record on this path.
        let tracer = TraceRecorder::new(config.trace_capacity);
        Self::start_inner(coord, registry, routes, default_model, addr, config, tracer, None)
    }

    #[allow(clippy::too_many_arguments)]
    fn start_inner(
        coord: Coordinator,
        registry: Arc<ModelRegistry>,
        routes: BTreeMap<String, ModelRoute>,
        default_model: String,
        addr: &str,
        config: ServeConfig,
        tracer: Arc<TraceRecorder>,
        autoscale: Option<(AutoscalePolicy, Option<f64>)>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let local_addr = listener.local_addr()?;
        let metrics_addr = config.metrics_addr.clone();
        let coord = Arc::new(coord);
        let routes = Arc::new(routes);
        let energy = EnergyModel::default_fpga();
        let autoscaler = match autoscale {
            Some((policy, budget_w)) => {
                let hooks = autoscale_hooks(&coord, &routes, energy);
                Some(Autoscaler::spawn(coord.clone(), policy, budget_w, hooks)?)
            }
            None => None,
        };
        let autoscale_view = autoscaler.as_ref().map(|a| AutoscaleView {
            stats: a.stats(),
            policy: a.policy(),
            budget_w: a.budget_w(),
        });
        let shared = Arc::new(Shared {
            coord,
            registry,
            config,
            routes,
            default_model,
            stop: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            read_timeouts: AtomicU64::new(0),
            tracer,
            energy,
            loop_stats: LoopStats::default(),
            start: Instant::now(),
            autoscale: autoscale_view,
        });
        let metrics_http = match metrics_addr {
            Some(addr) => {
                let render_shared = shared.clone();
                let render: Arc<dyn Fn() -> String + Send + Sync> =
                    Arc::new(move || render_metrics_text(&render_shared));
                Some(
                    MetricsHttp::start(&addr, render)
                        .with_context(|| format!("bind metrics sidecar {addr}"))?,
                )
            }
            None => None,
        };
        let hub = Arc::new(NotifyHub::new(WakePipe::new().context("wakeup pipe")?));
        let evloop = {
            let shared = shared.clone();
            let hub = hub.clone();
            std::thread::Builder::new()
                .name("edgemlp-evloop".into())
                .spawn(move || EventLoop::new(listener, shared, hub).run())
                .context("spawn event loop")?
        };
        Ok(Server { shared, local_addr, evloop: Some(evloop), hub, metrics_http, autoscaler })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Shared serving metrics (the coordinator's sink).
    pub fn metrics(&self) -> Arc<crate::coordinator::Metrics> {
        self.shared.coord.metrics()
    }

    /// The request-lifecycle trace ring (what `DumpTrace` exports).
    pub fn tracer(&self) -> Arc<TraceRecorder> {
        self.shared.tracer.clone()
    }

    /// The autoscaler's live counters, when a controller is running.
    pub fn autoscale_stats(&self) -> Option<Arc<AutoscaleStats>> {
        self.autoscaler.as_ref().map(|a| a.stats())
    }

    /// Bound address of the Prometheus sidecar, when one is running
    /// (resolves ephemeral ports).
    pub fn metrics_local_addr(&self) -> Option<SocketAddr> {
        self.metrics_http.as_ref().map(|m| m.local_addr())
    }

    /// Stop accepting, wind down connections (their in-flight
    /// responses are still written), close the coordinator queues and
    /// join everything.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(m) = self.metrics_http.take() {
            m.shutdown();
        }
        // The autoscaler goes first so no resize races the coordinator
        // teardown below.
        if let Some(a) = self.autoscaler.take() {
            a.shutdown();
        }
        // The wakeup pipe interrupts the loop's poll immediately.
        self.hub.wake();
        if let Some(h) = self.evloop.take() {
            let _ = h.join();
        }
        // Queues close only after the loop finished submitting; workers
        // drain what is left and exit (joined by Coordinator's Drop
        // when `shared` goes away).
        self.shared.coord.stop();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.shared.stop.load(Ordering::SeqCst) {
            self.stop_and_join();
        }
    }
}

/// Reserved poller token for the listening socket.
const LISTENER_TOKEN: u64 = u64::MAX;
/// Reserved poller token for the wakeup pipe's read end.
const WAKER_TOKEN: u64 = u64::MAX - 1;

/// Connection tokens pack (generation << 32) | slot index, so an event
/// or timer entry for a recycled slot is recognized as stale.
fn conn_token(generation: u64, idx: usize) -> u64 {
    ((generation & 0xffff_ffff) << 32) | (idx as u64 & 0xffff_ffff)
}

fn token_slot(token: u64) -> usize {
    (token & 0xffff_ffff) as usize
}

fn token_generation(token: u64) -> u64 {
    token >> 32
}

/// One occupied slab slot: the connection, its per-request completion
/// hook, and the interest last registered with the poller (so identical
/// interest never re-issues a syscall).
struct ConnSlot {
    conn: Conn,
    notify: CompletionNotify,
    reg_r: bool,
    reg_w: bool,
}

/// The readiness event loop: owns every connection, the poller, and
/// the timer wheel. Runs on the single `edgemlp-evloop` thread.
struct EventLoop {
    listener: TcpListener,
    shared: Arc<Shared>,
    hub: Arc<NotifyHub>,
    poller: Poller,
    slots: Vec<Option<ConnSlot>>,
    free: Vec<usize>,
    generation: u64,
    wheel: TimerWheel,
    /// Connections registered with the poller (counted + Busy drains).
    live: usize,
    /// Sum of unflushed writeback bytes across connections, maintained
    /// incrementally around every state change.
    pending_wb: u64,
    /// Accept backoff after fd exhaustion: a level-triggered readable
    /// listener that cannot accept would otherwise spin the loop.
    accept_paused_until: Option<Instant>,
    stopping: bool,
    stop_deadline: Option<Instant>,
}

impl EventLoop {
    fn new(listener: TcpListener, shared: Arc<Shared>, hub: Arc<NotifyHub>) -> EventLoop {
        EventLoop {
            listener,
            shared,
            hub,
            poller: Poller::new().expect("create poller"),
            slots: Vec::new(),
            free: Vec::new(),
            generation: 0,
            wheel: TimerWheel::new(TIMER_SLOTS, READ_TICK, Instant::now()),
            live: 0,
            pending_wb: 0,
            accept_paused_until: None,
            stopping: false,
            stop_deadline: None,
        }
    }

    fn run(mut self) {
        if self.poller.add(self.listener.as_raw_fd(), LISTENER_TOKEN, true, false).is_err() {
            return;
        }
        if self.poller.add(self.hub.wake_fd(), WAKER_TOKEN, true, false).is_err() {
            return;
        }
        let shared = self.shared.clone();
        let mut events: Vec<Event> = Vec::new();
        let mut ready_tokens: Vec<u64> = Vec::new();
        let mut fired: Vec<(u64, u64)> = Vec::new();
        loop {
            if self.poller.wait(&mut events, Some(READ_TICK)).is_err() {
                return;
            }
            let now = Instant::now();
            let stats = &shared.loop_stats;
            stats.poll_ticks.fetch_add(1, Ordering::Relaxed);
            stats.ready_events.fetch_add(events.len() as u64, Ordering::Relaxed);

            if !self.stopping && shared.stop.load(Ordering::SeqCst) {
                self.begin_stop(now);
            }

            let mut accept_ready = false;
            let mut waker_ready = false;
            for ev in &events {
                match ev.token {
                    LISTENER_TOKEN => accept_ready = true,
                    WAKER_TOKEN => waker_ready = true,
                    token => self.service_conn(token, ev.readable, ev.writable, now),
                }
            }

            if waker_ready || self.stopping {
                self.hub.drain_ready(&mut ready_tokens);
                for &token in &ready_tokens {
                    self.service_conn(token, false, false, now);
                }
            }

            // Timers: entries are hints — re-check the connection's
            // real deadlines and re-arm if they moved.
            self.wheel.advance(now, &mut fired);
            for &(token, generation) in &fired {
                if token_generation(token) == generation & 0xffff_ffff {
                    self.on_timer(token, now);
                }
            }
            fired.clear();

            if accept_ready && !self.stopping {
                self.accept_ready(now);
            }
            if let Some(until) = self.accept_paused_until {
                if now >= until {
                    self.accept_paused_until = None;
                    let _ = self.poller.modify(
                        self.listener.as_raw_fd(),
                        LISTENER_TOKEN,
                        true,
                        false,
                    );
                }
            }

            stats.registered_conns.store(self.live as u64, Ordering::Relaxed);
            stats.pending_writeback_bytes.store(self.pending_wb, Ordering::Relaxed);
            stats.timer_depth.store(self.wheel.depth() as u64, Ordering::Relaxed);

            if self.stopping {
                let past_grace = self.stop_deadline.is_some_and(|d| now >= d);
                if self.live == 0 || past_grace {
                    self.close_all();
                    return;
                }
            }
        }
    }

    /// Accept everything the backlog holds (level-triggered: stopping
    /// early just re-reports, but draining avoids an extra poll pass).
    fn accept_ready(&mut self, now: Instant) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if self.shared.tracer.enabled() {
                        self.shared.tracer.instant("conn", "accept", None, 0);
                    }
                    let _ = stream.set_nodelay(true);
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let over_limit = self.shared.active_conns.load(Ordering::SeqCst)
                        >= self.shared.config.max_conns;
                    self.register(stream, over_limit, now);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    continue
                }
                Err(_) => {
                    // Likely fd exhaustion: a readable-but-unacceptable
                    // listener would spin the loop, so mask it briefly.
                    self.accept_paused_until = Some(now + Duration::from_millis(10));
                    let _ = self.poller.modify(
                        self.listener.as_raw_fd(),
                        LISTENER_TOKEN,
                        false,
                        false,
                    );
                    return;
                }
            }
        }
    }

    /// Register one accepted socket. Over-limit connections become
    /// uncounted Busy drains: the goodbye frame flushes through the
    /// same careful-close machinery as every other goodbye. No request
    /// was read, so the frame goes out at MIN_VERSION — the one framing
    /// every supported client can parse.
    fn register(&mut self, stream: TcpStream, over_limit: bool, now: Instant) {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.slots.len() - 1
            }
        };
        self.generation += 1;
        let generation = self.generation;
        let token = conn_token(generation, idx);
        let mut conn = Conn::new(
            stream,
            generation,
            now,
            self.shared.config.read_timeout,
            self.shared.config.response_timeout,
        );
        if over_limit {
            self.shared.coord.metrics().record_busy_rejected();
            if self.shared.tracer.enabled() {
                self.shared.tracer.instant("conn", "busy_reject", None, 0);
            }
            conn.counted = false;
            conn.enqueue(Outgoing::Ready(
                Frame::error(Opcode::Ping, 0, Status::Busy, "server connection limit reached")
                    .at_version(wire::MIN_VERSION),
            ));
            conn.begin_close(true);
        } else {
            self.shared.active_conns.fetch_add(1, Ordering::SeqCst);
        }
        let (reg_r, reg_w) = (conn.want_read(), conn.want_write());
        if self.poller.add(conn.stream().as_raw_fd(), token, reg_r, reg_w).is_err() {
            if conn.counted {
                self.shared.active_conns.fetch_sub(1, Ordering::SeqCst);
            }
            self.free.push(idx);
            return;
        }
        let notify = self.hub.notifier(token);
        self.slots[idx] = Some(ConnSlot { conn, notify, reg_r, reg_w });
        self.live += 1;
        self.touch(idx, now);
    }

    /// Route one event/notify/timer to its connection, ignoring stale
    /// tokens from recycled slots.
    fn service_conn(&mut self, token: u64, readable: bool, writable: bool, now: Instant) {
        let idx = token_slot(token);
        let Some(Some(slot)) = self.slots.get_mut(idx) else { return };
        if slot.conn.generation & 0xffff_ffff != token_generation(token) {
            return;
        }
        if readable {
            let max_payload = self.shared.config.max_payload;
            let pass = slot.conn.read_ready(now, max_payload);
            self.handle_pass(idx, pass);
        }
        // Writability (and a bare completion notify) need no dedicated
        // handling: `touch` pumps, which always attempts a flush.
        let _ = writable;
        self.touch(idx, now);
    }

    /// Dispatch the frames one read pass produced, then apply its
    /// framing-error verdict.
    fn handle_pass(&mut self, idx: usize, pass: super::conn::ReadPass) {
        for frame in pass.frames {
            let Some(Some(slot)) = self.slots.get_mut(idx) else { return };
            if slot.conn.closing {
                break;
            }
            if self.shared.tracer.enabled() {
                self.shared.tracer.instant("conn", "decode", None, frame.request_id);
            }
            let notify = slot.notify.clone();
            let out = dispatch(frame, &self.shared, &notify);
            let Some(Some(slot)) = self.slots.get_mut(idx) else { return };
            slot.conn.enqueue(out);
        }
        let Some(Some(slot)) = self.slots.get_mut(idx) else { return };
        if let Some(msg) = pass.framing_error {
            // The stream position is unreliable after a framing error:
            // answer once, then close. The request version is unknown
            // here, so frame the reply at MIN_VERSION — every supported
            // client can parse it (a v1-only client would reject a v2
            // frame and lose the diagnostic).
            self.shared.coord.metrics().record_bad_request(framing_cause(&msg));
            if self.shared.tracer.enabled() {
                self.shared.tracer.instant("conn", "bad_request", None, 0);
            }
            slot.conn.enqueue(Outgoing::Ready(
                Frame::error(Opcode::Ping, 0, Status::BadRequest, &msg)
                    .at_version(wire::MIN_VERSION),
            ));
            slot.conn.begin_close(true);
        } else if slot.conn.peer_eof && !slot.conn.closing {
            // Clean half-close: the peer wants its remaining answers,
            // then we close without ceremony.
            slot.conn.begin_close(false);
        }
    }

    /// Pump a connection, refresh its poller interest and timer, and
    /// tear it down once finished.
    fn touch(&mut self, idx: usize, now: Instant) {
        let Some(Some(slot)) = self.slots.get_mut(idx) else { return };
        let wb_before = slot.conn.writeback_bytes();
        slot.conn.pump(now);
        let wb_after = slot.conn.writeback_bytes();
        self.pending_wb = self.pending_wb - wb_before + wb_after;
        let Some(Some(slot)) = self.slots.get_mut(idx) else { return };
        if slot.conn.done(now) {
            self.close(idx);
            return;
        }
        let (want_r, want_w) = (slot.conn.want_read(), slot.conn.want_write());
        if (want_r, want_w) != (slot.reg_r, slot.reg_w) {
            let token = conn_token(slot.conn.generation, idx);
            let _ = self.poller.modify(slot.conn.stream().as_raw_fd(), token, want_r, want_w);
            slot.reg_r = want_r;
            slot.reg_w = want_w;
        }
        // Arm the earliest deadline if the wheel holds nothing at least
        // that early for this connection.
        if let Some(d) = slot.conn.next_deadline() {
            let rearm = match slot.conn.timer_armed_for {
                Some(armed) => d < armed,
                None => true,
            };
            if rearm {
                let token = conn_token(slot.conn.generation, idx);
                self.wheel.schedule(now, d, token, slot.conn.generation);
                slot.conn.timer_armed_for = Some(d);
            }
        }
    }

    /// A timer entry fired: apply whichever deadline actually expired
    /// (the read deadline answers Timeout; response/drain/stall
    /// deadlines are enforced inside `pump`/`done`).
    fn on_timer(&mut self, token: u64, now: Instant) {
        let idx = token_slot(token);
        let Some(Some(slot)) = self.slots.get_mut(idx) else { return };
        if slot.conn.generation & 0xffff_ffff != token_generation(token) {
            return;
        }
        slot.conn.timer_armed_for = None;
        if slot.conn.read_deadline_expired(now) {
            self.shared.read_timeouts.fetch_add(1, Ordering::Relaxed);
            // No request id to echo and the version is unknown — frame
            // the goodbye at MIN_VERSION like framing errors.
            slot.conn.enqueue(Outgoing::Ready(
                Frame::error(
                    Opcode::Ping,
                    0,
                    Status::Timeout,
                    "read deadline exceeded — closing idle/stalled connection",
                )
                .at_version(wire::MIN_VERSION),
            ));
            slot.conn.begin_close(true);
        }
        self.touch(idx, now);
    }

    /// Graceful shutdown begins: stop accepting, mark every connection
    /// for a clean close (queued responses still flush), give them a
    /// grace window.
    fn begin_stop(&mut self, now: Instant) {
        self.stopping = true;
        self.stop_deadline = Some(now + STOP_GRACE);
        let _ =
            self.poller.modify(self.listener.as_raw_fd(), LISTENER_TOKEN, false, false);
        for idx in 0..self.slots.len() {
            let occupied = match self.slots.get_mut(idx) {
                Some(Some(slot)) => {
                    if !slot.conn.closing {
                        slot.conn.begin_close(false);
                    }
                    true
                }
                _ => false,
            };
            if occupied {
                self.touch(idx, now);
            }
        }
    }

    /// Tear down one connection and recycle its slot.
    fn close(&mut self, idx: usize) {
        let Some(entry) = self.slots.get_mut(idx).and_then(|s| s.take()) else { return };
        self.pending_wb -= entry.conn.writeback_bytes();
        let _ = self.poller.delete(entry.conn.stream().as_raw_fd());
        if entry.conn.counted {
            self.shared.active_conns.fetch_sub(1, Ordering::SeqCst);
        }
        self.live -= 1;
        self.free.push(idx);
    }

    /// Force-close whatever is left (shutdown past the grace window).
    fn close_all(&mut self) {
        for idx in 0..self.slots.len() {
            self.close(idx);
        }
    }
}

/// Handle one request frame, producing its (possibly pending) response.
fn dispatch(frame: Frame, shared: &Shared, notify: &CompletionNotify) -> Outgoing {
    let id = frame.request_id;
    let version = frame.version;
    let out = match frame.opcode {
        Opcode::Ping => Outgoing::Ready(Frame::ok(Opcode::Ping, id, frame.payload)),
        Opcode::Stats => {
            let snap = shared.coord.metrics().snapshot();
            let mut text = String::new();
            for route in shared.routes.values() {
                let active = route.slot.active();
                let tag = if route.slot.name() == shared.default_model { " [default]" } else { "" };
                text.push_str(&format!(
                    "model {}{tag}: {} v{} ({}→{}, generation {})\n",
                    route.slot.name(),
                    active.name,
                    active.version,
                    active.input_dim(),
                    active.output_dim(),
                    route.slot.generation(),
                ));
            }
            let g = shared.loop_stats.gauges();
            text.push_str(&format!(
                "event loop: {} registered, {} ready events / {} ticks, {} writeback bytes, {} timers\n",
                g.registered_conns,
                g.ready_events,
                g.poll_ticks,
                g.pending_writeback_bytes,
                g.timer_depth,
            ));
            if let Some(a) = &shared.autoscale {
                let budget = match a.budget_w {
                    Some(w) => format!("{w:.2} W"),
                    None => "none".to_string(),
                };
                text.push_str(&format!(
                    "autoscale: band [{}, {}], {} ups / {} downs, \
                     power {:.3} W (budget {budget}), power-degraded {}\n",
                    a.policy.min,
                    a.policy.max,
                    a.stats.scale_ups.load(Ordering::Relaxed),
                    a.stats.scale_downs.load(Ordering::Relaxed),
                    a.stats.power_mw.load(Ordering::Relaxed) as f64 / 1e3,
                    a.stats.power_degraded.load(Ordering::Relaxed),
                ));
            }
            text.push_str(&format!(
                "connections: {}\n{}",
                shared.active_conns.load(Ordering::SeqCst),
                snap.render()
            ));
            text.push_str(&render_energy_text(
                &shared.energy,
                &snap,
                shared.start.elapsed().as_secs_f64(),
            ));
            Outgoing::Ready(Frame::ok(Opcode::Stats, id, text.into_bytes()))
        }
        Opcode::StatsV2 => {
            if version < 4 {
                bad_request(
                    shared,
                    "version_gate",
                    Opcode::StatsV2,
                    id,
                    "StatsV2 requires protocol v4",
                )
            } else {
                Outgoing::Ready(Frame::ok(
                    Opcode::StatsV2,
                    id,
                    render_metrics_text(shared).into_bytes(),
                ))
            }
        }
        Opcode::DumpTrace => {
            if version < 4 {
                bad_request(
                    shared,
                    "version_gate",
                    Opcode::DumpTrace,
                    id,
                    "DumpTrace requires protocol v4",
                )
            } else {
                Outgoing::Ready(Frame::ok(
                    Opcode::DumpTrace,
                    id,
                    shared.tracer.export_chrome_json().into_bytes(),
                ))
            }
        }
        Opcode::ListModels => {
            if version < 2 {
                bad_request(
                    shared,
                    "version_gate",
                    Opcode::ListModels,
                    id,
                    "ListModels requires protocol v2",
                )
            } else {
                let models: Vec<ModelInfo> = shared
                    .routes
                    .values()
                    .map(|route| {
                        let active = route.slot.active();
                        ModelInfo {
                            slot: route.slot.name().to_string(),
                            model: active.name.clone(),
                            version: active.version,
                            input_dim: active.input_dim() as u32,
                            output_dim: active.output_dim() as u32,
                            generation: route.slot.generation(),
                            precision: route_precision(route),
                        }
                    })
                    .collect();
                // Encode at the REQUEST's version: the v4 precision
                // suffix would be trailing garbage to a pre-v4 decoder.
                match wire::encode_model_list_at(&models, version) {
                    Ok(payload) => Outgoing::Ready(Frame::ok(Opcode::ListModels, id, payload)),
                    Err(e) => Outgoing::Ready(Frame::error(
                        Opcode::ListModels,
                        id,
                        Status::Internal,
                        &e,
                    )),
                }
            }
        }
        Opcode::SwapModel => match wire::decode_swap_precision(&frame.payload, version) {
            Err(e) => bad_request(shared, "decode_swap", Opcode::SwapModel, id, &e),
            Ok((slot, source, precision)) => match shared.registry.activate_into(&slot, &source)
            {
                Ok((model, generation)) => {
                    let name =
                        if slot.is_empty() { shared.default_model.as_str() } else { &slot };
                    // The v4 precision byte pins the slot's serving
                    // precision alongside the activation; absent, the
                    // existing preference is left untouched.
                    let precision_note = match (precision, shared.routes.get(name)) {
                        (Some(p), Some(route)) => {
                            route.slot.set_preferred_precision(Some(p));
                            format!(", precision {p}")
                        }
                        _ => String::new(),
                    };
                    Outgoing::Ready(Frame::ok(
                        Opcode::SwapModel,
                        id,
                        format!(
                            "slot {name} now serves {} v{} (generation {generation}{precision_note})",
                            model.name, model.version
                        )
                        .into_bytes(),
                    ))
                }
                Err(e @ (SwapError::UnknownModel(_) | SwapError::UnknownSlot(_))) => {
                    Outgoing::Ready(Frame::error(
                        Opcode::SwapModel,
                        id,
                        Status::UnknownModel,
                        &e.to_string(),
                    ))
                }
                Err(e) => bad_request(shared, "swap_rejected", Opcode::SwapModel, id, &e.to_string()),
            },
        },
        Opcode::Health => {
            if version < 3 {
                bad_request(shared, "version_gate", Opcode::Health, id, "Health requires protocol v3")
            } else {
                let report = health_report(shared);
                // Encode at the REQUEST's version: the v4 extension,
                // loop-gauge, and autoscale blocks would be trailing
                // garbage to a v3 decoder.
                match wire::encode_health_full(
                    &report,
                    &shared.loop_stats.gauges(),
                    &autoscale_health(shared),
                    version,
                ) {
                    Ok(payload) => Outgoing::Ready(Frame::ok(Opcode::Health, id, payload)),
                    Err(e) => {
                        Outgoing::Ready(Frame::error(Opcode::Health, id, Status::Internal, &e))
                    }
                }
            }
        }
        Opcode::Infer => match wire::decode_infer(&frame.payload, version) {
            Err(e) => bad_request(shared, "decode_infer", Opcode::Infer, id, &e),
            Ok(req) => match resolve_pool(shared, &req.model, req.backend, req.x.len()) {
                Err(out) => Outgoing::Ready(out.into_frame(Opcode::Infer, id)),
                Ok(idx) => {
                    match shared.coord.try_submit_to_qos_notify(
                        idx,
                        req.x,
                        request_qos(req.qos),
                        Some(notify.clone()),
                    ) {
                        Ok(rx) => {
                            Outgoing::Pending { version, request_id: id, rx, deadline: None }
                        }
                        Err(e) => Outgoing::Ready(submit_error_frame(Opcode::Infer, id, e)),
                    }
                }
            },
        },
        Opcode::InferBatch => match wire::decode_infer_batch(&frame.payload, version) {
            Err(e) => bad_request(shared, "decode_infer", Opcode::InferBatch, id, &e),
            Ok(req) => {
                match resolve_pool(shared, &req.model, req.backend, req.samples[0].len()) {
                    Err(out) => Outgoing::Ready(out.into_frame(Opcode::InferBatch, id)),
                    Ok(idx) => {
                        let total = req.samples.len();
                        let qos = request_qos(req.qos);
                        let mut receivers = Vec::with_capacity(total);
                        let mut failed = None;
                        for x in req.samples {
                            match shared.coord.try_submit_to_qos_notify(
                                idx,
                                x,
                                qos,
                                Some(notify.clone()),
                            ) {
                                Ok(rx) => receivers.push(rx),
                                Err(e) => {
                                    failed = Some(e);
                                    break;
                                }
                            }
                        }
                        match failed {
                            // Partially submitted samples still run;
                            // their receivers are dropped and the batch
                            // is reported shed as a unit.
                            Some(SubmitError::Backpressure) => Outgoing::Ready(Frame::error(
                                Opcode::InferBatch,
                                id,
                                Status::Backpressure,
                                &format!(
                                    "queue full after {}/{total} samples",
                                    receivers.len()
                                ),
                            )),
                            Some(e) => {
                                Outgoing::Ready(submit_error_frame(Opcode::InferBatch, id, e))
                            }
                            None => Outgoing::PendingBatch {
                                version,
                                request_id: id,
                                rows: Vec::with_capacity(receivers.len()),
                                next: 0,
                                receivers,
                                deadline: None,
                            },
                        }
                    }
                }
            }
        },
    };
    // Responses echo the request's protocol version (a v1 client never
    // sees a v2 frame); pending items carry it to the writeback path.
    match out {
        Outgoing::Ready(f) => Outgoing::Ready(f.at_version(version)),
        other => other,
    }
}

/// Stable cause label for a framing-level protocol error, keyed off the
/// diagnostic text (`wire::read_frame*`'s messages are the source of
/// truth; anything unrecognized lands in "framing").
fn framing_cause(msg: &str) -> &'static str {
    if msg.contains("magic") {
        "magic"
    } else if msg.contains("version") {
        "version"
    } else if msg.contains("opcode") {
        "opcode"
    } else if msg.contains("status") {
        "status"
    } else if msg.contains("exceeds cap") {
        "payload_cap"
    } else if msg.contains("mid-frame") {
        "truncated"
    } else {
        "framing"
    }
}

/// Answer `Status::BadRequest` and bump the per-cause counter. `cause`
/// is a low-cardinality stable label (it becomes a Prometheus label
/// value), NOT the free-form diagnostic.
fn bad_request(
    shared: &Shared,
    cause: &'static str,
    opcode: Opcode,
    id: u64,
    msg: &str,
) -> Outgoing {
    shared.coord.metrics().record_bad_request(cause);
    if shared.tracer.enabled() {
        shared.tracer.instant("conn", "bad_request", None, id);
    }
    Outgoing::Ready(Frame::error(opcode, id, Status::BadRequest, msg))
}

/// Map a wire QoS onto coordinator scheduling inputs. The wire deadline
/// is a *relative* budget (µs from server receipt — client and server
/// clocks need not agree); it becomes absolute here, so queueing and
/// service time all burn the same budget.
fn request_qos(qos: wire::Qos) -> RequestQos {
    RequestQos {
        deadline: qos
            .has_deadline()
            .then(|| Instant::now() + Duration::from_micros(qos.deadline_us)),
        priority: qos.priority.rank(),
    }
}

/// Build the closures wiring an [`Autoscaler`] to this engine: a power
/// probe that differentiates the energy model's accumulated dynamic
/// joules into watts over each sampling window, and a latch applying
/// budget overruns to every route's degrade controller.
fn autoscale_hooks(
    coord: &Arc<Coordinator>,
    routes: &Arc<BTreeMap<String, ModelRoute>>,
    energy: EnergyModel,
) -> AutoscaleHooks {
    // Modeled draw = static board power + Δ(dynamic joules)/Δt across
    // the sampling window. The first sample has no window yet and
    // reports the static floor.
    let metrics = coord.metrics();
    let mut last: Option<(Instant, f64)> = None;
    let power_watts = Box::new(move || {
        let now = Instant::now();
        let total: f64 = metrics
            .snapshot()
            .backends
            .values()
            .map(|m| energy.dynamic_energy_j(&m.cycle_stats))
            .sum();
        let watts = match last {
            Some((t0, j0)) => {
                let dt = now.duration_since(t0).as_secs_f64().max(1e-9);
                energy.static_w + (total - j0).max(0.0) / dt
            }
            None => energy.static_w,
        };
        last = Some((now, total));
        watts
    });
    let metrics = coord.metrics();
    let routes = routes.clone();
    let set_power_degraded = Box::new(move |over: bool| {
        for route in routes.values() {
            if route.degrade.set_power(over) {
                metrics.record_degraded_transition();
            }
        }
    });
    AutoscaleHooks { power_watts, set_power_degraded }
}

/// The autoscaler's counters for one scrape
/// ([`AutoscaleExport::disabled`] when no controller runs — the
/// families still render, as zeros over a collapsed band).
fn autoscale_export(shared: &Shared) -> AutoscaleExport {
    match &shared.autoscale {
        Some(a) => AutoscaleExport {
            enabled: true,
            min_replicas: a.policy.min as u64,
            max_replicas: a.policy.max as u64,
            scale_ups: a.stats.scale_ups.load(Ordering::Relaxed),
            scale_downs: a.stats.scale_downs.load(Ordering::Relaxed),
            power_w: a.stats.power_mw.load(Ordering::Relaxed) as f64 / 1e3,
            budget_w: a.budget_w.unwrap_or(0.0),
            power_degraded: a.stats.power_degraded.load(Ordering::Relaxed),
        },
        None => AutoscaleExport::disabled(),
    }
}

/// The autoscale block for one v4 `Health` response (all zeros with
/// `enabled = false` when no controller runs).
fn autoscale_health(shared: &Shared) -> wire::AutoscaleHealth {
    match &shared.autoscale {
        Some(a) => wire::AutoscaleHealth {
            enabled: true,
            min_replicas: a.policy.min as u32,
            max_replicas: a.policy.max as u32,
            scale_ups: a.stats.scale_ups.load(Ordering::Relaxed),
            scale_downs: a.stats.scale_downs.load(Ordering::Relaxed),
            power_mw: a.stats.power_mw.load(Ordering::Relaxed),
            budget_mw: a.stats.budget_mw.load(Ordering::Relaxed),
            power_degraded: a.stats.power_degraded.load(Ordering::Relaxed),
        },
        None => wire::AutoscaleHealth::default(),
    }
}

/// Render the full Prometheus exposition text — the `/metrics` sidecar
/// body and the `StatsV2` payload are byte-identical.
fn render_metrics_text(shared: &Shared) -> String {
    let snap = shared.coord.metrics().snapshot();
    let health = health_report(shared);
    render_prometheus(
        &snap,
        &health,
        &shared.energy,
        shared.start.elapsed().as_secs_f64(),
        shared.tracer.len() as u64,
        shared.tracer.dropped(),
        &shared.loop_stats.gauges(),
        &autoscale_export(shared),
    )
}

/// Snapshot the resilience counters for one `Health` response.
fn health_report(shared: &Shared) -> HealthReport {
    let snap = shared.coord.metrics().snapshot();
    let capacity = shared.coord.queue_capacity() as u32;
    let pools = shared
        .coord
        .pool_names()
        .iter()
        .enumerate()
        .map(|(i, name)| {
            // Pools that have not served yet have no metrics entry.
            let m = snap.backends.get(name);
            PoolHealth {
                name: name.clone(),
                queue_depth: shared.coord.queue_depth(i).unwrap_or(0) as u32,
                queue_capacity: capacity,
                replicas: shared.coord.pool_replicas(i).unwrap_or(0) as u32,
                shed: m.map_or(0, |m| m.shed),
                expired: m.map_or(0, |m| m.expired),
            }
        })
        .collect();
    HealthReport {
        degraded: shared.routes.values().any(|r| r.degrade.is_degraded()),
        degraded_transitions: snap.degraded_transitions,
        read_timeouts: shared.read_timeouts.load(Ordering::Relaxed),
        pools,
        busy_rejected: snap.busy_rejected,
        bad_requests: snap.bad_requests.clone(),
    }
}

/// The precision `ListModels` reports for one slot: its pinned
/// preference if an operator set one, else the precision of the route's
/// first (wire index 0) backend kind. The low-level [`Server::start`]
/// path carries no kind info and reports f32.
fn route_precision(route: &ModelRoute) -> Precision {
    route
        .slot
        .preferred_precision()
        .or_else(|| route.precisions.first().copied())
        .unwrap_or(Precision::F32)
}

/// A routing failure, opcode-agnostic.
struct RouteError(Status, String);

impl RouteError {
    fn into_frame(self, opcode: Opcode, id: u64) -> Frame {
        Frame::error(opcode, id, self.0, &self.1)
    }
}

/// Resolve `(model, backend, dim)` to a coordinator pool index.
///
/// Wrong-dimension payloads are rejected here, before they reach a
/// queue: a batch formed by the coordinator mixes requests from every
/// connection, and one bad sample would fail the whole batch
/// (`stage_inputs` errors are batch-wide) — other clients' valid
/// requests must not pay for it. [`BACKEND_ANY`] picks the least-loaded
/// of the model's pools (queue depth).
fn resolve_pool(
    shared: &Shared,
    model: &str,
    requested: u32,
    dim: usize,
) -> Result<usize, RouteError> {
    let name = if model.is_empty() { shared.default_model.as_str() } else { model };
    let route = shared.routes.get(name).ok_or_else(|| {
        RouteError(Status::UnknownModel, format!("unknown model '{name}'"))
    })?;
    if dim != route.input_dim {
        shared.coord.metrics().record_bad_request("input_dim");
        return Err(RouteError(
            Status::BadRequest,
            format!("input dimension {dim} != model '{name}' input {}", route.input_dim),
        ));
    }
    if requested == BACKEND_ANY {
        // A pinned slot precision narrows `BACKEND_ANY` to the pools
        // serving at it; if no pool matches (or the preference predates
        // a backend-set change), every pool stays in play. Explicitly
        // indexed requests bypass the preference entirely.
        let preferred: Option<Vec<usize>> = route.slot.preferred_precision().map(|p| {
            route
                .pools
                .iter()
                .zip(&route.precisions)
                .filter(|(_, prec)| **prec == p)
                .map(|(i, _)| *i)
                .collect()
        });
        let candidates: &[usize] = match &preferred {
            Some(v) if !v.is_empty() => v,
            _ => &route.pools,
        };
        let idx = shared.coord.least_loaded_of(candidates).ok_or_else(|| {
            RouteError(Status::Internal, "model has no serving pools".into())
        })?;
        // Degraded-mode check rides the routing decision: the occupancy
        // of the best pool the router could pick is the load signal.
        // Sustained saturation flips `BACKEND_ANY` traffic onto the
        // cheapest backend; explicitly indexed requests are untouched.
        let capacity = shared.coord.queue_capacity().max(1);
        let occupancy = shared.coord.queue_depth(idx).unwrap_or(0) as f64 / capacity as f64;
        let (degraded, flipped) = route.degrade.observe(occupancy, Instant::now());
        if flipped {
            shared.coord.metrics().record_degraded_transition();
        }
        if degraded {
            return Ok(route.cheapest_pool);
        }
        return Ok(idx);
    }
    let idx = requested as usize;
    route.pools.get(idx).copied().ok_or_else(|| {
        RouteError(
            Status::UnknownBackend,
            format!("backend index {idx} out of range ({} backends)", route.pools.len()),
        )
    })
}

fn submit_error_frame(opcode: Opcode, id: u64, e: SubmitError) -> Frame {
    match e {
        SubmitError::Backpressure => {
            Frame::error(opcode, id, Status::Backpressure, "queue full — retry later")
        }
        SubmitError::Closed => {
            Frame::error(opcode, id, Status::Closed, "coordinator shutting down")
        }
        SubmitError::UnknownBackend => {
            Frame::error(opcode, id, Status::UnknownBackend, "unknown backend")
        }
        SubmitError::Expired { estimated_wait } => Frame::error(
            opcode,
            id,
            Status::Expired,
            &format!(
                "deadline infeasible: estimated queue wait {:.1} ms already exceeds it",
                estimated_wait.as_secs_f64() * 1e3
            ),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Degraded mode must prefer the lowest-bytes-per-sample datapath:
    /// packed int4, then int8, then the SPx shift-add paths, then the
    /// f32 CPU forwards — the paper's precision-for-power trade.
    #[test]
    fn cheapest_backend_is_the_quantized_datapath() {
        let kinds = [
            BackendKind::Cpu,
            BackendKind::PipelineCpu { depth: 2 },
            BackendKind::PipelineFpga { config: AccelConfig::default_fpga(), depth: 2 },
            BackendKind::FpgaSim(AccelConfig::default_fpga()),
            BackendKind::Int8,
            BackendKind::Int4,
        ];
        let cheapest = kinds.iter().min_by_key(|k| k.cost_rank()).unwrap();
        assert!(matches!(cheapest, BackendKind::Int4));
        let mut ranks: Vec<u8> = kinds.iter().map(|k| k.cost_rank()).collect();
        ranks.sort_unstable();
        assert_eq!(ranks, vec![0, 1, 2, 3, 4, 5], "cost ranks must be a strict order");
        // Without the integer kinds the SPx datapath stays cheapest —
        // the pre-existing degraded-mode behavior.
        let cheapest_spx = kinds[..4].iter().min_by_key(|k| k.cost_rank()).unwrap();
        assert!(matches!(cheapest_spx, BackendKind::FpgaSim(_)));
    }

    /// Every backend kind maps to the wire precision its pool serves
    /// at, and labels match the CLI spellings `Precision::parse` takes.
    #[test]
    fn backend_kinds_report_their_precision() {
        let cases = [
            (BackendKind::Cpu, Precision::F32),
            (BackendKind::PipelineCpu { depth: 2 }, Precision::F32),
            (BackendKind::FpgaSim(AccelConfig::default_fpga()), Precision::Spx),
            (
                BackendKind::PipelineFpga { config: AccelConfig::default_fpga(), depth: 2 },
                Precision::Spx,
            ),
            (BackendKind::Int8, Precision::Int8),
            (BackendKind::Int4, Precision::Int4),
        ];
        for (kind, want) in cases {
            assert_eq!(kind.precision(), want, "{}", kind.label());
        }
        assert_eq!(Precision::parse(BackendKind::Int8.label()), Some(Precision::Int8));
        assert_eq!(Precision::parse(BackendKind::Int4.label()), Some(Precision::Int4));
    }

    #[test]
    fn serve_config_defaults_are_safe() {
        let c = ServeConfig::default();
        assert!(c.read_timeout >= Duration::from_secs(1), "read deadline too twitchy");
        assert!(c.degrade.validate().is_ok());
        assert!(c.metrics_addr.is_none(), "no sidecar unless asked");
        assert!(c.trace_capacity > 0, "tracing should default on");
    }

    /// The per-cause BadRequest labels must stay stable against the
    /// exact diagnostics `wire::read_frame*` produces today.
    #[test]
    fn framing_causes_match_wire_diagnostics() {
        assert_eq!(framing_cause("bad magic [58, 4d, 57, 50]"), "magic");
        assert_eq!(framing_cause("unsupported protocol version 9 (supported 1..=4)"), "version");
        assert_eq!(framing_cause("unknown opcode 200"), "opcode");
        assert_eq!(framing_cause("unknown status 77"), "status");
        assert_eq!(framing_cause("payload length 999 exceeds cap 16"), "payload_cap");
        assert_eq!(framing_cause("connection closed mid-frame"), "truncated");
        assert_eq!(framing_cause("something new"), "framing");
    }

    /// Token packing must round-trip (slot, generation) and never
    /// collide with the reserved listener/waker tokens for any slot a
    /// real slab can hold.
    #[test]
    fn conn_tokens_round_trip_and_avoid_reserved_values() {
        for (generation, idx) in [(1u64, 0usize), (7, 42), (0xffff_fffe, 123_456)] {
            let t = conn_token(generation, idx);
            assert_eq!(token_slot(t), idx);
            assert_eq!(token_generation(t), generation & 0xffff_ffff);
            assert_ne!(t, LISTENER_TOKEN);
            assert_ne!(t, WAKER_TOKEN);
        }
    }
}
