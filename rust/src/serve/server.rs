//! The TCP serving front-end: a `TcpListener` acceptor plus a bounded
//! pool of per-connection worker threads layered on the
//! [`crate::coordinator::Coordinator`].
//!
//! Each accepted connection gets a *reader* thread (decodes frames,
//! submits into the coordinator's batching queues) and a *writer*
//! thread (resolves responses in submission order and puts them back on
//! the wire, echoing each request's id and protocol version). Because
//! the reader never waits for inference to finish, a single connection
//! can keep many requests in flight — that pipelining is what lets the
//! dynamic batcher form real batches from one client.
//!
//! Multi-model routing: every served model (a registry *slot*) owns a
//! list of coordinator pools, one per backend kind, each pool holding
//! `replicas` workers. A v2 `Infer`/`InferBatch` frame names its model;
//! v1 frames (and the empty name) resolve to the default model.
//! [`Server::serve`] builds the whole engine — pools, routes, registry
//! wiring — from an [`EngineConfig`]; [`Server::start`] remains the
//! low-level single-model entry for custom coordinators.
//!
//! Load shedding and shutdown map onto protocol status codes
//! ([`SubmitError::Backpressure`] → `Status::Backpressure`,
//! [`SubmitError::Closed`] → `Status::Closed`); connections over the
//! pool limit are answered with a `Status::Busy` error frame and
//! dropped.

use super::pipeline_backend::{pipeline_cpu_factory_traced, pipeline_fpga_factory_traced};
use super::registry::{ModelRegistry, ModelSlot, SwapError};
use super::wire::{
    self, Frame, HealthReport, ModelInfo, Opcode, PoolHealth, Precision, ReadError, Status,
    BACKEND_ANY, DEFAULT_MAX_PAYLOAD,
};
use crate::coordinator::degrade::{DegradeController, DegradePolicy};
use crate::coordinator::request::{FailureKind, InferResult};
use crate::coordinator::server::{Coordinator, PoolSpec, RequestQos, SubmitError};
use crate::coordinator::CoordinatorConfig;
use crate::fpga::accelerator::AccelConfig;
use crate::fpga::power::EnergyModel;
use crate::obs::{render_energy_text, render_prometheus, MetricsHttp, TraceRecorder};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Connection-pool bound; further connections get `Status::Busy`.
    pub max_conns: usize,
    /// Per-frame payload cap.
    pub max_payload: u32,
    /// How long the writer waits for one inference result before
    /// answering `Status::Internal`.
    pub response_timeout: Duration,
    /// Reader deadline per frame: a connection that stays silent — or
    /// dribbles a partial frame — longer than this is answered
    /// `Status::Timeout` and closed, so slowloris peers cannot pin
    /// connection-pool slots (`docs/serving-resilience.md`).
    pub read_timeout: Duration,
    /// Degraded-mode hysteresis; every model's controller shares it.
    pub degrade: DegradePolicy,
    /// Bind address for the Prometheus exposition sidecar
    /// (`GET /metrics`); `None` = no sidecar. The same text is always
    /// reachable in-band via the `StatsV2` opcode.
    pub metrics_addr: Option<String>,
    /// Request-lifecycle trace ring capacity, in events; 0 disables
    /// tracing entirely (the recorder still exists so `DumpTrace`
    /// answers an empty trace instead of an error).
    pub trace_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_conns: 64,
            max_payload: DEFAULT_MAX_PAYLOAD,
            response_timeout: Duration::from_secs(30),
            read_timeout: Duration::from_secs(30),
            degrade: DegradePolicy::default(),
            metrics_addr: None,
            trace_capacity: 8192,
        }
    }
}

/// Which backend kinds an engine pool runs.
#[derive(Debug, Clone, Copy)]
pub enum BackendKind {
    /// The f32 CPU forward ([`crate::coordinator::CpuBackend`]).
    Cpu,
    /// The cycle-accurate SPx accelerator simulator.
    FpgaSim(AccelConfig),
    /// The stage-pipelined f32 forward
    /// ([`super::pipeline_backend::PipelineCpuBackend`]): one thread
    /// per layer, `depth` micro-batches in flight, bitwise identical
    /// outputs to [`BackendKind::Cpu`].
    PipelineCpu {
        /// Maximum in-flight micro-batches (CLI `--pipeline-depth`).
        depth: usize,
    },
    /// The stage-pipelined SPx path
    /// ([`super::pipeline_backend::PipelineFpgaBackend`]): bitwise
    /// identical outputs to [`BackendKind::FpgaSim`].
    PipelineFpga {
        /// Simulator microarchitecture (same as [`BackendKind::FpgaSim`]).
        config: AccelConfig,
        /// Maximum in-flight micro-batches (CLI `--pipeline-depth`).
        depth: usize,
    },
    /// The VSQ int8 integer forward ([`crate::coordinator::VsqBackend`]):
    /// per-row-group scaled int8 weights through the SIMD widening dot.
    Int8,
    /// The VSQ int4 variant — the smallest weight footprint the engine
    /// can serve, and what degraded mode prefers.
    Int4,
}

impl BackendKind {
    fn label(&self) -> &'static str {
        match self {
            BackendKind::Cpu => "cpu",
            BackendKind::FpgaSim(_) => "fpga",
            BackendKind::PipelineCpu { .. } => "pipeline",
            BackendKind::PipelineFpga { .. } => "pipeline-fpga",
            BackendKind::Int8 => "int8",
            BackendKind::Int4 => "int4",
        }
    }

    /// The numeric precision this kind serves at — what `ListModels`
    /// reports for a slot with no explicit preference, and the key for
    /// its weight-footprint metrics.
    fn precision(&self) -> Precision {
        match self {
            BackendKind::Cpu | BackendKind::PipelineCpu { .. } => Precision::F32,
            BackendKind::FpgaSim(_) | BackendKind::PipelineFpga { .. } => Precision::Spx,
            BackendKind::Int8 => Precision::Int8,
            BackendKind::Int4 => Precision::Int4,
        }
    }

    /// Relative serving cost, lower = cheaper — ordered by weight bytes
    /// moved per sample. Degraded mode routes `BACKEND_ANY` traffic to
    /// the model's cheapest kind: the packed int4/int8 integer paths
    /// beat the SPx shift-add datapaths, which beat the f32 CPU
    /// forwards — the paper's precision-for-power trade.
    fn cost_rank(&self) -> u8 {
        match self {
            BackendKind::Int4 => 0,
            BackendKind::Int8 => 1,
            BackendKind::FpgaSim(_) => 2,
            BackendKind::PipelineFpga { .. } => 3,
            BackendKind::PipelineCpu { .. } => 4,
            BackendKind::Cpu => 5,
        }
    }
}

/// Everything [`Server::serve`] needs to assemble the engine: which
/// backend kinds to run, how many replica workers per pool, and the
/// coordinator/server knobs.
pub struct EngineConfig {
    /// Worker replicas per (backend kind × model) pool.
    pub replicas: usize,
    /// Backend kinds, in wire `backend`-index order.
    pub backends: Vec<BackendKind>,
    pub coordinator: CoordinatorConfig,
    pub serve: ServeConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            replicas: 1,
            backends: vec![BackendKind::Cpu],
            coordinator: CoordinatorConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

/// How often blocked connection reads wake up to check the stop flag.
const READ_TICK: Duration = Duration::from_millis(100);

/// Routing entry for one served model: its slot, the coordinator pools
/// serving it (in backend-kind order), and the cached input dimension
/// (invariant for the server's lifetime — `activate_into` refuses dim
/// changes), so per-frame validation does not lock the registry.
struct ModelRoute {
    slot: Arc<ModelSlot>,
    pools: Vec<usize>,
    /// Serving precision of each pool, parallel to `pools` — the
    /// `ListModels` column and the filter for a slot's precision
    /// preference (empty on the low-level [`Server::start`] path, where
    /// backend kinds are unknown).
    precisions: Vec<Precision>,
    input_dim: usize,
    /// Hysteresis state machine deciding when sustained saturation
    /// flips this model's `BACKEND_ANY` routing to `cheapest_pool`.
    degrade: DegradeController,
    /// The pool degraded mode routes to (cheapest
    /// [`BackendKind::cost_rank`] among `pools`).
    cheapest_pool: usize,
}

struct Shared {
    coord: Coordinator,
    registry: Arc<ModelRegistry>,
    config: ServeConfig,
    routes: BTreeMap<String, ModelRoute>,
    default_model: String,
    stop: AtomicBool,
    active_conns: AtomicUsize,
    conn_seq: AtomicUsize,
    /// Connections closed by the reader deadline (slowloris defense);
    /// surfaced by the `Health` opcode.
    read_timeouts: AtomicU64,
    /// Request-lifecycle trace ring shared with the coordinator and
    /// pipeline stages; the `DumpTrace` opcode exports it.
    tracer: Arc<TraceRecorder>,
    /// Per-operation energy coefficients used to convert aggregate
    /// [`crate::fpga::accelerator::CycleStats`] into joules on the
    /// `Stats` / `StatsV2` responses and the `/metrics` sidecar.
    energy: EnergyModel,
    /// Server start, the origin of `edgemlp_uptime_seconds` and the
    /// window for average-power figures.
    start: Instant,
}

/// A running server. [`Server::shutdown`] (or drop) stops accepting,
/// winds down connections, and drains the coordinator.
pub struct Server {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Prometheus exposition sidecar, when `metrics_addr` was set.
    metrics_http: Option<MetricsHttp>,
}

impl Server {
    /// Assemble and start the full engine: one coordinator pool per
    /// (backend kind × registry slot), each pool `replicas` workers
    /// deep, with routes wired so wire-protocol model names reach the
    /// right pools. Pool labels are `"<kind>/<model>"` (the per-model
    /// metrics breakdown).
    pub fn serve(
        registry: Arc<ModelRegistry>,
        addr: &str,
        engine: EngineConfig,
    ) -> Result<Server> {
        if engine.backends.is_empty() {
            bail!("engine needs at least one backend kind");
        }
        engine.serve.degrade.validate().map_err(|e| anyhow::anyhow!(e))?;
        let replicas = engine.replicas.max(1);
        // One trace ring for the whole engine: connection handlers, the
        // coordinator's queues/workers, and every pipeline stage write
        // into it. Capacity 0 keeps the recorder (DumpTrace still
        // answers) but disables recording.
        let tracer = TraceRecorder::new(engine.serve.trace_capacity);
        let pool_tracer =
            if engine.serve.trace_capacity > 0 { Some(tracer.clone()) } else { None };
        let mut pools = Vec::new();
        let mut routes = BTreeMap::new();
        for slot in registry.slots() {
            let mut indices = Vec::with_capacity(engine.backends.len());
            for kind in &engine.backends {
                let factory = match kind {
                    BackendKind::Cpu => super::registry::swappable_cpu_factory(slot.clone()),
                    BackendKind::FpgaSim(config) => {
                        super::registry::swappable_fpga_factory(slot.clone(), *config)
                    }
                    BackendKind::PipelineCpu { depth } => {
                        pipeline_cpu_factory_traced(slot.clone(), *depth, pool_tracer.clone())
                    }
                    BackendKind::PipelineFpga { config, depth } => {
                        pipeline_fpga_factory_traced(
                            slot.clone(),
                            *config,
                            *depth,
                            pool_tracer.clone(),
                        )
                    }
                    BackendKind::Int8 => {
                        super::registry::swappable_vsq_factory(slot.clone(), 8)
                    }
                    BackendKind::Int4 => {
                        super::registry::swappable_vsq_factory(slot.clone(), 4)
                    }
                };
                indices.push(pools.len());
                pools.push(PoolSpec::replicated(
                    format!("{}/{}", kind.label(), slot.name()),
                    replicas,
                    factory,
                ));
            }
            let input_dim = slot.active().input_dim();
            // Position of the cheapest backend kind in this route's
            // pool list, precomputed so degraded routing is a lookup.
            let cheapest = engine
                .backends
                .iter()
                .enumerate()
                .min_by_key(|(_, k)| k.cost_rank())
                .map(|(i, _)| indices[i])
                .expect("backends is non-empty");
            routes.insert(
                slot.name().to_string(),
                ModelRoute {
                    slot,
                    pools: indices,
                    precisions: engine.backends.iter().map(|k| k.precision()).collect(),
                    input_dim,
                    degrade: DegradeController::new(engine.serve.degrade),
                    cheapest_pool: cheapest,
                },
            );
        }
        let coord = Coordinator::start_traced(pools, engine.coordinator, pool_tracer)?;
        // Register each pool's weight footprint (bytes streamed per
        // sample) with the metrics sink — a static property of the
        // (model, precision) pair: `activate_into` refuses dimension
        // changes, so the figure holds across swaps.
        for route in routes.values() {
            let active = route.slot.active();
            for (kind, _) in engine.backends.iter().zip(&route.pools) {
                coord.metrics().set_pool_bytes(
                    &format!("{}/{}", kind.label(), route.slot.name()),
                    active.weight_bytes(kind.precision()),
                );
            }
        }
        let default_model = registry.default_slot_name().to_string();
        Self::start_inner(coord, registry, routes, default_model, addr, engine.serve, tracer)
    }

    /// Bind `addr` (use port 0 for an ephemeral port) and start
    /// accepting on a caller-built coordinator. Single-model routing:
    /// every pool of `coord` serves the registry's default slot, and
    /// wire backend indices map straight onto pool indices.
    pub fn start(
        coord: Coordinator,
        registry: Arc<ModelRegistry>,
        addr: &str,
        config: ServeConfig,
    ) -> Result<Server> {
        config.degrade.validate().map_err(|e| anyhow::anyhow!(e))?;
        let slot = registry.default_slot();
        let input_dim = slot.active().input_dim();
        let mut routes = BTreeMap::new();
        routes.insert(
            slot.name().to_string(),
            ModelRoute {
                slot,
                pools: (0..coord.num_pools()).collect(),
                precisions: Vec::new(),
                input_dim,
                degrade: DegradeController::new(config.degrade),
                // A caller-built coordinator carries no backend-kind
                // info; degraded mode falls back to the first pool.
                cheapest_pool: 0,
            },
        );
        let default_model = registry.default_slot_name().to_string();
        // A caller-built coordinator carries no tracer, so only the
        // connection-level events record on this path.
        let tracer = TraceRecorder::new(config.trace_capacity);
        Self::start_inner(coord, registry, routes, default_model, addr, config, tracer)
    }

    fn start_inner(
        coord: Coordinator,
        registry: Arc<ModelRegistry>,
        routes: BTreeMap<String, ModelRoute>,
        default_model: String,
        addr: &str,
        config: ServeConfig,
        tracer: Arc<TraceRecorder>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local_addr = listener.local_addr()?;
        let metrics_addr = config.metrics_addr.clone();
        let shared = Arc::new(Shared {
            coord,
            registry,
            config,
            routes,
            default_model,
            stop: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            conn_seq: AtomicUsize::new(0),
            read_timeouts: AtomicU64::new(0),
            tracer,
            energy: EnergyModel::default_fpga(),
            start: Instant::now(),
        });
        let metrics_http = match metrics_addr {
            Some(addr) => {
                let render_shared = shared.clone();
                let render: Arc<dyn Fn() -> String + Send + Sync> =
                    Arc::new(move || render_metrics_text(&render_shared));
                Some(
                    MetricsHttp::start(&addr, render)
                        .with_context(|| format!("bind metrics sidecar {addr}"))?,
                )
            }
            None => None,
        };
        let conns = Arc::new(Mutex::new(Vec::new()));
        let acceptor = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("edgemlp-accept".into())
                .spawn(move || accept_loop(listener, shared, conns))
                .context("spawn acceptor")?
        };
        Ok(Server { shared, local_addr, acceptor: Some(acceptor), conns, metrics_http })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Shared serving metrics (the coordinator's sink).
    pub fn metrics(&self) -> Arc<crate::coordinator::Metrics> {
        self.shared.coord.metrics()
    }

    /// The request-lifecycle trace ring (what `DumpTrace` exports).
    pub fn tracer(&self) -> Arc<TraceRecorder> {
        self.shared.tracer.clone()
    }

    /// Bound address of the Prometheus sidecar, when one is running
    /// (resolves ephemeral ports).
    pub fn metrics_local_addr(&self) -> Option<SocketAddr> {
        self.metrics_http.as_ref().map(|m| m.local_addr())
    }

    /// Stop accepting, wind down connection threads (their in-flight
    /// responses are still written), close the coordinator queues and
    /// join everything.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(m) = self.metrics_http.take() {
            m.shutdown();
        }
        // Unblock the acceptor with a throwaway connection. A bind to
        // 0.0.0.0/:: is not connectable on every platform — aim the
        // wakeup at loopback on the bound port instead.
        let mut wake = self.local_addr;
        if wake.ip().is_unspecified() {
            match wake.ip() {
                std::net::IpAddr::V4(_) => {
                    wake.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST))
                }
                std::net::IpAddr::V6(_) => {
                    wake.set_ip(std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST))
                }
            }
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.conns.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        // Queues close only after every connection finished submitting;
        // workers drain what is left and exit (joined by Coordinator's
        // Drop when `shared` goes away).
        self.shared.coord.stop();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.shared.stop.load(Ordering::SeqCst) {
            self.stop_and_join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    loop {
        let (stream, _peer) = match listener.accept() {
            Ok(s) => s,
            Err(_) if shared.stop.load(Ordering::SeqCst) => return,
            Err(_) => {
                // Persistent failures (e.g. EMFILE when the fd limit is
                // hit) must not busy-spin the acceptor core.
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        // Reap finished handlers so the vec stays bounded.
        {
            let mut held = conns.lock().unwrap();
            let mut live = Vec::with_capacity(held.len());
            for h in held.drain(..) {
                if h.is_finished() {
                    let _ = h.join();
                } else {
                    live.push(h);
                }
            }
            *held = live;
        }
        if shared.active_conns.load(Ordering::SeqCst) >= shared.config.max_conns {
            // Over the pool bound: answer Busy, then close carefully so
            // the frame survives (see `drain_then_close`). No request
            // was read, so the frame goes out at MIN_VERSION — the one
            // framing every supported client can parse.
            shared.coord.metrics().record_busy_rejected();
            if shared.tracer.enabled() {
                shared.tracer.instant("conn", "busy_reject", None, 0);
            }
            {
                let mut w = BufWriter::new(&stream);
                let frame =
                    Frame::error(Opcode::Ping, 0, Status::Busy, "server connection limit reached")
                        .at_version(wire::MIN_VERSION);
                let _ = wire::write_frame(&mut w, &frame);
                let _ = w.flush();
            }
            // Off-thread: the drain can dwell up to its deadline and
            // must not stall the acceptor during a connection flood.
            std::thread::spawn(move || drain_then_close(stream));
            continue;
        }
        shared.active_conns.fetch_add(1, Ordering::SeqCst);
        let id = shared.conn_seq.fetch_add(1, Ordering::Relaxed);
        let shared2 = shared.clone();
        let handle = std::thread::Builder::new()
            .name(format!("edgemlp-conn-{id}"))
            .spawn(move || {
                let _guard = ConnGuard(shared2.clone());
                handle_connection(stream, &shared2);
            });
        match handle {
            Ok(h) => conns.lock().unwrap().push(h),
            Err(_) => {
                shared.active_conns.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

struct ConnGuard(Arc<Shared>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.active_conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Work items handed from the reader to the writer, in request order.
/// `version` is the protocol version of the request being answered —
/// the response frame echoes it.
enum Outgoing {
    /// Response already known (ping, stats, errors, swap results).
    Ready(Frame),
    /// Waiting on one coordinator response.
    Pending { version: u16, request_id: u64, rx: Receiver<InferResult> },
    /// Waiting on a whole submitted batch.
    PendingBatch { version: u16, request_id: u64, receivers: Vec<Receiver<InferResult>> },
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    if shared.tracer.enabled() {
        shared.tracer.instant("conn", "accept", None, 0);
    }
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_TICK));
    let write_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = write_stream.set_write_timeout(Some(Duration::from_secs(10)));
    let (tx, rx) = channel::<Outgoing>();
    let response_timeout = shared.config.response_timeout;
    let writer = std::thread::Builder::new()
        .name("edgemlp-conn-writer".into())
        .spawn(move || writer_loop(write_stream, rx, response_timeout));
    let writer = match writer {
        Ok(w) => w,
        Err(_) => return,
    };

    let mut reader = BufReader::new(stream);
    let mut framing_error = false;
    loop {
        // The deadline restarts per frame: an active connection can
        // live forever, one that goes silent — or drips a partial
        // header — is cut off (slowloris defense).
        let deadline = Instant::now() + shared.config.read_timeout;
        match wire::read_frame_deadline(
            &mut reader,
            shared.config.max_payload,
            Some(&shared.stop),
            Some(deadline),
        ) {
            Ok(frame) => {
                if shared.tracer.enabled() {
                    shared.tracer.instant("conn", "decode", None, frame.request_id);
                }
                if !dispatch(frame, &tx, shared) {
                    break;
                }
            }
            Err(ReadError::Eof) | Err(ReadError::Stopped) | Err(ReadError::Io(_)) => break,
            Err(ReadError::TimedOut) => {
                shared.read_timeouts.fetch_add(1, Ordering::Relaxed);
                // No request id to echo and the version is unknown —
                // frame the goodbye at MIN_VERSION like framing errors.
                let _ = tx.send(Outgoing::Ready(
                    Frame::error(
                        Opcode::Ping,
                        0,
                        Status::Timeout,
                        "read deadline exceeded — closing idle/stalled connection",
                    )
                    .at_version(wire::MIN_VERSION),
                ));
                framing_error = true; // same careful close as below
                break;
            }
            Err(ReadError::Protocol(msg)) => {
                // The stream position is unreliable after a framing
                // error: answer once, then close. The request version
                // is unknown here, so frame the reply at MIN_VERSION —
                // every supported client can parse it (a v1-only
                // client would reject a v2 frame and lose the
                // diagnostic).
                shared.coord.metrics().record_bad_request(framing_cause(&msg));
                if shared.tracer.enabled() {
                    shared.tracer.instant("conn", "bad_request", None, 0);
                }
                let _ = tx.send(Outgoing::Ready(
                    Frame::error(Opcode::Ping, 0, Status::BadRequest, &msg)
                        .at_version(wire::MIN_VERSION),
                ));
                framing_error = true;
                break;
            }
        }
    }
    // Dropping the sender lets the writer drain every queued/pending
    // response before exiting — in-flight work is never dropped.
    drop(tx);
    let _ = writer.join();
    if framing_error {
        // A malformed stream usually has more bytes in flight; closing
        // with unread data would RST away the BadRequest frame.
        drain_then_close(reader.into_inner());
    }
}

/// Close a socket so that a just-written error frame survives: send our
/// FIN first, then briefly discard whatever the peer already sent —
/// closing with unread receive data turns into a RST that destroys
/// in-flight output on common TCP stacks.
fn drain_then_close(mut stream: TcpStream) {
    use std::io::Read;
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut sink = [0u8; 4096];
    let deadline = std::time::Instant::now() + Duration::from_millis(250);
    while std::time::Instant::now() < deadline {
        match stream.read(&mut sink) {
            Ok(0) => break, // peer acknowledged the FIN and closed
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(_) => break,
        }
    }
}

fn writer_loop(stream: TcpStream, rx: Receiver<Outgoing>, response_timeout: Duration) {
    let mut w = BufWriter::new(stream);
    for item in rx {
        let frame = resolve(item, response_timeout);
        if wire::write_frame(&mut w, &frame).is_err() || w.flush().is_err() {
            return;
        }
    }
}

/// The wire status one coordinator failure maps to.
fn failure_status(kind: FailureKind) -> Status {
    match kind {
        FailureKind::Backend => Status::BackendError,
        FailureKind::Expired => Status::Expired,
    }
}

/// Turn one queued work item into the frame that goes on the wire.
fn resolve(item: Outgoing, timeout: Duration) -> Frame {
    match item {
        Outgoing::Ready(frame) => frame,
        Outgoing::Pending { version, request_id, rx } => match rx.recv_timeout(timeout) {
            Ok(Ok(resp)) => {
                Frame::ok(Opcode::Infer, request_id, wire::encode_outputs(&resp.output))
                    .at_version(version)
            }
            Ok(Err(e)) => {
                Frame::error(Opcode::Infer, request_id, failure_status(e.kind), &e.message)
                    .at_version(version)
            }
            Err(_) => Frame::error(
                Opcode::Infer,
                request_id,
                Status::Internal,
                "response channel lost or timed out",
            )
            .at_version(version),
        },
        Outgoing::PendingBatch { version, request_id, receivers } => {
            // One deadline for the whole batch — a per-receiver timeout
            // would multiply worst-case head-of-line blocking by the
            // batch size.
            let deadline = std::time::Instant::now() + timeout;
            let mut rows = Vec::with_capacity(receivers.len());
            for rx in receivers {
                let left = deadline.saturating_duration_since(std::time::Instant::now());
                match rx.recv_timeout(left) {
                    Ok(Ok(resp)) => rows.push(resp.output),
                    Ok(Err(e)) => {
                        return Frame::error(
                            Opcode::InferBatch,
                            request_id,
                            failure_status(e.kind),
                            &e.message,
                        )
                        .at_version(version)
                    }
                    Err(_) => {
                        return Frame::error(
                            Opcode::InferBatch,
                            request_id,
                            Status::Internal,
                            "response channel lost or timed out",
                        )
                        .at_version(version)
                    }
                }
            }
            Frame::ok(Opcode::InferBatch, request_id, wire::encode_batch_outputs(&rows))
                .at_version(version)
        }
    }
}

/// Handle one request frame. Returns `false` to close the connection.
fn dispatch(frame: Frame, tx: &Sender<Outgoing>, shared: &Shared) -> bool {
    let id = frame.request_id;
    let version = frame.version;
    let out = match frame.opcode {
        Opcode::Ping => Outgoing::Ready(Frame::ok(Opcode::Ping, id, frame.payload)),
        Opcode::Stats => {
            let snap = shared.coord.metrics().snapshot();
            let mut text = String::new();
            for route in shared.routes.values() {
                let active = route.slot.active();
                let tag = if route.slot.name() == shared.default_model { " [default]" } else { "" };
                text.push_str(&format!(
                    "model {}{tag}: {} v{} ({}→{}, generation {})\n",
                    route.slot.name(),
                    active.name,
                    active.version,
                    active.input_dim(),
                    active.output_dim(),
                    route.slot.generation(),
                ));
            }
            text.push_str(&format!(
                "connections: {}\n{}",
                shared.active_conns.load(Ordering::SeqCst),
                snap.render()
            ));
            text.push_str(&render_energy_text(
                &shared.energy,
                &snap,
                shared.start.elapsed().as_secs_f64(),
            ));
            Outgoing::Ready(Frame::ok(Opcode::Stats, id, text.into_bytes()))
        }
        Opcode::StatsV2 => {
            if version < 4 {
                bad_request(
                    shared,
                    "version_gate",
                    Opcode::StatsV2,
                    id,
                    "StatsV2 requires protocol v4",
                )
            } else {
                Outgoing::Ready(Frame::ok(
                    Opcode::StatsV2,
                    id,
                    render_metrics_text(shared).into_bytes(),
                ))
            }
        }
        Opcode::DumpTrace => {
            if version < 4 {
                bad_request(
                    shared,
                    "version_gate",
                    Opcode::DumpTrace,
                    id,
                    "DumpTrace requires protocol v4",
                )
            } else {
                Outgoing::Ready(Frame::ok(
                    Opcode::DumpTrace,
                    id,
                    shared.tracer.export_chrome_json().into_bytes(),
                ))
            }
        }
        Opcode::ListModels => {
            if version < 2 {
                bad_request(
                    shared,
                    "version_gate",
                    Opcode::ListModels,
                    id,
                    "ListModels requires protocol v2",
                )
            } else {
                let models: Vec<ModelInfo> = shared
                    .routes
                    .values()
                    .map(|route| {
                        let active = route.slot.active();
                        ModelInfo {
                            slot: route.slot.name().to_string(),
                            model: active.name.clone(),
                            version: active.version,
                            input_dim: active.input_dim() as u32,
                            output_dim: active.output_dim() as u32,
                            generation: route.slot.generation(),
                            precision: route_precision(route),
                        }
                    })
                    .collect();
                // Encode at the REQUEST's version: the v4 precision
                // suffix would be trailing garbage to a pre-v4 decoder.
                match wire::encode_model_list_at(&models, version) {
                    Ok(payload) => Outgoing::Ready(Frame::ok(Opcode::ListModels, id, payload)),
                    Err(e) => Outgoing::Ready(Frame::error(
                        Opcode::ListModels,
                        id,
                        Status::Internal,
                        &e,
                    )),
                }
            }
        }
        Opcode::SwapModel => match wire::decode_swap_precision(&frame.payload, version) {
            Err(e) => bad_request(shared, "decode_swap", Opcode::SwapModel, id, &e),
            Ok((slot, source, precision)) => match shared.registry.activate_into(&slot, &source)
            {
                Ok((model, generation)) => {
                    let name =
                        if slot.is_empty() { shared.default_model.as_str() } else { &slot };
                    // The v4 precision byte pins the slot's serving
                    // precision alongside the activation; absent, the
                    // existing preference is left untouched.
                    let precision_note = match (precision, shared.routes.get(name)) {
                        (Some(p), Some(route)) => {
                            route.slot.set_preferred_precision(Some(p));
                            format!(", precision {p}")
                        }
                        _ => String::new(),
                    };
                    Outgoing::Ready(Frame::ok(
                        Opcode::SwapModel,
                        id,
                        format!(
                            "slot {name} now serves {} v{} (generation {generation}{precision_note})",
                            model.name, model.version
                        )
                        .into_bytes(),
                    ))
                }
                Err(e @ (SwapError::UnknownModel(_) | SwapError::UnknownSlot(_))) => {
                    Outgoing::Ready(Frame::error(
                        Opcode::SwapModel,
                        id,
                        Status::UnknownModel,
                        &e.to_string(),
                    ))
                }
                Err(e) => bad_request(shared, "swap_rejected", Opcode::SwapModel, id, &e.to_string()),
            },
        },
        Opcode::Health => {
            if version < 3 {
                bad_request(shared, "version_gate", Opcode::Health, id, "Health requires protocol v3")
            } else {
                let report = health_report(shared);
                // Encode at the REQUEST's version: the v4 extension
                // block would be trailing garbage to a v3 decoder.
                match wire::encode_health_at(&report, version) {
                    Ok(payload) => Outgoing::Ready(Frame::ok(Opcode::Health, id, payload)),
                    Err(e) => {
                        Outgoing::Ready(Frame::error(Opcode::Health, id, Status::Internal, &e))
                    }
                }
            }
        }
        Opcode::Infer => match wire::decode_infer(&frame.payload, version) {
            Err(e) => bad_request(shared, "decode_infer", Opcode::Infer, id, &e),
            Ok(req) => match resolve_pool(shared, &req.model, req.backend, req.x.len()) {
                Err(out) => Outgoing::Ready(out.into_frame(Opcode::Infer, id)),
                Ok(idx) => {
                    match shared.coord.try_submit_to_qos(idx, req.x, request_qos(req.qos)) {
                        Ok(rx) => Outgoing::Pending { version, request_id: id, rx },
                        Err(e) => Outgoing::Ready(submit_error_frame(Opcode::Infer, id, e)),
                    }
                }
            },
        },
        Opcode::InferBatch => match wire::decode_infer_batch(&frame.payload, version) {
            Err(e) => bad_request(shared, "decode_infer", Opcode::InferBatch, id, &e),
            Ok(req) => {
                match resolve_pool(shared, &req.model, req.backend, req.samples[0].len()) {
                    Err(out) => Outgoing::Ready(out.into_frame(Opcode::InferBatch, id)),
                    Ok(idx) => {
                        let total = req.samples.len();
                        let qos = request_qos(req.qos);
                        let mut receivers = Vec::with_capacity(total);
                        let mut failed = None;
                        for x in req.samples {
                            match shared.coord.try_submit_to_qos(idx, x, qos) {
                                Ok(rx) => receivers.push(rx),
                                Err(e) => {
                                    failed = Some(e);
                                    break;
                                }
                            }
                        }
                        match failed {
                            // Partially submitted samples still run;
                            // their receivers are dropped and the batch
                            // is reported shed as a unit.
                            Some(SubmitError::Backpressure) => Outgoing::Ready(Frame::error(
                                Opcode::InferBatch,
                                id,
                                Status::Backpressure,
                                &format!(
                                    "queue full after {}/{total} samples",
                                    receivers.len()
                                ),
                            )),
                            Some(e) => {
                                Outgoing::Ready(submit_error_frame(Opcode::InferBatch, id, e))
                            }
                            None => {
                                Outgoing::PendingBatch { version, request_id: id, receivers }
                            }
                        }
                    }
                }
            }
        },
    };
    // Responses echo the request's protocol version (a v1 client never
    // sees a v2 frame); pending items carry it to the writer instead.
    let out = match out {
        Outgoing::Ready(f) => Outgoing::Ready(f.at_version(version)),
        other => other,
    };
    tx.send(out).is_ok()
}

/// Stable cause label for a framing-level protocol error, keyed off the
/// diagnostic text (`wire::read_frame*`'s messages are the source of
/// truth; anything unrecognized lands in "framing").
fn framing_cause(msg: &str) -> &'static str {
    if msg.contains("magic") {
        "magic"
    } else if msg.contains("version") {
        "version"
    } else if msg.contains("opcode") {
        "opcode"
    } else if msg.contains("status") {
        "status"
    } else if msg.contains("exceeds cap") {
        "payload_cap"
    } else if msg.contains("mid-frame") {
        "truncated"
    } else {
        "framing"
    }
}

/// Answer `Status::BadRequest` and bump the per-cause counter. `cause`
/// is a low-cardinality stable label (it becomes a Prometheus label
/// value), NOT the free-form diagnostic.
fn bad_request(
    shared: &Shared,
    cause: &'static str,
    opcode: Opcode,
    id: u64,
    msg: &str,
) -> Outgoing {
    shared.coord.metrics().record_bad_request(cause);
    if shared.tracer.enabled() {
        shared.tracer.instant("conn", "bad_request", None, id);
    }
    Outgoing::Ready(Frame::error(opcode, id, Status::BadRequest, msg))
}

/// Map a wire QoS onto coordinator scheduling inputs. The wire deadline
/// is a *relative* budget (µs from server receipt — client and server
/// clocks need not agree); it becomes absolute here, so queueing and
/// service time all burn the same budget.
fn request_qos(qos: wire::Qos) -> RequestQos {
    RequestQos {
        deadline: qos
            .has_deadline()
            .then(|| Instant::now() + Duration::from_micros(qos.deadline_us)),
        priority: qos.priority.rank(),
    }
}

/// Render the full Prometheus exposition text — the `/metrics` sidecar
/// body and the `StatsV2` payload are byte-identical.
fn render_metrics_text(shared: &Shared) -> String {
    let snap = shared.coord.metrics().snapshot();
    let health = health_report(shared);
    render_prometheus(
        &snap,
        &health,
        &shared.energy,
        shared.start.elapsed().as_secs_f64(),
        shared.tracer.len() as u64,
        shared.tracer.dropped(),
    )
}

/// Snapshot the resilience counters for one `Health` response.
fn health_report(shared: &Shared) -> HealthReport {
    let snap = shared.coord.metrics().snapshot();
    let capacity = shared.coord.queue_capacity() as u32;
    let pools = shared
        .coord
        .pool_names()
        .iter()
        .enumerate()
        .map(|(i, name)| {
            // Pools that have not served yet have no metrics entry.
            let m = snap.backends.get(name);
            PoolHealth {
                name: name.clone(),
                queue_depth: shared.coord.queue_depth(i).unwrap_or(0) as u32,
                queue_capacity: capacity,
                replicas: shared.coord.pool_replicas(i).unwrap_or(0) as u32,
                shed: m.map_or(0, |m| m.shed),
                expired: m.map_or(0, |m| m.expired),
            }
        })
        .collect();
    HealthReport {
        degraded: shared.routes.values().any(|r| r.degrade.is_degraded()),
        degraded_transitions: snap.degraded_transitions,
        read_timeouts: shared.read_timeouts.load(Ordering::Relaxed),
        pools,
        busy_rejected: snap.busy_rejected,
        bad_requests: snap.bad_requests.clone(),
    }
}

/// The precision `ListModels` reports for one slot: its pinned
/// preference if an operator set one, else the precision of the route's
/// first (wire index 0) backend kind. The low-level [`Server::start`]
/// path carries no kind info and reports f32.
fn route_precision(route: &ModelRoute) -> Precision {
    route
        .slot
        .preferred_precision()
        .or_else(|| route.precisions.first().copied())
        .unwrap_or(Precision::F32)
}

/// A routing failure, opcode-agnostic.
struct RouteError(Status, String);

impl RouteError {
    fn into_frame(self, opcode: Opcode, id: u64) -> Frame {
        Frame::error(opcode, id, self.0, &self.1)
    }
}

/// Resolve `(model, backend, dim)` to a coordinator pool index.
///
/// Wrong-dimension payloads are rejected here, before they reach a
/// queue: a batch formed by the coordinator mixes requests from every
/// connection, and one bad sample would fail the whole batch
/// (`stage_inputs` errors are batch-wide) — other clients' valid
/// requests must not pay for it. [`BACKEND_ANY`] picks the least-loaded
/// of the model's pools (queue depth).
fn resolve_pool(
    shared: &Shared,
    model: &str,
    requested: u32,
    dim: usize,
) -> Result<usize, RouteError> {
    let name = if model.is_empty() { shared.default_model.as_str() } else { model };
    let route = shared.routes.get(name).ok_or_else(|| {
        RouteError(Status::UnknownModel, format!("unknown model '{name}'"))
    })?;
    if dim != route.input_dim {
        shared.coord.metrics().record_bad_request("input_dim");
        return Err(RouteError(
            Status::BadRequest,
            format!("input dimension {dim} != model '{name}' input {}", route.input_dim),
        ));
    }
    if requested == BACKEND_ANY {
        // A pinned slot precision narrows `BACKEND_ANY` to the pools
        // serving at it; if no pool matches (or the preference predates
        // a backend-set change), every pool stays in play. Explicitly
        // indexed requests bypass the preference entirely.
        let preferred: Option<Vec<usize>> = route.slot.preferred_precision().map(|p| {
            route
                .pools
                .iter()
                .zip(&route.precisions)
                .filter(|(_, prec)| **prec == p)
                .map(|(i, _)| *i)
                .collect()
        });
        let candidates: &[usize] = match &preferred {
            Some(v) if !v.is_empty() => v,
            _ => &route.pools,
        };
        let idx = shared.coord.least_loaded_of(candidates).ok_or_else(|| {
            RouteError(Status::Internal, "model has no serving pools".into())
        })?;
        // Degraded-mode check rides the routing decision: the occupancy
        // of the best pool the router could pick is the load signal.
        // Sustained saturation flips `BACKEND_ANY` traffic onto the
        // cheapest backend; explicitly indexed requests are untouched.
        let capacity = shared.coord.queue_capacity().max(1);
        let occupancy = shared.coord.queue_depth(idx).unwrap_or(0) as f64 / capacity as f64;
        let (degraded, flipped) = route.degrade.observe(occupancy, Instant::now());
        if flipped {
            shared.coord.metrics().record_degraded_transition();
        }
        if degraded {
            return Ok(route.cheapest_pool);
        }
        return Ok(idx);
    }
    let idx = requested as usize;
    route.pools.get(idx).copied().ok_or_else(|| {
        RouteError(
            Status::UnknownBackend,
            format!("backend index {idx} out of range ({} backends)", route.pools.len()),
        )
    })
}

fn submit_error_frame(opcode: Opcode, id: u64, e: SubmitError) -> Frame {
    match e {
        SubmitError::Backpressure => {
            Frame::error(opcode, id, Status::Backpressure, "queue full — retry later")
        }
        SubmitError::Closed => {
            Frame::error(opcode, id, Status::Closed, "coordinator shutting down")
        }
        SubmitError::UnknownBackend => {
            Frame::error(opcode, id, Status::UnknownBackend, "unknown backend")
        }
        SubmitError::Expired { estimated_wait } => Frame::error(
            opcode,
            id,
            Status::Expired,
            &format!(
                "deadline infeasible: estimated queue wait {:.1} ms already exceeds it",
                estimated_wait.as_secs_f64() * 1e3
            ),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Degraded mode must prefer the lowest-bytes-per-sample datapath:
    /// packed int4, then int8, then the SPx shift-add paths, then the
    /// f32 CPU forwards — the paper's precision-for-power trade.
    #[test]
    fn cheapest_backend_is_the_quantized_datapath() {
        let kinds = [
            BackendKind::Cpu,
            BackendKind::PipelineCpu { depth: 2 },
            BackendKind::PipelineFpga { config: AccelConfig::default_fpga(), depth: 2 },
            BackendKind::FpgaSim(AccelConfig::default_fpga()),
            BackendKind::Int8,
            BackendKind::Int4,
        ];
        let cheapest = kinds.iter().min_by_key(|k| k.cost_rank()).unwrap();
        assert!(matches!(cheapest, BackendKind::Int4));
        let mut ranks: Vec<u8> = kinds.iter().map(|k| k.cost_rank()).collect();
        ranks.sort_unstable();
        assert_eq!(ranks, vec![0, 1, 2, 3, 4, 5], "cost ranks must be a strict order");
        // Without the integer kinds the SPx datapath stays cheapest —
        // the pre-existing degraded-mode behavior.
        let cheapest_spx = kinds[..4].iter().min_by_key(|k| k.cost_rank()).unwrap();
        assert!(matches!(cheapest_spx, BackendKind::FpgaSim(_)));
    }

    /// Every backend kind maps to the wire precision its pool serves
    /// at, and labels match the CLI spellings `Precision::parse` takes.
    #[test]
    fn backend_kinds_report_their_precision() {
        let cases = [
            (BackendKind::Cpu, Precision::F32),
            (BackendKind::PipelineCpu { depth: 2 }, Precision::F32),
            (BackendKind::FpgaSim(AccelConfig::default_fpga()), Precision::Spx),
            (
                BackendKind::PipelineFpga { config: AccelConfig::default_fpga(), depth: 2 },
                Precision::Spx,
            ),
            (BackendKind::Int8, Precision::Int8),
            (BackendKind::Int4, Precision::Int4),
        ];
        for (kind, want) in cases {
            assert_eq!(kind.precision(), want, "{}", kind.label());
        }
        assert_eq!(Precision::parse(BackendKind::Int8.label()), Some(Precision::Int8));
        assert_eq!(Precision::parse(BackendKind::Int4.label()), Some(Precision::Int4));
    }

    #[test]
    fn serve_config_defaults_are_safe() {
        let c = ServeConfig::default();
        assert!(c.read_timeout >= Duration::from_secs(1), "read deadline too twitchy");
        assert!(c.degrade.validate().is_ok());
        assert!(c.metrics_addr.is_none(), "no sidecar unless asked");
        assert!(c.trace_capacity > 0, "tracing should default on");
    }

    /// The per-cause BadRequest labels must stay stable against the
    /// exact diagnostics `wire::read_frame*` produces today.
    #[test]
    fn framing_causes_match_wire_diagnostics() {
        assert_eq!(framing_cause("bad magic [58, 4d, 57, 50]"), "magic");
        assert_eq!(framing_cause("unsupported protocol version 9 (supported 1..=4)"), "version");
        assert_eq!(framing_cause("unknown opcode 200"), "opcode");
        assert_eq!(framing_cause("unknown status 77"), "status");
        assert_eq!(framing_cause("payload length 999 exceeds cap 16"), "payload_cap");
        assert_eq!(framing_cause("connection closed mid-frame"), "truncated");
        assert_eq!(framing_cause("something new"), "framing");
    }
}
